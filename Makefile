# imcopt build / verify entry points.
#
#   make build      release build (native evaluator; no xla needed)
#   make test       release build + full test suite
#   make lint       rustfmt --check + clippy -D warnings
#   make check      full CI gate (ci.sh): lint, build, tests, golden
#                   cross-check, bench + schema validation, bench-trend
#                   gate vs bench_baselines/, `imcopt run --all --quick`
#                   smoke + artifact validation, the --resume replay
#                   check and the orchestrator crash matrix. Run one
#                   stage with ./ci.sh --stage <name>.
#   make check-pjrt ci.sh against the pjrt feature (vendored xla API stub)
#   make bench      full benches (2s budget per case) -> BENCH_*.json
#   make artifacts  export the AOT JAX/Pallas artifacts (needs python+jax)
#   make pjrt       release build with the PJRT runtime (stub xla unless
#                   Cargo.toml points at the real crate)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test lint check check-pjrt bench artifacts pjrt clean

build:
	$(CARGO) build --release

test: build
	$(CARGO) test -q

lint:
	$(CARGO) fmt --all -- --check
	$(CARGO) clippy --all-targets -- -D warnings

check:
	./ci.sh

check-pjrt:
	IMCOPT_FEATURES="--features pjrt" ./ci.sh

bench:
	$(CARGO) bench --bench evaluator
	$(CARGO) bench --bench pareto
	$(CARGO) bench --bench surrogate

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

pjrt:
	$(CARGO) build --release --features pjrt

clean:
	$(CARGO) clean
	rm -f BENCH_eval.json BENCH_model.json BENCH_pareto.json BENCH_surrogate.json
