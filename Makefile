# imcopt build / verify entry points.
#
#   make build      release build (native evaluator; no xla needed)
#   make test       release build + full test suite
#   make check      CI gate: build + tests + evaluator bench smoke run
#                   (emits BENCH_eval.json with score_batch designs/sec)
#   make bench      full evaluator bench (2s budget per case)
#   make artifacts  export the AOT JAX/Pallas artifacts (needs python+jax)
#   make pjrt       release build with the PJRT runtime (needs xla crate)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test check bench artifacts pjrt clean

build:
	$(CARGO) build --release

test: build
	$(CARGO) test -q

check:
	./ci.sh

bench:
	$(CARGO) bench --bench evaluator

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

pjrt:
	$(CARGO) build --release --features pjrt

clean:
	$(CARGO) clean
	rm -f BENCH_eval.json
