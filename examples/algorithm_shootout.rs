//! Optimizer shootout (paper §III-C1, Table 3): exhaustively evaluate the
//! reduced RRAM space, then race GA / ES / ERES / PSO / G3PCX / CMA-ES at
//! equal budget against the known global minimum.
//!
//! ```bash
//! cargo run --release --example algorithm_shootout
//! ```

use imcopt::coordinator::{EvalBackend, JointProblem};
use imcopt::model::MemoryTech;
use imcopt::objective::Objective;
use imcopt::search::{
    CmaEs, EvolutionStrategy, Exhaustive, G3Pcx, GaConfig, GeneticAlgorithm, Optimizer,
    Pso, SearchBudget,
};
use imcopt::space::SearchSpace;
use imcopt::util::rng::Rng;
use imcopt::workloads::WorkloadSet;

fn main() -> anyhow::Result<()> {
    let space = SearchSpace::rram_reduced();
    let set = WorkloadSet::cnn4();
    let problem = JointProblem::with_backend(
        &space,
        &set,
        EvalBackend::native(MemoryTech::Rram),
        Objective::edap(),
    );

    let ex = Exhaustive::default();
    let scored = ex.score_all(&problem);
    let global = scored.iter().map(|(_, s)| *s).fold(f64::INFINITY, f64::min);
    let minima = ex.local_minima(&problem, &scored);
    println!(
        "reduced space: {} designs, global min EDAP {:.4}, {} local minima\n",
        scored.len(),
        global,
        minima.len()
    );

    let budget = SearchBudget { pop: 30, gens: 20 };
    let algos: Vec<Box<dyn Optimizer>> = vec![
        Box::new(GeneticAlgorithm::new(GaConfig::classic(budget))),
        Box::new(EvolutionStrategy::plain(budget)),
        Box::new(EvolutionStrategy::eres(budget)),
        Box::new(Pso::new(budget)),
        Box::new(G3Pcx::new(budget)),
        Box::new(CmaEs::new(budget)),
    ];
    println!(
        "{:<22} {:>9} {:>14} {:>10}",
        "algorithm", "hits", "mean best", "mean time"
    );
    for algo in &algos {
        let mut hits = 0;
        let mut bests = Vec::new();
        let mut wall = std::time::Duration::ZERO;
        let seeds = 5u64;
        for seed in 0..seeds {
            let p = JointProblem::with_backend(
                &space,
                &set,
                EvalBackend::native(MemoryTech::Rram),
                Objective::edap(),
            );
            let r = algo.run(&p, &mut Rng::seed_from(seed));
            if r.best_score <= global * (1.0 + 1e-6) {
                hits += 1;
            }
            bests.push(r.best_score);
            wall += r.wall;
        }
        println!(
            "{:<22} {:>7}/{} {:>14.4} {:>10}",
            algo.name(),
            hits,
            seeds,
            imcopt::util::stats::mean(&bests),
            imcopt::util::fmt_duration(wall / seeds as u32)
        );
    }
    println!(
        "\npaper shape: GA/ES/ERES reach the global minimum (GA fastest); \
         PSO & G3PCX stall in local minima; CMA-ES fails to converge"
    );
    Ok(())
}
