//! Quickstart: evaluate one hardware design, then run a small joint
//! co-optimization over the paper's 4-workload CNN set.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use imcopt::prelude::*;

fn main() -> anyhow::Result<()> {
    // --- 1. evaluate a hand-picked design on each workload ----------------
    // [rows, cols, macros/tile, tiles/router, groups, bits/cell,
    //  V, t_cycle ns, GLB KB, tech nm]
    let raw = [512.0, 256.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0];
    let eval = NativeEvaluator::new(MemoryTech::Rram);
    println!("hand-picked design on the CNN-4 workloads:");
    for w in &WorkloadSet::cnn4().workloads {
        let m = eval.evaluate(&raw, w);
        println!(
            "  {:<12} energy {:>8.4} mJ  latency {:>8.3} ms  area {:>6.1} mm²  \
             EDAP {:>9.3}  feasible {}",
            w.name,
            m.energy * 1e3,
            m.latency * 1e3,
            m.area,
            m.edap(),
            m.feasible
        );
    }

    // --- 2. joint co-optimization with the proposed 4-phase GA -------------
    let space = SearchSpace::rram();
    let workloads = WorkloadSet::cnn4();
    let problem = JointProblem::new(
        &space,
        &workloads,
        eval,
        Objective::edap(),
        Aggregation::Max,
    );
    let mut rng = Rng::seed_from(42);
    let result = FourPhaseGa::paper_defaults().run(&problem, &mut rng);
    println!(
        "\njoint search: best EDAP score {:.4} after {} evaluations",
        result.best_score, result.evals
    );
    println!("best design: {}", space.describe(&result.best));
    println!("top-5 designs:");
    for (d, s) in &result.top {
        println!("  {:>10.4}  {}", s, space.describe(d));
    }
    Ok(())
}
