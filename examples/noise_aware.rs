//! Accuracy-aware optimization under RRAM non-idealities (paper §IV-H):
//! run the joint search with `max(E)·max(L)·A / Π acc`, where the accuracy
//! estimates flow through the AOT noisy-crossbar Pallas kernel when
//! artifacts are present (analytical fallback otherwise).
//!
//! ```bash
//! make artifacts && cargo run --release --example noise_aware [-- --quick]
//! ```

use imcopt::accuracy;
use imcopt::coordinator::ExpContext;
use imcopt::experiments::common;
use imcopt::model::MemoryTech;
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::space::SearchSpace;
use imcopt::workloads::WorkloadSet;

fn main() -> anyhow::Result<()> {
    let args = imcopt::util::cli::Args::from_env();
    let ctx = ExpContext::from_args(&args);
    let set = WorkloadSet::cnn4();
    let space = SearchSpace::rram();

    let acc_obj = Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max);
    let p_acc = ctx.problem(&space, &set, MemoryTech::Rram, acc_obj);
    let r_acc = common::run_ga(&p_acc, common::four_phase(&ctx), ctx.seed);

    let edap_obj = Objective::edap();
    let p_edap = ctx.problem(&space, &set, MemoryTech::Rram, edap_obj);
    let r_edap = common::run_ga(&p_edap, common::four_phase(&ctx), ctx.seed);

    println!("accuracy-aware best: {}", space.describe(&r_acc.best));
    println!("EDAP-only best:      {}", space.describe(&r_edap.best));
    println!(
        "architectures differ in {}/10 parameters (paper: nearly identical — \
         cycle-to-cycle noise dominates IR-drop)\n",
        r_acc.best.hamming(&r_edap.best)
    );

    let ev = p_acc.evaluate_design(&r_acc.best);
    let accs = ev.accuracies.expect("accuracy objective populates estimates");
    println!(
        "{:<14} {:>10} {:>12} {:>12}",
        "workload", "EDAP", "est. acc %", "8-bit base %"
    );
    let edaps = common::per_workload_scores(&p_acc, &r_acc.best, &edap_obj);
    for (i, w) in set.workloads.iter().enumerate() {
        let (base, _) = accuracy::baseline(&w.name);
        println!(
            "{:<14} {:>10.4} {:>11.2} {:>11.2}",
            w.name,
            edaps[i],
            accs[i] * 100.0,
            base * 100.0
        );
    }
    Ok(())
}
