//! Hardware-workload-technology co-optimization (paper §IV-I): sweep the
//! CMOS node as a search variable on SRAM hardware, score with the
//! cost-aware objective `max(E)·max(L)·α·A`, and print the EDAP-vs-cost
//! Pareto front with its winning nodes.
//!
//! ```bash
//! cargo run --release --example tech_pareto [-- --quick]
//! ```

use imcopt::coordinator::ExpContext;
use imcopt::experiments::common;
use imcopt::model::{tech, MemoryTech};
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::search::Problem;
use imcopt::space::{idx, SearchSpace};
use imcopt::util::rng::Rng;
use imcopt::util::stats;
use imcopt::workloads::WorkloadSet;

fn main() -> anyhow::Result<()> {
    let args = imcopt::util::cli::Args::from_env();
    let ctx = ExpContext::from_args(&args);
    let set = WorkloadSet::cnn4();
    let space = SearchSpace::sram_tech();
    let objective = Objective::new(ObjectiveKind::EdapCost, Aggregation::Max);
    let problem = ctx.problem(&space, &set, MemoryTech::Sram, objective);

    // cost-aware joint search + a random sweep so every node shows up
    let r = common::run_ga(&problem, common::four_phase(&ctx), ctx.seed);
    let mut rng = Rng::seed_from(ctx.seed ^ 1);
    let n = if ctx.quick { 300 } else { 2000 };
    let sweep: Vec<_> = (0..n).map(|_| space.random(&mut rng)).collect();
    problem.score_batch(&sweep);

    let mut pts: Vec<(f64, f64, f64)> = Vec::new(); // (cost, edap, node)
    for d in sweep.iter().chain(r.top.iter().map(|(d, _)| d)) {
        let ev = problem.evaluate_design(d);
        if !ev.score.is_finite() {
            continue;
        }
        let raw = space.decode(d);
        let area = ev.metrics[0].area;
        let e = stats::max(&ev.metrics.iter().map(|m| m.energy * 1e3).collect::<Vec<_>>());
        let l = stats::max(&ev.metrics.iter().map(|m| m.latency * 1e3).collect::<Vec<_>>());
        pts.push((
            tech::fabrication_cost(raw[idx::TECH_NM], area),
            e * l * area,
            raw[idx::TECH_NM],
        ));
    }
    let front = stats::pareto_front_2d(
        &pts.iter().map(|p| (p.0, p.1)).collect::<Vec<_>>(),
    );
    println!("explored {} feasible designs; Pareto front:", pts.len());
    println!("{:>12} {:>12} {:>8}", "cost (norm)", "EDAP", "node");
    for &i in &front {
        println!("{:>12.1} {:>12.4} {:>6}nm", pts[i].0, pts[i].1, pts[i].2);
    }
    let advanced = front.iter().filter(|&&i| pts[i].2 <= 14.0).count();
    println!(
        "\n{advanced}/{} Pareto points are ≤14nm (paper: the front is dominated by 7–14nm, \
         knee around 10nm)",
        front.len()
    );
    println!("cost-aware search best: {}", space.describe(&r.best));
    Ok(())
}
