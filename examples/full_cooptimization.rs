//! END-TO-END DRIVER (DESIGN.md §5): the complete joint hardware-workload
//! co-optimization pipeline on a real workload set, through all three
//! layers — the L1 Pallas fitness kernel inside the L2 JAX graph, AOT
//! compiled to `artifacts/*.hlo.txt`, executed by the L3 Rust coordinator
//! via PJRT (falling back to the native evaluator if artifacts are
//! missing).
//!
//! Reproduces the paper's headline experiment at full paper budget
//! (P_H=1000, P_E=500, P_GA=40, G=10×4 phases): joint vs
//! largest-workload-only optimization on RRAM and SRAM, reporting the
//! per-workload EDAP reductions (paper: up to 76.2% on the 4-workload
//! set). The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_cooptimization
//! ```

use imcopt::coordinator::ExpContext;
use imcopt::experiments::common;
use imcopt::model::MemoryTech;
use imcopt::objective::Objective;
use imcopt::space::SearchSpace;
use imcopt::workloads::WorkloadSet;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let ctx = ExpContext::default(); // full paper budget, auto backend
    let set = WorkloadSet::cnn4();
    let objective = Objective::edap();
    let backend = if ctx.engine().is_some() { "pjrt" } else { "native" };
    println!("=== end-to-end joint co-optimization (backend: {backend}) ===\n");

    let mut overall_best_reduction = f64::NEG_INFINITY;
    for (mem, space) in [
        (MemoryTech::Rram, SearchSpace::rram()),
        (MemoryTech::Sram, SearchSpace::sram()),
    ] {
        println!(
            "--- {} ({} = {:.2e} design points) ---",
            mem.name(),
            space.variant,
            space.size() as f64
        );
        let problem = ctx.problem(&space, &set, mem, objective);

        let t0 = Instant::now();
        let joint = common::run_ga(&problem, common::four_phase(&ctx), ctx.seed);
        let joint_wall = t0.elapsed();

        // the §IV-A naive baseline: largest workload + conventional GA
        // (see EXPERIMENTS.md "Interpretation note")
        let t1 = Instant::now();
        let largest =
            common::naive_largest_search(&ctx, &space, &set, mem, objective, ctx.seed);
        let largest_wall = t1.elapsed();

        let joint_scores =
            common::per_workload_scores(&problem, &joint.best, &objective);
        let largest_scores =
            common::per_workload_scores(&problem, &largest.best, &objective);

        println!(
            "joint:   {} (score {:.4}, {} evals, {})",
            space.describe(&joint.best),
            joint.best_score,
            joint.evals,
            imcopt::util::fmt_duration(joint_wall)
        );
        println!(
            "largest: {} ({} evals, {})",
            space.describe(&largest.best),
            largest.evals,
            imcopt::util::fmt_duration(largest_wall)
        );
        println!(
            "{:<14} {:>14} {:>14} {:>12}",
            "workload", "largest-opt", "joint-opt", "reduction"
        );
        for (i, w) in set.workloads.iter().enumerate() {
            let red = common::reduction_pct(largest_scores[i], joint_scores[i]);
            overall_best_reduction = overall_best_reduction.max(red);
            println!(
                "{:<14} {:>14.4} {:>14.4} {:>11.1}%",
                w.name, largest_scores[i], joint_scores[i], red
            );
        }
        println!();
    }
    println!(
        "max per-workload EDAP reduction across both memories: {overall_best_reduction:.1}% \
         (paper: up to 76.2% on the 4-workload set)"
    );
    Ok(())
}
