#!/usr/bin/env bash
# CI gate, invoked by .github/workflows/ci.yml (and `make check`):
#
#   1. rustfmt + clippy (-D warnings) lint gates, plus `cargo doc
#      --no-deps` under RUSTDOCFLAGS=-D warnings (broken intra-doc links
#      fail the gate)
#   2. release build + full test suite (includes the kill/resume
#      bit-identity test, the golden determinism tests and the
#      docs/experiments.md catalog drift test; `imcopt list --markdown`
#      is additionally diffed against the checked-in catalog and `list
#      --json` validated against schemas/registry.schema.json)
#   3. cross-process golden check: bless quick-budget report goldens into
#      a scratch dir, then re-verify them from a second test process
#   4. bench smokes -> BENCH_eval.json + BENCH_model.json (evaluator) and
#      BENCH_pareto.json (non-dominated sort + hypervolume on >= 1k
#      points), validated against schemas/bench_{eval,model,pareto}
#      .schema.json (the model schema gates the compiled evaluator's
#      >= 3x speedup over the naive layer loop and its <= 1e-9 oracle
#      agreement)
#   5. registry smoke: `imcopt run --all --quick` must emit a well-formed
#      JSON artifact for every registered experiment (validated against
#      schemas/experiment_report.schema.json), and a `--resume` re-run
#      must replay everything without recomputing a single cell
#   6. orchestrator crash matrix: the same sweep at --workers 4 with a
#      deterministically killed worker (IMCOPT_FAULT) must complete via
#      restarts + lease stealing, produce artifacts byte-identical to the
#      single-process smoke, resume with zero recompute, and emit an
#      orchestrator_status.json conforming to its schema
#
# Set IMCOPT_FEATURES="--features pjrt" to run the same gate against the
# feature-gated PJRT path (vendored API stub; see vendor/xla-stub).
set -euo pipefail
cd "$(dirname "$0")"

FEATURES="${IMCOPT_FEATURES:-}"

echo "=== cargo fmt --check ==="
cargo fmt --all -- --check

echo "=== cargo clippy --all-targets $FEATURES -- -D warnings ==="
# shellcheck disable=SC2086
cargo clippy --all-targets $FEATURES -- -D warnings

echo "=== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) ==="
# broken intra-doc links, unclosed HTML-looking tags and bare URLs in the
# public docs fail the gate; doctest examples run under `cargo test` below
# shellcheck disable=SC2086
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps $FEATURES

echo "=== cargo build --release $FEATURES ==="
# shellcheck disable=SC2086
cargo build --release $FEATURES

echo "=== cargo test -q $FEATURES ==="
# shellcheck disable=SC2086
cargo test -q $FEATURES

echo "=== cross-process golden check ==="
GOLDEN_DIR="$(pwd)/target/ci-golden"
rm -rf "$GOLDEN_DIR"
# shellcheck disable=SC2086
IMCOPT_GOLDEN_DIR="$GOLDEN_DIR" IMCOPT_BLESS=1 \
    cargo test -q $FEATURES --test report_golden
# shellcheck disable=SC2086
IMCOPT_GOLDEN_DIR="$GOLDEN_DIR" \
    cargo test -q $FEATURES --test report_golden

echo "=== bench smoke (evaluator) ==="
# shellcheck disable=SC2086
IMCOPT_BENCH_QUICK=1 cargo bench $FEATURES --bench evaluator

if [ ! -f BENCH_eval.json ]; then
    echo "error: BENCH_eval.json was not produced" >&2
    exit 1
fi
if [ ! -f BENCH_model.json ]; then
    echo "error: BENCH_model.json was not produced" >&2
    exit 1
fi

echo "=== bench smoke (pareto primitives) ==="
# shellcheck disable=SC2086
IMCOPT_BENCH_QUICK=1 cargo bench $FEATURES --bench pareto

if [ ! -f BENCH_pareto.json ]; then
    echo "error: BENCH_pareto.json was not produced" >&2
    exit 1
fi

IMCOPT_BIN=./target/release/imcopt

echo "=== validate BENCH_eval.json against its schema ==="
"$IMCOPT_BIN" validate --bench BENCH_eval.json --schema schemas/bench_eval.schema.json

echo "=== validate BENCH_model.json (compiled model >= 3x, <= 1e-9 agreement) ==="
"$IMCOPT_BIN" validate --bench BENCH_model.json --schema schemas/bench_model.schema.json

echo "=== validate BENCH_pareto.json (>= 1k points, monotone hypervolume) ==="
"$IMCOPT_BIN" validate --bench BENCH_pareto.json --schema schemas/bench_pareto.schema.json

echo "=== experiment catalog: registry JSON schema + docs drift ==="
"$IMCOPT_BIN" list --json > target/registry.json
"$IMCOPT_BIN" validate --bench target/registry.json --schema schemas/registry.schema.json
# the checked-in catalog must match the registry byte for byte
# (regenerate with: imcopt list --markdown > docs/experiments.md)
"$IMCOPT_BIN" list --markdown | diff - docs/experiments.md

echo "=== registry smoke: imcopt run --all --quick ==="
SMOKE_OUT="$(pwd)/target/ci-smoke"
rm -rf "$SMOKE_OUT"
"$IMCOPT_BIN" run --all --quick --stable --seed 5 --out-dir "$SMOKE_OUT"

echo "=== validate experiment artifacts (all 16 required) ==="
"$IMCOPT_BIN" validate --out-dir "$SMOKE_OUT" --require-all

echo "=== resume smoke: a completed run replays without recomputation ==="
RESUME_LINE=$("$IMCOPT_BIN" run --all --quick --stable --seed 5 \
    --out-dir "$SMOKE_OUT" --resume | tail -n 1)
echo "$RESUME_LINE"
case "$RESUME_LINE" in
    *"executed=0"*"cells_computed=0"*) ;;
    *)
        echo "error: --resume re-ran work on a completed out-dir" >&2
        exit 1
        ;;
esac

echo "=== orchestrator crash matrix: --workers 4 with a killed worker ==="
ORCH_OUT="$(pwd)/target/ci-orch"
rm -rf "$ORCH_OUT"
# worker 1 is killed at its second claimed cell on every (re)start: one
# restart, then abandonment — the surviving workers steal its leases and
# the sweep must still complete
IMCOPT_FAULT="w1:exit@cell=2" IMCOPT_MAX_RESTARTS=1 IMCOPT_LEASE_MS=500 \
    "$IMCOPT_BIN" run --all --quick --stable --seed 5 \
    --out-dir "$ORCH_OUT" --workers 4

echo "=== validate orchestrated artifacts (all 16 required) ==="
"$IMCOPT_BIN" validate --out-dir "$ORCH_OUT" --require-all
"$IMCOPT_BIN" validate --bench "$ORCH_OUT/orchestrator_status.json" \
    --schema schemas/orchestrator_status.schema.json

echo "=== orchestrated out-dir resumes single-process with zero recompute ==="
ORCH_RESUME=$("$IMCOPT_BIN" run --all --quick --stable --seed 5 \
    --out-dir "$ORCH_OUT" --resume | tail -n 1)
echo "$ORCH_RESUME"
case "$ORCH_RESUME" in
    *"executed=0"*"cells_computed=0"*) ;;
    *)
        echo "error: resume after an orchestrated run re-ran work" >&2
        exit 1
        ;;
esac

echo "=== orchestrated artifacts are byte-identical to the single-process smoke ==="
diff -r --exclude=checkpoints --exclude=orchestrator_status.json \
    "$SMOKE_OUT" "$ORCH_OUT"

echo "=== ci.sh passed ==="
