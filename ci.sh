#!/usr/bin/env bash
# CI gate, invoked by .github/workflows/ci.yml (and `make check`).
#
# The gate is split into named stages, each timed and runnable on its
# own with `./ci.sh --stage <name>` (see README.md, "CI"):
#
#   lint     rustfmt + clippy (-D warnings) + `cargo doc --no-deps`
#            under RUSTDOCFLAGS=-D warnings (broken intra-doc links fail)
#   build    release build
#   test     full test suite (kill/resume bit-identity, golden
#            determinism, surrogate screening determinism, catalog drift)
#   golden   cross-process golden check: bless quick-budget report
#            goldens into a scratch dir, re-verify from a second process
#   bench    bench smokes -> BENCH_eval/model/pareto/surrogate/
#            robustness/telemetry.json, each validated against
#            schemas/bench_*.schema.json (the model schema gates the
#            compiled evaluator's >= 3x speedup; the surrogate schema
#            gates screen_speedup > 1 and a deterministic ranking; the
#            robustness bench asserts robust-scoring overhead below the
#            naive ensemble-size multiple; the telemetry bench gates
#            instrumentation overhead on the score_batch hot path at
#            <= 2% with bit-identical scores)
#   trend    bench-trend gate: every BENCH_*.json is compared against
#            its committed floor in bench_baselines/ via `imcopt
#            validate --trend` — a >15% throughput/speedup regression
#            fails. Re-bless intentional changes with
#            `cp BENCH_<x>.json bench_baselines/`.
#   catalog  registry JSON schema + docs/experiments.md drift
#   ingest   workload ingestion: the valid parser corpus round-trips
#            through `imcopt workloads --spec` and validates against
#            schemas/workload.schema.json, every malformed corpus file
#            is rejected, and `imcopt run population --quick` sweeps a
#            200-net synthetic family end-to-end with a zero-recompute
#            resume
#   smoke    `imcopt run --all --quick` emits a well-formed artifact for
#            every registered experiment (--require-all), and a
#            `--resume` re-run replays without recomputing a cell; plus a
#            robust-mode leg: `imcopt run robustness --robust cvar0.25`
#            with its own zero-recompute resume check
#   telemetry  a quick run writes schema-valid trace/counter snapshots
#            under <out-dir>/telemetry/, `imcopt trace` renders the
#            analyzer over them, and an IMCOPT_TELEMETRY=0 re-run leaves
#            every artifact byte-identical (telemetry is out-of-band)
#   orch     orchestrator crash matrix: the same sweep at --workers 4
#            with a deterministically killed worker must complete via
#            restarts + lease stealing, match the smoke byte for byte,
#            and emit a schema-conforming orchestrator_status.json
#
# Set IMCOPT_FEATURES="--features pjrt" to run the same gate against the
# feature-gated PJRT path (vendored API stub; see vendor/xla-stub).
# IMCOPT_TREND_TOLERANCE overrides the trend gate's percentage (default 15).
set -euo pipefail
cd "$(dirname "$0")"

FEATURES="${IMCOPT_FEATURES:-}"
IMCOPT_BIN=./target/release/imcopt
TREND_TOLERANCE="${IMCOPT_TREND_TOLERANCE:-15}"
ALL_STAGES=(lint build test golden bench trend catalog ingest smoke telemetry orch)

usage() {
    echo "usage: ./ci.sh [--stage <name>]"
    echo "stages: ${ALL_STAGES[*]} (default: all, in that order)"
}

SELECTED="all"
while [ $# -gt 0 ]; do
    case "$1" in
        --stage)
            [ $# -ge 2 ] || { echo "error: --stage needs a name" >&2; usage >&2; exit 2; }
            SELECTED="$2"
            shift 2
            ;;
        -h|--help)
            usage
            exit 0
            ;;
        *)
            echo "error: unknown argument '$1'" >&2
            usage >&2
            exit 2
            ;;
    esac
done

# Stages that drive the release binary build it when missing, so
# `./ci.sh --stage trend` works from a clean checkout.
ensure_bin() {
    if [ ! -x "$IMCOPT_BIN" ]; then
        echo "--- $IMCOPT_BIN missing; building ---"
        # shellcheck disable=SC2086
        cargo build --release $FEATURES
    fi
}

stage_lint() {
    echo "=== cargo fmt --check ==="
    cargo fmt --all -- --check

    echo "=== cargo clippy --all-targets $FEATURES -- -D warnings ==="
    # shellcheck disable=SC2086
    cargo clippy --all-targets $FEATURES -- -D warnings

    echo "=== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) ==="
    # broken intra-doc links, unclosed HTML-looking tags and bare URLs in
    # the public docs fail the gate; doctest examples run under `cargo
    # test` in the test stage
    # shellcheck disable=SC2086
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps $FEATURES
}

stage_build() {
    echo "=== cargo build --release $FEATURES ==="
    # shellcheck disable=SC2086
    cargo build --release $FEATURES
}

stage_test() {
    echo "=== cargo test -q $FEATURES ==="
    # shellcheck disable=SC2086
    cargo test -q $FEATURES
}

stage_golden() {
    echo "=== cross-process golden check ==="
    GOLDEN_DIR="$(pwd)/target/ci-golden"
    rm -rf "$GOLDEN_DIR"
    # shellcheck disable=SC2086
    IMCOPT_GOLDEN_DIR="$GOLDEN_DIR" IMCOPT_BLESS=1 \
        cargo test -q $FEATURES --test report_golden
    # shellcheck disable=SC2086
    IMCOPT_GOLDEN_DIR="$GOLDEN_DIR" \
        cargo test -q $FEATURES --test report_golden
}

stage_bench() {
    ensure_bin
    for b in evaluator pareto surrogate robustness telemetry; do
        echo "=== bench smoke ($b) ==="
        # shellcheck disable=SC2086
        IMCOPT_BENCH_QUICK=1 cargo bench $FEATURES --bench "$b"
    done
    for f in BENCH_eval BENCH_model BENCH_pareto BENCH_surrogate BENCH_robustness \
             BENCH_telemetry; do
        if [ ! -f "$f.json" ]; then
            echo "error: $f.json was not produced" >&2
            exit 1
        fi
    done

    echo "=== validate BENCH_eval.json against its schema ==="
    "$IMCOPT_BIN" validate --bench BENCH_eval.json --schema schemas/bench_eval.schema.json

    echo "=== validate BENCH_model.json (compiled model >= 3x, <= 1e-9 agreement) ==="
    "$IMCOPT_BIN" validate --bench BENCH_model.json --schema schemas/bench_model.schema.json

    echo "=== validate BENCH_pareto.json (>= 1k points, monotone hypervolume) ==="
    "$IMCOPT_BIN" validate --bench BENCH_pareto.json --schema schemas/bench_pareto.schema.json

    echo "=== validate BENCH_surrogate.json (screen_speedup > 1, deterministic ranking) ==="
    "$IMCOPT_BIN" validate --bench BENCH_surrogate.json --schema schemas/bench_surrogate.schema.json

    echo "=== validate BENCH_robustness.json (overhead below ensemble size, deterministic) ==="
    "$IMCOPT_BIN" validate --bench BENCH_robustness.json --schema schemas/bench_robustness.schema.json

    echo "=== validate BENCH_telemetry.json (<= 2% score_batch overhead, identical scores) ==="
    "$IMCOPT_BIN" validate --bench BENCH_telemetry.json --schema schemas/bench_telemetry.schema.json
}

stage_trend() {
    ensure_bin
    for b in eval model pareto surrogate robustness telemetry; do
        if [ ! -f "BENCH_$b.json" ]; then
            echo "error: BENCH_$b.json missing — run './ci.sh --stage bench' first" >&2
            exit 1
        fi
        echo "=== bench trend gate: BENCH_$b.json vs bench_baselines/ (>${TREND_TOLERANCE}% fails) ==="
        "$IMCOPT_BIN" validate --trend "BENCH_$b.json" \
            --baseline "bench_baselines/BENCH_$b.json" --tolerance "$TREND_TOLERANCE"
    done
}

stage_catalog() {
    ensure_bin
    echo "=== experiment catalog: registry JSON schema + docs drift ==="
    "$IMCOPT_BIN" list --json > target/registry.json
    "$IMCOPT_BIN" validate --bench target/registry.json --schema schemas/registry.schema.json
    # the checked-in catalog must match the registry byte for byte
    # (regenerate with: imcopt list --markdown > docs/experiments.md)
    "$IMCOPT_BIN" list --markdown | diff - docs/experiments.md
}

stage_ingest() {
    ensure_bin
    echo "=== ingest: valid corpus parses and validates against the schema ==="
    for f in rust/tests/ingest/valid/*.json; do
        "$IMCOPT_BIN" validate --bench "$f" --schema schemas/workload.schema.json
        "$IMCOPT_BIN" workloads --spec "$f:rram" > /dev/null
        echo "  ok: $f"
    done

    echo "=== ingest: malformed corpus is rejected (typed errors, nonzero exit) ==="
    for f in rust/tests/ingest/malformed/*.json; do
        if "$IMCOPT_BIN" workloads --spec "$f:rram" > /dev/null 2>&1; then
            echo "error: malformed corpus file $f was accepted" >&2
            exit 1
        fi
        echo "  rejected: $f"
    done

    echo "=== ingest: synthetic family resolves deterministically ==="
    "$IMCOPT_BIN" workloads --spec synth:mixed:20:7:rram > target/ci-synth-a.txt
    "$IMCOPT_BIN" workloads --spec synth:mixed:20:7:rram > target/ci-synth-b.txt
    diff target/ci-synth-a.txt target/ci-synth-b.txt

    echo "=== ingest: population smoke over a 200-net synthetic family ==="
    POP_OUT="$(pwd)/target/ci-population"
    rm -rf "$POP_OUT"
    "$IMCOPT_BIN" run population --quick --stable --seed 5 --out-dir "$POP_OUT"
    "$IMCOPT_BIN" validate --out-dir "$POP_OUT"

    echo "=== ingest: population resume replays with zero recompute ==="
    POP_RESUME=$("$IMCOPT_BIN" run population --quick --stable --seed 5 \
        --out-dir "$POP_OUT" --resume | tail -n 1)
    echo "$POP_RESUME"
    case "$POP_RESUME" in
        *"executed=0"*"cells_computed=0"*) ;;
        *)
            echo "error: population --resume re-ran work on a completed out-dir" >&2
            exit 1
            ;;
    esac
}

stage_smoke() {
    ensure_bin
    echo "=== registry smoke: imcopt run --all --quick ==="
    SMOKE_OUT="$(pwd)/target/ci-smoke"
    rm -rf "$SMOKE_OUT"
    "$IMCOPT_BIN" run --all --quick --stable --seed 5 --out-dir "$SMOKE_OUT"

    echo "=== validate experiment artifacts (all 19 required) ==="
    "$IMCOPT_BIN" validate --out-dir "$SMOKE_OUT" --require-all

    echo "=== resume smoke: a completed run replays without recomputation ==="
    RESUME_LINE=$("$IMCOPT_BIN" run --all --quick --stable --seed 5 \
        --out-dir "$SMOKE_OUT" --resume | tail -n 1)
    echo "$RESUME_LINE"
    case "$RESUME_LINE" in
        *"executed=0"*"cells_computed=0"*) ;;
        *)
            echo "error: --resume re-ran work on a completed out-dir" >&2
            exit 1
            ;;
    esac

    echo "=== robust-mode smoke: imcopt run robustness --robust cvar0.25 ==="
    ROBUST_OUT="$(pwd)/target/ci-robust"
    rm -rf "$ROBUST_OUT"
    "$IMCOPT_BIN" run robustness --quick --stable --seed 5 \
        --robust cvar0.25 --out-dir "$ROBUST_OUT"
    "$IMCOPT_BIN" validate --out-dir "$ROBUST_OUT"

    echo "=== robust-mode resume replays with zero recompute ==="
    ROBUST_RESUME=$("$IMCOPT_BIN" run robustness --quick --stable --seed 5 \
        --robust cvar0.25 --out-dir "$ROBUST_OUT" --resume | tail -n 1)
    echo "$ROBUST_RESUME"
    case "$ROBUST_RESUME" in
        *"executed=0"*"cells_computed=0"*) ;;
        *)
            echo "error: robust-mode --resume re-ran work on a completed out-dir" >&2
            exit 1
            ;;
    esac
}

stage_telemetry() {
    ensure_bin
    echo "=== telemetry: a quick run leaves an out-of-band trace ==="
    TELEM_OUT="$(pwd)/target/ci-telemetry"
    rm -rf "$TELEM_OUT"
    "$IMCOPT_BIN" run fig3 table3 --quick --stable --seed 5 --out-dir "$TELEM_OUT"
    for f in telemetry/trace.jsonl telemetry/counters.json; do
        if [ ! -f "$TELEM_OUT/$f" ]; then
            echo "error: $f was not produced" >&2
            exit 1
        fi
    done
    "$IMCOPT_BIN" validate --bench "$TELEM_OUT/telemetry/counters.json" \
        --schema schemas/telemetry_counters.schema.json

    echo "=== telemetry: imcopt trace renders the analyzer ==="
    # also schema-validates every trace event and counter snapshot
    "$IMCOPT_BIN" trace "$TELEM_OUT"

    echo "=== telemetry: IMCOPT_TELEMETRY=0 leaves artifacts byte-identical ==="
    TELEM_OFF="$(pwd)/target/ci-telemetry-off"
    rm -rf "$TELEM_OFF"
    IMCOPT_TELEMETRY=0 "$IMCOPT_BIN" run fig3 table3 --quick --stable --seed 5 \
        --out-dir "$TELEM_OFF"
    if [ -e "$TELEM_OFF/telemetry" ]; then
        echo "error: IMCOPT_TELEMETRY=0 still wrote a telemetry directory" >&2
        exit 1
    fi
    diff -r --exclude=checkpoints --exclude=telemetry "$TELEM_OUT" "$TELEM_OFF"
}

stage_orch() {
    ensure_bin
    echo "=== orchestrator crash matrix: --workers 4 with a killed worker ==="
    ORCH_OUT="$(pwd)/target/ci-orch"
    rm -rf "$ORCH_OUT"
    # worker 1 is killed at its second claimed cell on every (re)start:
    # one restart, then abandonment — the surviving workers steal its
    # leases and the sweep must still complete
    IMCOPT_FAULT="w1:exit@cell=2" IMCOPT_MAX_RESTARTS=1 IMCOPT_LEASE_MS=500 \
        "$IMCOPT_BIN" run --all --quick --stable --seed 5 \
        --out-dir "$ORCH_OUT" --workers 4

    echo "=== validate orchestrated artifacts (all 19 required) ==="
    "$IMCOPT_BIN" validate --out-dir "$ORCH_OUT" --require-all
    "$IMCOPT_BIN" validate --bench "$ORCH_OUT/orchestrator_status.json" \
        --schema schemas/orchestrator_status.schema.json

    echo "=== orchestrated out-dir resumes single-process with zero recompute ==="
    ORCH_RESUME=$("$IMCOPT_BIN" run --all --quick --stable --seed 5 \
        --out-dir "$ORCH_OUT" --resume | tail -n 1)
    echo "$ORCH_RESUME"
    case "$ORCH_RESUME" in
        *"executed=0"*"cells_computed=0"*) ;;
        *)
            echo "error: resume after an orchestrated run re-ran work" >&2
            exit 1
            ;;
    esac

    if [ -d "$(pwd)/target/ci-smoke" ]; then
        echo "=== orchestrated artifacts are byte-identical to the single-process smoke ==="
        # telemetry/ is out-of-band and legitimately differs between
        # worker topologies (per-worker trace files)
        diff -r --exclude=checkpoints --exclude=orchestrator_status.json \
            --exclude=telemetry "$(pwd)/target/ci-smoke" "$ORCH_OUT"
    else
        echo "--- skipping smoke-vs-orch diff (no target/ci-smoke; run --stage smoke first) ---"
    fi
}

STAGE_TIMINGS=()
run_stage() {
    local name="$1"
    echo ""
    echo "######## stage: $name ########"
    local t0=$SECONDS
    "stage_$name"
    local dt=$((SECONDS - t0))
    STAGE_TIMINGS+=("$(printf '%-8s %5ss' "$name" "$dt")")
    echo "-------- stage $name: ${dt}s --------"
}

case "$SELECTED" in
    all)
        for s in "${ALL_STAGES[@]}"; do
            run_stage "$s"
        done
        ;;
    lint|build|test|golden|bench|trend|catalog|ingest|smoke|telemetry|orch)
        run_stage "$SELECTED"
        ;;
    *)
        echo "error: unknown stage '$SELECTED'" >&2
        usage >&2
        exit 2
        ;;
esac

echo ""
echo "=== stage wall-clock ==="
for line in "${STAGE_TIMINGS[@]}"; do
    echo "  $line"
done
echo "=== ci.sh passed (stages: $SELECTED) ==="
