#!/usr/bin/env bash
# CI gate: release build, full test suite, and a smoke run of the
# evaluator throughput bench. The bench writes BENCH_eval.json
# (sequential vs parallel score_batch designs/sec + speedup) for the
# perf trajectory; the smoke run uses the reduced IMCOPT_BENCH_QUICK
# budget so the whole gate stays fast.
set -euo pipefail
cd "$(dirname "$0")"

echo "=== cargo build --release ==="
cargo build --release

echo "=== cargo test -q ==="
cargo test -q

echo "=== bench smoke (evaluator) ==="
IMCOPT_BENCH_QUICK=1 cargo bench --bench evaluator

if [ -f BENCH_eval.json ]; then
    echo "=== BENCH_eval.json ==="
    cat BENCH_eval.json
else
    echo "warning: BENCH_eval.json was not produced" >&2
    exit 1
fi
