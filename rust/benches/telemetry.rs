//! Telemetry overhead on the `score_batch` hot path: the counters and
//! timing spans bumped inside `JointProblem::score_batch` /
//! `evaluate_misses` must be free relative to the work they observe.
//!
//! Two paths are measured with telemetry forced on and off
//! (`telemetry::set_enabled`):
//!
//! * the **exact path** — a fresh problem per iteration so every design
//!   is a cache miss and the analytical evaluator dominates. This is the
//!   guarded number: telemetry may cost at most 2% here.
//! * the **hit path** — re-scoring an already-cached batch, the worst
//!   case for counter overhead (two relaxed atomics per memo lookup).
//!   Reported for visibility, not gated: the absolute cost is a few
//!   nanoseconds per lookup and the ratio is noise-dominated.
//!
//! Writes `BENCH_telemetry.json`, validated in ci.sh against
//! `schemas/bench_telemetry.schema.json` and gated against the committed
//! `bench_baselines/BENCH_telemetry.json` by the trend leg. The bench
//! also asserts the determinism contract at its core: scores are
//! bit-identical with telemetry on and off.

use imcopt::coordinator::{EvalBackend, JointProblem};
use imcopt::model::MemoryTech;
use imcopt::objective::Objective;
use imcopt::search::Problem;
use imcopt::space::{Design, SearchSpace};
use imcopt::telemetry;
use imcopt::util::bench::Bench;
use imcopt::util::json::Json;
use imcopt::util::rng::Rng;
use imcopt::workloads::WorkloadSet;

fn main() {
    let bench = Bench::new("telemetry");
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let fresh_problem = || {
        JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        )
    };
    let mut rng = Rng::seed_from(1);
    let problem = fresh_problem();
    let pool: Vec<Design> = (0..256).map(|_| problem.random_candidate(&mut rng)).collect();

    // determinism guard first: identical scores with telemetry on and off
    telemetry::set_enabled(true);
    let scores_on = fresh_problem().score_batch(&pool);
    telemetry::set_enabled(false);
    let scores_off = fresh_problem().score_batch(&pool);
    let scores_identical = scores_on
        .iter()
        .zip(&scores_off)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(scores_identical, "telemetry perturbed score_batch results");

    // ---- exact path (all cache misses; the guarded number) ----------------
    telemetry::set_enabled(false);
    let m_off = bench.run("exact/score_batch-256/telemetry-off", pool.len(), || {
        let p = fresh_problem();
        std::hint::black_box(p.score_batch(&pool));
    });
    telemetry::set_enabled(true);
    let m_on = bench.run("exact/score_batch-256/telemetry-on", pool.len(), || {
        let p = fresh_problem();
        std::hint::black_box(p.score_batch(&pool));
    });

    // ---- hit path (all memo hits; worst relative counter cost) ------------
    let warm = fresh_problem();
    warm.score_batch(&pool);
    telemetry::set_enabled(false);
    let h_off = bench.run("hits/score_batch-256/telemetry-off", pool.len(), || {
        std::hint::black_box(warm.score_batch(&pool));
    });
    telemetry::set_enabled(true);
    let h_on = bench.run("hits/score_batch-256/telemetry-on", pool.len(), || {
        std::hint::black_box(warm.score_batch(&pool));
    });

    // medians resist scheduler spikes better than means for the gate
    let off = m_off.median.as_secs_f64();
    let on = m_on.median.as_secs_f64();
    let overhead_pct = (on / off - 1.0) * 100.0;
    let hit_overhead_pct = (h_on.median.as_secs_f64() / h_off.median.as_secs_f64() - 1.0) * 100.0;
    println!(
        "telemetry overhead: exact path {overhead_pct:+.2}% (gate <= 2%), \
         hit path {hit_overhead_pct:+.2}% (informational)"
    );
    assert!(
        overhead_pct <= 2.0,
        "telemetry costs {overhead_pct:.2}% on the exact score_batch path \
         (budget 2%)"
    );

    let on_evals_per_sec = pool.len() as f64 / m_on.mean.as_secs_f64();
    let off_evals_per_sec = pool.len() as f64 / m_off.mean.as_secs_f64();
    let hit_lookups_per_sec = pool.len() as f64 / h_on.mean.as_secs_f64();
    let report = Json::obj(vec![
        ("bench", Json::Str("telemetry_overhead".into())),
        ("space", Json::Str("rram-32nm".into())),
        ("workload_set", Json::Str("cnn4".into())),
        ("batch", Json::Num(pool.len() as f64)),
        ("telemetry_on_evals_per_sec", Json::Num(on_evals_per_sec)),
        ("telemetry_off_evals_per_sec", Json::Num(off_evals_per_sec)),
        ("hit_path_lookups_per_sec", Json::Num(hit_lookups_per_sec)),
        ("overhead_pct", Json::Num(overhead_pct)),
        ("hit_overhead_pct", Json::Num(hit_overhead_pct)),
        ("overhead_within_budget", Json::Bool(overhead_pct <= 2.0)),
        ("scores_identical", Json::Bool(scores_identical)),
    ]);
    let out = "BENCH_telemetry.json";
    match std::fs::write(out, report.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
