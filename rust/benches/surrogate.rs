//! Surrogate pre-screening throughput: the ridge pipeline behind the GA
//! and NSGA-II two-stage generation loops (`--screen-frac`, see
//! `docs/search.md`) — feature extraction, online ridge fits, per-design
//! prediction, and the full rank-and-partition screening pass — against
//! the exact evaluator it short-circuits.
//!
//! Writes `BENCH_surrogate.json`, validated in ci.sh against
//! `schemas/bench_surrogate.schema.json` and gated against the committed
//! `bench_baselines/BENCH_surrogate.json` by the trend leg. The headline
//! is `screen_speedup`: how many surrogate predictions fit in one exact
//! joint evaluation — the factor that makes ranking a `1/frac`-times
//! larger offspring pool essentially free.

use imcopt::coordinator::{EvalBackend, JointProblem};
use imcopt::model::MemoryTech;
use imcopt::objective::Objective;
use imcopt::search::surrogate::{features, RidgeModel, ScreenState, N_FEATURES};
use imcopt::search::Problem;
use imcopt::space::{Design, SearchSpace};
use imcopt::util::bench::Bench;
use imcopt::util::json::Json;
use imcopt::util::rng::Rng;
use imcopt::workloads::WorkloadSet;

fn main() {
    let bench = Bench::new("surrogate");
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let problem = JointProblem::with_backend(
        &space,
        &set,
        EvalBackend::native(MemoryTech::Rram),
        Objective::edap(),
    );
    let mut rng = Rng::seed_from(1);
    let n_train = 256usize;
    let train: Vec<Design> = (0..n_train).map(|_| problem.random_candidate(&mut rng)).collect();
    let scores = problem.score_batch(&train);
    let pool: Vec<Design> = (0..256).map(|_| problem.random_candidate(&mut rng)).collect();

    // ---- the exact path screening avoids ----------------------------------
    // Fresh problem per iteration so every design is a cache miss (the GA
    // only ever evaluates designs it has not seen).
    let m_eval = bench.run("exact/score_batch-cnn4/256", pool.len(), || {
        let p = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        );
        std::hint::black_box(p.score_batch(&pool));
    });

    // ---- feature extraction -------------------------------------------------
    let raws: Vec<[f64; 10]> = train.iter().map(|d| space.decode(d)).collect();
    let m_feat = bench.run("features/256", raws.len(), || {
        for raw in &raws {
            std::hint::black_box(features(raw));
        }
    });

    // ---- online ridge fit ----------------------------------------------------
    // The exact training pairs ScreenState accumulates: finite positive
    // scores, log-domain target.
    let mut xs: Vec<[f64; N_FEATURES]> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    for (d, &s) in train.iter().zip(&scores) {
        if s.is_finite() && s > 0.0 {
            xs.push(features(&space.decode(d)));
            ys.push(s.ln());
        }
    }
    assert!(
        xs.len() > N_FEATURES + 1,
        "too few feasible training designs ({}) for a ridge fit",
        xs.len()
    );
    let m_fit = bench.run(&format!("ridge_fit/{}", xs.len()), 1, || {
        std::hint::black_box(RidgeModel::fit(&xs, &ys, 1e-3));
    });
    let model = RidgeModel::fit(&xs, &ys, 1e-3).expect("ridge fit degenerated");
    let r2 = model.r2(&xs, &ys);
    println!("training-set r2 on {} feasible designs: {r2:.3}", xs.len());

    // ---- per-design prediction ----------------------------------------------
    let pool_feats: Vec<[f64; N_FEATURES]> =
        pool.iter().map(|d| features(&space.decode(d))).collect();
    let m_pred = bench.run("predict/256", pool_feats.len(), || {
        for x in &pool_feats {
            std::hint::black_box(model.predict(x));
        }
    });

    // ---- full screening pass (decode + features + predict + rank) ----------
    let mut screen = ScreenState::new(0.25).expect("0.25 enables screening");
    screen.observe(&space, &train, &scores);
    let keep = 64usize;
    let m_rank = bench.run(&format!("screen_select/256->{keep}"), pool.len(), || {
        let mut s = screen.clone();
        std::hint::black_box(s.select(&space, pool.clone(), keep));
    });

    // determinism guard: ranking is a pure function of (training set, pool)
    let sel_a = screen.clone().select(&space, pool.clone(), keep);
    let sel_b = screen.clone().select(&space, pool.clone(), keep);
    let ranking_deterministic = sel_a == sel_b && sel_a.len() == keep;
    assert!(ranking_deterministic, "screening rank diverged between runs");

    let evals_per_sec = pool.len() as f64 / m_eval.mean.as_secs_f64();
    let features_per_sec = raws.len() as f64 / m_feat.mean.as_secs_f64();
    let fits_per_sec = 1.0 / m_fit.mean.as_secs_f64();
    let predicts_per_sec = pool_feats.len() as f64 / m_pred.mean.as_secs_f64();
    let rank_per_sec = pool.len() as f64 / m_rank.mean.as_secs_f64();
    let screen_speedup = predicts_per_sec / evals_per_sec;
    assert!(
        screen_speedup.is_finite() && screen_speedup > 1.0,
        "surrogate prediction must beat exact evaluation, got {screen_speedup:.2}x"
    );
    println!(
        "surrogate screen: {predicts_per_sec:.0} predictions/s vs \
         {evals_per_sec:.0} exact evals/s = {screen_speedup:.0}x; full \
         rank-and-partition {rank_per_sec:.0} candidates/s"
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("surrogate_screen".into())),
        ("space", Json::Str("rram-32nm".into())),
        ("workload_set", Json::Str("cnn4".into())),
        ("train_designs", Json::Num(xs.len() as f64)),
        ("features_per_sec", Json::Num(features_per_sec)),
        ("fits_per_sec", Json::Num(fits_per_sec)),
        ("predicts_per_sec", Json::Num(predicts_per_sec)),
        ("rank_per_sec", Json::Num(rank_per_sec)),
        ("screen_speedup", Json::Num(screen_speedup)),
        ("surrogate_r2", Json::Num(r2)),
        ("ranking_deterministic", Json::Bool(ranking_deterministic)),
    ]);
    let out = "BENCH_surrogate.json";
    match std::fs::write(out, report.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
