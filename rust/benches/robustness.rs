//! Device-variation robustness overhead: what a `--robust` objective
//! costs relative to the nominal accuracy-aware objective it wraps. A
//! robust score aggregates one accuracy evaluation per ensemble member
//! (3 corners + K jittered draws per corner), but the per-layer eps memo
//! is shared across designs, so the steady-state overhead is far below
//! the naive `ensemble.len()`×.
//!
//! Writes `BENCH_robustness.json`, validated in ci.sh against
//! `schemas/bench_robustness.schema.json` and gated against the committed
//! `bench_baselines/BENCH_robustness.json` by the trend leg. The headline
//! is `robust_overhead`: robust-batch time over nominal-batch time for
//! the same fresh-cache workload.

use imcopt::accuracy::{analytical_eps, NoiseSpec};
use imcopt::coordinator::{EvalBackend, JointProblem};
use imcopt::model::MemoryTech;
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::robustness::{Corner, RobustConfig};
use imcopt::search::Problem;
use imcopt::space::{Design, SearchSpace};
use imcopt::util::bench::Bench;
use imcopt::util::json::Json;
use imcopt::util::rng::Rng;
use imcopt::workloads::WorkloadSet;

fn acc_problem<'a>(
    space: &'a SearchSpace,
    set: &'a WorkloadSet,
    robust: Option<RobustConfig>,
) -> JointProblem<'a> {
    JointProblem::with_backend(
        space,
        set,
        EvalBackend::native(MemoryTech::Rram),
        Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max),
    )
    .with_robust(robust)
}

fn main() {
    let bench = Bench::new("robustness");
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let rc = RobustConfig::from_flag("worst", 1, 8).expect("valid mode");
    let ensemble_len = rc.ensemble.len();
    let mut rng = Rng::seed_from(1);
    let pool: Vec<Design> = (0..128).map(|_| space.random(&mut rng)).collect();

    // ---- perturbed eps pipeline ------------------------------------------
    // NoiseSpec -> corner perturbation -> analytical per-layer eps: the
    // inner kernel each extra ensemble member pays per distinct geometry.
    let raws: Vec<[f64; 10]> = pool.iter().map(|d| space.decode(d)).collect();
    let high = Corner::High.perturbation();
    let m_eps = bench.run("perturb_eps/128", raws.len(), || {
        for raw in &raws {
            let spec = high.apply(&NoiseSpec::from_design(raw, MemoryTech::Rram));
            std::hint::black_box(analytical_eps(&spec, 1));
        }
    });

    // ---- nominal vs robust scoring ---------------------------------------
    // Fresh problem per iteration so every design is a cache miss — the
    // GA only ever scores designs it has not seen.
    let m_nom = bench.run("nominal/score_batch-cnn4/128", pool.len(), || {
        let p = acc_problem(&space, &set, None);
        std::hint::black_box(p.score_batch(&pool));
    });
    let m_rob = bench.run(
        &format!("robust-worst-n{ensemble_len}/score_batch-cnn4/128"),
        pool.len(),
        || {
            let p = acc_problem(&space, &set, Some(rc.clone()));
            std::hint::black_box(p.score_batch(&pool));
        },
    );

    // determinism guard: two fresh robust problems produce bit-identical
    // batches (the contract rust/tests/robustness_determinism.rs pins
    // across thread counts)
    let s_a = acc_problem(&space, &set, Some(rc.clone())).score_batch(&pool);
    let s_b = acc_problem(&space, &set, Some(rc.clone())).score_batch(&pool);
    let deterministic = s_a
        .iter()
        .zip(&s_b)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(deterministic, "robust score batches diverged between runs");

    let perturb_eps_per_sec = raws.len() as f64 / m_eps.mean.as_secs_f64();
    let nominal_score_per_sec = pool.len() as f64 / m_nom.mean.as_secs_f64();
    let robust_score_per_sec = pool.len() as f64 / m_rob.mean.as_secs_f64();
    let robust_overhead = m_rob.mean.as_secs_f64() / m_nom.mean.as_secs_f64();
    assert!(
        robust_overhead.is_finite() && robust_overhead < ensemble_len as f64,
        "eps memo sharing must keep robust overhead below the naive \
         {ensemble_len}x, got {robust_overhead:.2}x"
    );
    println!(
        "robust objective: {robust_score_per_sec:.0} designs/s vs \
         {nominal_score_per_sec:.0} nominal = {robust_overhead:.2}x for a \
         {ensemble_len}-member ensemble"
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("robustness".into())),
        ("space", Json::Str("rram-32nm".into())),
        ("workload_set", Json::Str("cnn4".into())),
        ("ensemble_members", Json::Num(ensemble_len as f64)),
        ("perturb_eps_per_sec", Json::Num(perturb_eps_per_sec)),
        ("nominal_score_per_sec", Json::Num(nominal_score_per_sec)),
        ("robust_score_per_sec", Json::Num(robust_score_per_sec)),
        ("robust_overhead", Json::Num(robust_overhead)),
        ("deterministic", Json::Bool(deterministic)),
    ]);
    let out = "BENCH_robustness.json";
    match std::fs::write(out, report.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
