//! Pareto-subsystem throughput: non-dominated sorting and hypervolume on
//! a 1k-point objective cloud — the primitives behind the `pareto`
//! experiment's NSGA-II ranking and front-quality reporting.
//!
//! Writes `BENCH_pareto.json`, validated in ci.sh against
//! `schemas/bench_pareto.schema.json` (which pins the workload size at
//! ≥ 1000 points and the hypervolume monotonicity sanity check).

use imcopt::pareto::{indicators, sort};
use imcopt::util::bench::Bench;
use imcopt::util::json::Json;
use imcopt::util::rng::Rng;

fn main() {
    let bench = Bench::new("pareto");
    let mut rng = Rng::seed_from(1);
    let n = 1024usize;
    let dims = 3usize;
    let points: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..dims).map(|_| rng.f64()).collect())
        .collect();

    // full NSGA-II-style ranking of the cloud
    let m_sort = bench.run(&format!("nds/{n}x{dims}"), n, || {
        std::hint::black_box(sort::non_dominated_sort(&points));
    });
    let fronts = sort::non_dominated_sort(&points);
    let front: Vec<usize> = fronts[0].clone();
    let m_crowd = bench.run(&format!("crowding/front{}", front.len()), front.len(), || {
        std::hint::black_box(sort::crowding_distance(&points, &front));
    });

    // hypervolume of the full cloud (reduces to its non-dominated front
    // internally; exact WFG path at 3 objectives)
    let reference = vec![1.1f64; dims];
    let m_hv = bench.run(&format!("hypervolume/{n}x{dims}"), 1, || {
        std::hint::black_box(indicators::hypervolume(&points, &reference));
    });
    let hv = indicators::hypervolume(&points, &reference);
    assert!(hv > 0.0 && hv.is_finite(), "degenerate hypervolume {hv}");

    // sanity: adding a dominating point cannot shrink the hypervolume
    let dominating: Vec<f64> = points[front[0]].iter().map(|&x| x / 2.0).collect();
    let mut more = points.clone();
    more.push(dominating);
    let monotone = indicators::hypervolume(&more, &reference) >= hv;
    assert!(monotone, "hypervolume shrank under a dominating point");

    let sorts_per_sec = 1.0 / m_sort.mean.as_secs_f64();
    let crowds_per_sec = 1.0 / m_crowd.mean.as_secs_f64();
    let hv_per_sec = 1.0 / m_hv.mean.as_secs_f64();
    println!(
        "pareto primitives on {n}x{dims}: {sorts_per_sec:.1} sorts/s, \
         {crowds_per_sec:.1} crowdings/s, {hv_per_sec:.1} hypervolumes/s \
         (front {} points, hv {hv:.4})",
        front.len()
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("pareto_front".into())),
        ("points", Json::Num(n as f64)),
        ("dims", Json::Num(dims as f64)),
        ("front_size", Json::Num(front.len() as f64)),
        ("sorts_per_sec", Json::Num(sorts_per_sec)),
        ("crowdings_per_sec", Json::Num(crowds_per_sec)),
        ("hypervolumes_per_sec", Json::Num(hv_per_sec)),
        ("hypervolume", Json::Num(hv)),
        ("monotone", Json::Bool(monotone)),
    ]);
    let out = "BENCH_pareto.json";
    match std::fs::write(out, report.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
