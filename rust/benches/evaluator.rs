//! Hot-path throughput: native scalar evaluator vs the AOT PJRT batched
//! fitness artifact (the production search path), per memory technology
//! and workload size. This is the §Perf L3-vs-L2/L1 headline bench.

use imcopt::model::{MemoryTech, NativeEvaluator};
use imcopt::runtime::Engine;
use imcopt::space::SearchSpace;
use imcopt::util::bench::Bench;
use imcopt::util::rng::Rng;
use imcopt::workloads::{by_name, WorkloadSet};

fn main() {
    let bench = Bench::new("evaluator");
    let space = SearchSpace::rram();
    let mut rng = Rng::seed_from(1);
    let raws64: Vec<[f64; 10]> = (0..64)
        .map(|_| space.decode(&space.random(&mut rng)))
        .collect();
    let raws256: Vec<[f64; 10]> = (0..256)
        .map(|_| space.decode(&space.random(&mut rng)))
        .collect();

    // ---- native ------------------------------------------------------------
    let native = NativeEvaluator::new(MemoryTech::Rram);
    for wname in ["alexnet", "vgg16", "densenet201", "gpt2-medium"] {
        let w = by_name(wname).unwrap();
        bench.run(&format!("native/{wname}/64"), 64, || {
            for raw in &raws64 {
                std::hint::black_box(native.evaluate(raw, &w));
            }
        });
    }

    // joint score over the 4-workload set (the GA's actual unit of work)
    let set = WorkloadSet::cnn4();
    bench.run("native/joint-cnn4/64", 64, || {
        for raw in &raws64 {
            for w in &set.workloads {
                std::hint::black_box(native.evaluate(raw, w));
            }
        }
    });

    // ---- PJRT artifact -------------------------------------------------------
    match Engine::load_default() {
        Ok(engine) => {
            for wname in ["alexnet", "vgg16", "gpt2-medium"] {
                let w = by_name(wname).unwrap();
                bench.run(&format!("pjrt/{wname}/b64"), 64, || {
                    std::hint::black_box(
                        engine.fitness(&raws64, &w, MemoryTech::Rram).unwrap(),
                    );
                });
                bench.run(&format!("pjrt/{wname}/b256"), 256, || {
                    std::hint::black_box(
                        engine.fitness(&raws256, &w, MemoryTech::Rram).unwrap(),
                    );
                });
            }
            bench.run("pjrt/accproxy", 1, || {
                std::hint::black_box(engine.accproxy_eps(0.03, 0.02).unwrap());
            });
        }
        Err(e) => eprintln!("skipping pjrt benches (artifacts unavailable: {e})"),
    }
}
