//! Hot-path throughput: native scalar evaluator vs the AOT PJRT batched
//! fitness artifact (the production search path), per memory technology
//! and workload size, plus the parallel `score_batch` pipeline bench that
//! guards the coordinator's multi-core speedup. This is the §Perf
//! L3-vs-L2/L1 headline bench.
//!
//! Writes `BENCH_eval.json` (designs/sec for the sequential and parallel
//! `score_batch` paths plus the speedup) and `BENCH_model.json` (compiled
//! O(1) model vs the naive layer loop on the all9 set; the schema gates
//! speedup ≥ 3× and ≤1e-9 agreement) for the perf trajectory.

use imcopt::coordinator::{EvalBackend, JointProblem};
use imcopt::model::{MemoryTech, NativeEvaluator};
use imcopt::objective::Objective;
use imcopt::runtime::Engine;
use imcopt::search::Problem;
use imcopt::space::{Design, SearchSpace};
use imcopt::util::bench::Bench;
use imcopt::util::json::Json;
use imcopt::util::pool;
use imcopt::util::rng::Rng;
use imcopt::workloads::{by_name, WorkloadSet};

fn main() {
    let bench = Bench::new("evaluator");
    let space = SearchSpace::rram();
    let mut rng = Rng::seed_from(1);
    let raws64: Vec<[f64; 10]> = (0..64)
        .map(|_| space.decode(&space.random(&mut rng)))
        .collect();
    let raws256: Vec<[f64; 10]> = (0..256)
        .map(|_| space.decode(&space.random(&mut rng)))
        .collect();

    // ---- native ------------------------------------------------------------
    let native = NativeEvaluator::new(MemoryTech::Rram);
    for wname in ["alexnet", "vgg16", "densenet201", "gpt2-medium"] {
        let w = by_name(wname).unwrap();
        bench.run(&format!("native/{wname}/64"), 64, || {
            for raw in &raws64 {
                std::hint::black_box(native.evaluate(raw, &w));
            }
        });
    }

    // joint score over the 4-workload set (the GA's actual unit of work)
    let set = WorkloadSet::cnn4();
    bench.run("native/joint-cnn4/64", 64, || {
        for raw in &raws64 {
            for w in &set.workloads {
                std::hint::black_box(native.evaluate(raw, w));
            }
        }
    });

    // ---- compiled vs naive closed-form model (BENCH_model.json) ------------
    // The canonical `evaluate` reads the per-workload aggregate tables
    // (model::compiled); `evaluate_naive` is the O(layers) oracle it
    // replaced. Same designs, all 9 workloads — the all9 scenarios are
    // where the layer loop hurt most (MobileBERT has the most layers).
    let all9 = WorkloadSet::all9();
    let n_model = 32usize;
    let model_raws = &raws64[..n_model];
    let model_evals = n_model * all9.len();
    // agreement guard at the property-test bound (also builds the tables
    // before any timing starts)
    let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(f64::MIN_POSITIVE);
    let mut agreement = true;
    for raw in model_raws {
        for w in &all9.workloads {
            let c = native.evaluate(raw, w);
            let o = native.evaluate_naive(raw, w);
            if rel(c.energy, o.energy) > 1e-9
                || rel(c.latency, o.latency) > 1e-9
                || c.area.to_bits() != o.area.to_bits()
                || c.feasible != o.feasible
            {
                agreement = false;
            }
        }
    }
    assert!(agreement, "compiled model diverged from the naive oracle");
    let m_model_naive = bench.run(&format!("model/all9/naive/{n_model}"), model_evals, || {
        for raw in model_raws {
            for w in &all9.workloads {
                std::hint::black_box(native.evaluate_naive(raw, w));
            }
        }
    });
    let m_model_comp = bench.run(&format!("model/all9/compiled/{n_model}"), model_evals, || {
        for raw in model_raws {
            for w in &all9.workloads {
                std::hint::black_box(native.evaluate(raw, w));
            }
        }
    });
    let model_speedup = m_model_naive.mean.as_secs_f64() / m_model_comp.mean.as_secs_f64();
    let naive_eps = model_evals as f64 / m_model_naive.mean.as_secs_f64();
    let comp_eps = model_evals as f64 / m_model_comp.mean.as_secs_f64();
    println!(
        "compiled model speedup: {model_speedup:.2}x on all9 \
         ({naive_eps:.0} -> {comp_eps:.0} evals/s), agreement: {agreement}"
    );
    let model_report = Json::obj(vec![
        ("bench", Json::Str("model_eval".into())),
        ("space", Json::Str("rram-32nm".into())),
        ("workload_set", Json::Str("all9".into())),
        ("designs", Json::Num(n_model as f64)),
        ("evals_per_iter", Json::Num(model_evals as f64)),
        ("evals_per_sec_naive", Json::Num(naive_eps)),
        ("evals_per_sec_compiled", Json::Num(comp_eps)),
        ("speedup", Json::Num(model_speedup)),
        ("agreement", Json::Bool(agreement)),
    ]);
    let model_out = "BENCH_model.json";
    match std::fs::write(model_out, model_report.to_string() + "\n") {
        Ok(()) => println!("wrote {model_out}"),
        Err(e) => eprintln!("could not write {model_out}: {e}"),
    }

    // design-major parallel batch (the score_batch miss path's primitive)
    let threads = pool::default_threads();
    {
        let w = by_name("vgg16").unwrap();
        bench.run(&format!("native/vgg16/batch256/t{threads}"), 256, || {
            std::hint::black_box(native.evaluate_batch(&raws256, &w, threads));
        });
    }

    // ---- score_batch pipeline (sequential vs parallel) ---------------------
    // Fresh problem per iteration so every design is a cache miss; this is
    // the coordinator hot path the search loop actually runs.
    let designs: Vec<Design> = (0..256).map(|_| space.random(&mut rng)).collect();
    let batch = 256usize;
    let run_score_batch = |threads: usize, bench: &Bench| {
        bench.run(&format!("score_batch/native-cnn4/{batch}/t{threads}"), batch, || {
            let p = JointProblem::with_backend(
                &space,
                &set,
                EvalBackend::native(MemoryTech::Rram),
                Objective::edap(),
            )
            .with_threads(threads);
            std::hint::black_box(p.score_batch(&designs));
        })
    };
    let m_seq = run_score_batch(1, &bench);
    let m_par = run_score_batch(threads, &bench);

    // determinism guard: parallel scores must be bit-identical to
    // sequential, and the caches must agree
    let p1 = JointProblem::with_backend(
        &space,
        &set,
        EvalBackend::native(MemoryTech::Rram),
        Objective::edap(),
    )
    .with_threads(1);
    let pn = JointProblem::with_backend(
        &space,
        &set,
        EvalBackend::native(MemoryTech::Rram),
        Objective::edap(),
    )
    .with_threads(threads);
    let s1 = p1.score_batch(&designs);
    let sn = pn.score_batch(&designs);
    let identical = s1
        .iter()
        .zip(&sn)
        .all(|(a, b)| a.to_bits() == b.to_bits())
        && p1.cached_scores().len() == pn.cached_scores().len();
    assert!(identical, "parallel score_batch diverged from sequential");

    let seq_dps = batch as f64 / m_seq.mean.as_secs_f64();
    let par_dps = batch as f64 / m_par.mean.as_secs_f64();
    let speedup = m_seq.mean.as_secs_f64() / m_par.mean.as_secs_f64();
    println!(
        "score_batch speedup: {speedup:.2}x at {threads} threads \
         ({seq_dps:.1} -> {par_dps:.1} designs/s), identical scores: {identical}"
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("score_batch".into())),
        ("space", Json::Str("rram-32nm".into())),
        ("workload_set", Json::Str("cnn4".into())),
        ("batch", Json::Num(batch as f64)),
        ("threads", Json::Num(threads as f64)),
        ("designs_per_sec_seq", Json::Num(seq_dps)),
        ("designs_per_sec_parallel", Json::Num(par_dps)),
        ("speedup", Json::Num(speedup)),
        ("identical_scores", Json::Bool(identical)),
    ]);
    let out = "BENCH_eval.json";
    match std::fs::write(out, report.to_string() + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }

    // ---- PJRT artifact -------------------------------------------------------
    match Engine::load_default() {
        Ok(engine) => {
            for wname in ["alexnet", "vgg16", "gpt2-medium"] {
                let w = by_name(wname).unwrap();
                bench.run(&format!("pjrt/{wname}/b64"), 64, || {
                    std::hint::black_box(
                        engine.fitness(&raws64, &w, MemoryTech::Rram).unwrap(),
                    );
                });
                bench.run(&format!("pjrt/{wname}/b256"), 256, || {
                    std::hint::black_box(
                        engine.fitness(&raws256, &w, MemoryTech::Rram).unwrap(),
                    );
                });
            }
            bench.run("pjrt/accproxy", 1, || {
                std::hint::black_box(engine.accproxy_eps(0.03, 0.02).unwrap());
            });
        }
        Err(e) => eprintln!("skipping pjrt benches (artifacts unavailable: {e})"),
    }
}
