//! Search-stack benches: the paper-budget 4-phase GA run (Table 6's unit),
//! Hamming sampling, and the Table 3 optimizer lineup on the reduced
//! space.

use imcopt::coordinator::{EvalBackend, JointProblem};
use imcopt::model::MemoryTech;
use imcopt::objective::Objective;
use imcopt::search::{
    sampling, CmaEs, EvolutionStrategy, G3Pcx, GaConfig, GeneticAlgorithm, Optimizer, Pso,
    SearchBudget,
};
use imcopt::space::SearchSpace;
use imcopt::util::bench::Bench;
use imcopt::util::rng::Rng;
use imcopt::workloads::WorkloadSet;

fn main() {
    let bench = Bench::new("search");
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let problem = || {
        JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        )
    };

    // Hamming-diversity sampling phase alone (the paper's ~30% overhead)
    bench.run("sampling/ph1000-pe500", 500, || {
        let p = problem();
        let mut rng = Rng::seed_from(3);
        std::hint::black_box(sampling::hamming_init(&p, 1000, 500, 40, &mut rng));
    });

    // full paper-budget 4-phase GA (joint, 4 workloads, native backend)
    bench.run("ga/4phase-paper-budget", 40 * 41, || {
        let p = problem();
        let ga = GeneticAlgorithm::new(GaConfig::four_phase(SearchBudget::paper()));
        std::hint::black_box(ga.run(&p, &mut Rng::seed_from(5)));
    });

    // Table 3 lineup on the reduced space at equal budget
    let reduced = SearchSpace::rram_reduced();
    let budget = SearchBudget { pop: 30, gens: 20 };
    let algos: Vec<(&str, Box<dyn Optimizer>)> = vec![
        ("ga", Box::new(GeneticAlgorithm::new(GaConfig::classic(budget)))),
        ("es", Box::new(EvolutionStrategy::plain(budget))),
        ("eres", Box::new(EvolutionStrategy::eres(budget))),
        ("pso", Box::new(Pso::new(budget))),
        ("g3pcx", Box::new(G3Pcx::new(budget))),
        ("cmaes", Box::new(CmaEs::new(budget))),
    ];
    for (name, algo) in &algos {
        bench.run(&format!("table3/{name}"), budget.pop * budget.gens, || {
            let p = JointProblem::with_backend(
                &reduced,
                &set,
                EvalBackend::native(MemoryTech::Rram),
                Objective::edap(),
            );
            std::hint::black_box(algo.run(&p, &mut Rng::seed_from(7)));
        });
    }
}
