//! End-to-end benches: one timed run per registered experiment.
//! `cargo bench` regenerates every result at quick scale and reports its
//! wall-clock; `imcopt run --all` (no --quick) is the full-scale path.

use imcopt::coordinator::ExpContext;
use imcopt::experiments;
use imcopt::util::bench::Bench;
use std::time::Duration;

fn main() {
    let mut bench = Bench::new("paper");
    // each experiment is itself a long-running unit; one timed iteration
    // per experiment keeps `cargo bench` bounded
    bench.budget = Duration::from_millis(1);
    bench.min_iters = 1;

    for id in experiments::ALL_IDS {
        bench.run(id, 1, || {
            let mut ctx = ExpContext::quick(1234);
            ctx.out_dir = std::env::temp_dir().join("imcopt-bench-results");
            let report = experiments::run(id, &ctx).expect(id);
            std::hint::black_box(report);
        });
    }
}
