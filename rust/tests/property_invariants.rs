//! Property-based tests over the core invariants, via the in-crate
//! mini-proptest harness (`util::proptest`).

use imcopt::accuracy;
use imcopt::model::{DesignView, MemoryTech, NativeEvaluator};
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::search::sampling::select_diverse;
use imcopt::space::{idx, Design, SearchSpace};
use imcopt::util::proptest::check;
use imcopt::util::rng::Rng;
use imcopt::workloads::{by_name, ALL_NAMES};

fn any_space(rng: &mut Rng) -> SearchSpace {
    match rng.below(4) {
        0 => SearchSpace::rram(),
        1 => SearchSpace::sram(),
        2 => SearchSpace::sram_tech(),
        _ => SearchSpace::rram_reduced(),
    }
}

#[test]
fn hamming_is_a_metric() {
    check("hamming metric axioms", 200, |rng| {
        let space = any_space(rng);
        let a = space.random(rng);
        let b = space.random(rng);
        let c = space.random(rng);
        let (dab, dba) = (a.hamming(&b), b.hamming(&a));
        if dab != dba {
            return Err(format!("asymmetric: {dab} vs {dba}"));
        }
        if a.hamming(&a) != 0 {
            return Err("non-zero self distance".into());
        }
        if a.hamming(&c) > dab + b.hamming(&c) {
            return Err("triangle inequality violated".into());
        }
        Ok(())
    });
}

#[test]
fn decode_is_total_and_in_domain() {
    check("decode in-domain", 300, |rng| {
        let space = any_space(rng);
        let d = space.random(rng);
        let raw = space.decode(&d);
        for (i, &v) in raw.iter().enumerate() {
            if !v.is_finite() {
                return Err(format!("param {i} decoded to {v}"));
            }
        }
        // voltage decodes inside the node's Table 7 range
        let (vmin, vmax) =
            imcopt::model::tech::voltage_range(raw[idx::TECH_NM]);
        if raw[idx::V_STEP] < vmin - 1e-9 || raw[idx::V_STEP] > vmax + 1e-9 {
            return Err(format!("voltage {} outside [{vmin},{vmax}]", raw[idx::V_STEP]));
        }
        Ok(())
    });
}

#[test]
fn linear_index_is_injective_on_samples() {
    check("linear index injective", 100, |rng| {
        let space = any_space(rng);
        let a = space.random(rng);
        let b = space.random(rng);
        if a != b && space.linear_index(&a) == space.linear_index(&b) {
            return Err(format!("collision: {a:?} vs {b:?}"));
        }
        if space.linear_index(&a) >= space.size() {
            return Err("index out of range".into());
        }
        Ok(())
    });
}

#[test]
fn evaluator_outputs_are_positive_finite_everywhere() {
    check("evaluator totality", 60, |rng| {
        let (space, mem) = if rng.chance(0.5) {
            (SearchSpace::rram(), MemoryTech::Rram)
        } else {
            (SearchSpace::sram_tech(), MemoryTech::Sram)
        };
        let ev = NativeEvaluator::new(mem);
        let d = space.random(rng);
        let raw = space.decode(&d);
        let w = by_name(ALL_NAMES[rng.below(ALL_NAMES.len())]).unwrap();
        let m = ev.evaluate(&raw, &w);
        if !(m.energy.is_finite() && m.energy > 0.0) {
            return Err(format!("energy {}", m.energy));
        }
        if !(m.latency.is_finite() && m.latency > 0.0) {
            return Err(format!("latency {}", m.latency));
        }
        if !(m.area.is_finite() && m.area > 0.0) {
            return Err(format!("area {}", m.area));
        }
        Ok(())
    });
}

#[test]
fn evaluator_monotone_in_workload_scale() {
    // Duplicating every layer of a workload must not decrease energy or
    // latency on a fixed design (mapping feasibility aside).
    check("monotone in workload size", 40, |rng| {
        let space = SearchSpace::sram();
        let ev = NativeEvaluator::new(MemoryTech::Sram);
        let d = space.random(rng);
        let raw = space.decode(&d);
        let base = by_name("alexnet").unwrap();
        let mut doubled = base.clone();
        let extra: Vec<_> = base.layers.clone();
        doubled.layers.extend(extra);
        let m1 = ev.evaluate(&raw, &base);
        let m2 = ev.evaluate(&raw, &doubled);
        if m2.energy < m1.energy {
            return Err(format!("energy shrank: {} -> {}", m1.energy, m2.energy));
        }
        if m2.latency < m1.latency {
            return Err(format!("latency shrank: {} -> {}", m1.latency, m2.latency));
        }
        Ok(())
    });
}

#[test]
fn area_independent_of_workload_and_v() {
    check("area invariants", 60, |rng| {
        let space = SearchSpace::rram();
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        let d = space.random(rng);
        let mut raw = space.decode(&d);
        let a1 = ev.area(&raw);
        raw[idx::V_STEP] = 0.9; // voltage must not change area
        let a2 = ev.area(&raw);
        if (a1 - a2).abs() > 1e-12 {
            return Err(format!("area depends on voltage: {a1} vs {a2}"));
        }
        Ok(())
    });
}

#[test]
fn objective_scores_scale_invariantly() {
    check("objective scaling", 100, |rng| {
        // doubling every workload's energy doubles Max- and Mean-aggregated
        // EDAP, and multiplies All-aggregated EDAP by 2^n
        let n = 1 + rng.below(4);
        let ms: Vec<imcopt::model::Metrics> = (0..n)
            .map(|_| imcopt::model::Metrics {
                energy: rng.range_f64(1e-4, 1e-2),
                latency: rng.range_f64(1e-4, 1e-2),
                area: 50.0,
                feasible: true,
            })
            .collect();
        let doubled: Vec<imcopt::model::Metrics> = ms
            .iter()
            .map(|m| imcopt::model::Metrics {
                energy: m.energy * 2.0,
                ..*m
            })
            .collect();
        for (agg, factor) in [
            (Aggregation::Max, 2.0),
            (Aggregation::Mean, 2.0),
            (Aggregation::All, 2f64.powi(n as i32)),
        ] {
            let obj = Objective::new(ObjectiveKind::Edap, agg);
            let s1 = obj.score(&ms, None, 32.0);
            let s2 = obj.score(&doubled, None, 32.0);
            let rel = (s2 / s1 - factor).abs() / factor;
            if rel > 1e-9 {
                return Err(format!("{agg:?}: {s1} -> {s2}, expected x{factor}"));
            }
        }
        Ok(())
    });
}

#[test]
fn diverse_selection_never_shrinks_min_distance_vs_prefix() {
    check("diversity selection", 30, |rng| {
        let space = SearchSpace::rram();
        let pool: Vec<Design> = (0..60).map(|_| space.random(rng)).collect();
        let k = 5 + rng.below(20);
        let sel = select_diverse(&pool, k);
        if sel.len() != k.min(pool.len()) {
            return Err("wrong selection size".into());
        }
        let min_pair = |xs: &[Design]| {
            let mut m = usize::MAX;
            for i in 0..xs.len() {
                for j in (i + 1)..xs.len() {
                    m = m.min(xs[i].hamming(&xs[j]));
                }
            }
            m
        };
        if min_pair(&sel) < min_pair(&pool[..k]) {
            return Err("diversified set less spread than arbitrary prefix".into());
        }
        Ok(())
    });
}

#[test]
fn accuracy_estimates_bounded_and_monotone_in_depth() {
    check("accuracy bounds", 60, |rng| {
        let space = SearchSpace::rram();
        let d = space.random(rng);
        let raw = space.decode(&d);
        let spec = accuracy::NoiseSpec::from_design(&raw, MemoryTech::Rram);
        let e1 = accuracy::analytical_eps(&spec, 10);
        let e2 = accuracy::analytical_eps(&spec, 40);
        if e2 < e1 {
            return Err("eps must grow with depth".into());
        }
        let (base, chance) = accuracy::baseline("resnet18");
        let acc = accuracy::accuracy_from_eps(e1, base, chance);
        if !(acc >= chance - 1e-9 && acc <= base + 1e-9) {
            return Err(format!("accuracy {acc} outside [{chance},{base}]"));
        }
        Ok(())
    });
}

#[test]
fn dpw_and_capacity_relations() {
    check("bit-slicing capacity", 100, |rng| {
        let space = SearchSpace::rram();
        let d = space.random(rng);
        let raw = space.decode(&d);
        let view = DesignView::new(&raw, MemoryTech::Rram);
        // dpw * bits >= 8 and (dpw-1) * bits < 8
        let b = raw[idx::BITS_CELL];
        if view.dpw * b < 8.0 || (view.dpw - 1.0) * b >= 8.0 {
            return Err(format!("dpw {} for bits {b}", view.dpw));
        }
        // more bits per cell never needs more crossbars
        let view1 = DesignView::new(
            &{
                let mut r = raw;
                r[idx::BITS_CELL] = 1.0;
                r
            },
            MemoryTech::Rram,
        );
        if view.xbars_for(512.0, 512.0) > view1.xbars_for(512.0, 512.0) {
            return Err("multi-bit cells increased crossbar demand".into());
        }
        Ok(())
    });
}
