//! Parallel-evaluation determinism: `score_batch` must return bit-identical
//! scores and produce identical memo-cache contents for ANY worker-thread
//! count (the `--threads` / `IMCOPT_THREADS` knob), including batches with
//! duplicated and shuffled designs, on both the RRAM and SRAM spaces.

use imcopt::coordinator::{EvalBackend, JointProblem};
use imcopt::model::MemoryTech;
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::search::Problem;
use imcopt::space::{Design, SearchSpace};
use imcopt::util::proptest::check;
use imcopt::util::rng::Rng;
use imcopt::workloads::WorkloadSet;

fn problem<'a>(
    space: &'a SearchSpace,
    set: &'a WorkloadSet,
    mem: MemoryTech,
    objective: Objective,
    threads: usize,
) -> JointProblem<'a> {
    JointProblem::with_backend(space, set, EvalBackend::native(mem), objective)
        .with_threads(threads)
}

/// Random batch with injected duplicates, shuffled.
fn messy_batch(space: &SearchSpace, rng: &mut Rng) -> Vec<Design> {
    let n = 8 + rng.below(24);
    let mut batch: Vec<Design> = (0..n).map(|_| space.random(rng)).collect();
    let dups = 1 + rng.below(8);
    for _ in 0..dups {
        let d = batch[rng.below(batch.len())].clone();
        batch.push(d);
    }
    rng.shuffle(&mut batch);
    batch
}

fn assert_same_scores_and_cache(
    p1: &JointProblem<'_>,
    p8: &JointProblem<'_>,
    batch: &[Design],
) -> Result<(), String> {
    let s1 = p1.score_batch(batch);
    let s8 = p8.score_batch(batch);
    for (i, (a, b)) in s1.iter().zip(&s8).enumerate() {
        if a.to_bits() != b.to_bits() {
            return Err(format!("score[{i}] diverged: {a} (t=1) vs {b} (t=8)"));
        }
    }
    let c1 = p1.cached_scores();
    let c8 = p8.cached_scores();
    if c1.len() != c8.len() {
        return Err(format!("cache sizes differ: {} vs {}", c1.len(), c8.len()));
    }
    for ((k1, v1), (k8, v8)) in c1.iter().zip(&c8) {
        if k1 != k8 {
            return Err(format!("cache keys differ: {k1} vs {k8}"));
        }
        if v1.to_bits() != v8.to_bits() {
            return Err(format!("cached score for key {k1} diverged: {v1} vs {v8}"));
        }
    }
    if p1.evals() != p8.evals() {
        return Err(format!(
            "eval counts differ: {} vs {}",
            p1.evals(),
            p8.evals()
        ));
    }
    Ok(())
}

#[test]
fn score_batch_thread_count_invariant_rram_reduced() {
    check("score_batch t1 == t8 (rram_reduced)", 12, |rng| {
        let space = SearchSpace::rram_reduced();
        let set = WorkloadSet::cnn4();
        let p1 = problem(&space, &set, MemoryTech::Rram, Objective::edap(), 1);
        let p8 = problem(&space, &set, MemoryTech::Rram, Objective::edap(), 8);
        let batch = messy_batch(&space, rng);
        assert_same_scores_and_cache(&p1, &p8, &batch)?;
        // a second (partially overlapping) batch exercises warm-cache hits
        let batch2 = messy_batch(&space, rng);
        assert_same_scores_and_cache(&p1, &p8, &batch2)
    });
}

#[test]
fn score_batch_thread_count_invariant_sram() {
    check("score_batch t1 == t8 (sram)", 10, |rng| {
        let space = SearchSpace::sram();
        let set = WorkloadSet::cnn4();
        let p1 = problem(&space, &set, MemoryTech::Sram, Objective::edap(), 1);
        let p8 = problem(&space, &set, MemoryTech::Sram, Objective::edap(), 8);
        let batch = messy_batch(&space, rng);
        assert_same_scores_and_cache(&p1, &p8, &batch)
    });
}

#[test]
fn score_batch_thread_count_invariant_accuracy_objective() {
    // EdapAccuracy exercises the sharded accuracy-proxy cache from many
    // workers concurrently
    check("score_batch t1 == t8 (EDAP/Acc)", 8, |rng| {
        let space = SearchSpace::rram_reduced();
        let set = WorkloadSet::cnn4();
        let obj = Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max);
        let p1 = problem(&space, &set, MemoryTech::Rram, obj, 1);
        let p8 = problem(&space, &set, MemoryTech::Rram, obj, 8);
        let batch = messy_batch(&space, rng);
        assert_same_scores_and_cache(&p1, &p8, &batch)
    });
}

#[test]
fn score_batch_order_invariant_under_shuffle() {
    // scoring a shuffled copy of the batch yields the permuted scores
    check("score_batch shuffle equivariance", 10, |rng| {
        let space = SearchSpace::rram_reduced();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram, Objective::edap(), 8);
        let batch = messy_batch(&space, rng);
        let scores = p.score_batch(&batch);
        let mut perm: Vec<usize> = (0..batch.len()).collect();
        rng.shuffle(&mut perm);
        let shuffled: Vec<Design> = perm.iter().map(|&i| batch[i].clone()).collect();
        let shuffled_scores = p.score_batch(&shuffled);
        for (j, &i) in perm.iter().enumerate() {
            if scores[i].to_bits() != shuffled_scores[j].to_bits() {
                return Err(format!(
                    "score of design {i} changed after shuffle: {} vs {}",
                    scores[i], shuffled_scores[j]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn imcopt_threads_override_resolution() {
    // `IMCOPT_THREADS` drives `pool::default_threads`, which is what
    // `ExpContext` (and so every CLI run) feeds into `with_threads`. The
    // parsing is tested through `threads_from` rather than `set_var` —
    // mutating the environment while sibling tests read it concurrently
    // is undefined behavior on glibc.
    use imcopt::util::pool::threads_from;
    assert_eq!(threads_from(Some("1")), 1);
    assert_eq!(threads_from(Some("8")), 8);
    assert_eq!(threads_from(Some("0")), 1, "clamped to at least one worker");
    assert!(threads_from(Some("not-a-number")) >= 1, "falls back to cores");
    assert!(threads_from(None) >= 1);
}
