//! Crash matrix for the orchestrator: under any seeded fault
//! interleaving — worker kills, injected panics, journal I/O errors, at
//! any worker count — a `--resume` completes the sweep with artifacts
//! **byte-identical** to an undisturbed run and zero recomputation on a
//! further resume.
//!
//! Faults are injected with the deterministic harness in
//! `imcopt::util::fault` via `IMCOPT_FAULT` (see `docs/orchestration.md`
//! for the plan grammar). Every case drives the real binary
//! (`CARGO_BIN_EXE_imcopt`), so process exits, lease files, worker
//! respawns and exit-code protocols are all exercised for real.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::{Command, Output};

/// fig3 + table3: cheap, cell-granular, and covering both GA and
/// non-GA journal cell kinds.
const IDS: [&str; 2] = ["fig3", "table3"];
const SEED: &str = "11";

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_imcopt")
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imcopt-faults-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// An `imcopt run` command over `dir` with fast orchestrator knobs and a
/// clean fault environment (cases opt in via `.env("IMCOPT_FAULT", ..)`).
fn run_cmd(dir: &Path, extra: &[&str]) -> Command {
    let mut cmd = Command::new(bin());
    cmd.arg("run")
        .args(IDS)
        .args(["--quick", "--stable", "--native"])
        .args(["--seed", SEED])
        .arg("--out-dir")
        .arg(dir)
        .args(extra)
        .env_remove("IMCOPT_FAULT")
        .env_remove("IMCOPT_WORKER_ID")
        .env("IMCOPT_THREADS", "2")
        .env("IMCOPT_LEASE_MS", "300")
        .env("IMCOPT_POLL_MS", "10")
        .env("IMCOPT_RETRY_MS", "10")
        .env("IMCOPT_MAX_RESTARTS", "1");
    cmd
}

fn run_ok(cmd: &mut Command, what: &str) -> Output {
    let out = cmd.output().expect("spawn imcopt");
    assert!(
        out.status.success(),
        "{what} failed ({}):\nstdout:\n{}\nstderr:\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    out
}

/// Every emitted artifact below `dir` keyed by relative path, excluding
/// orchestration internals (checkpoints, status file) whose layout
/// legitimately differs between disturbed and undisturbed runs.
fn artifacts(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).expect("readable dir") {
            let path = entry.unwrap().path();
            let name = path.file_name().unwrap().to_string_lossy().to_string();
            if path.is_dir() {
                // out-of-band telemetry differs between worker topologies
                if name == "checkpoints" || name == "telemetry" {
                    continue;
                }
                walk(root, &path, out);
            } else if name != "orchestrator_status.json" {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().to_string();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

/// The undisturbed single-process reference run.
fn reference(name: &str) -> BTreeMap<String, Vec<u8>> {
    let dir = tmp(name);
    run_ok(&mut run_cmd(&dir, &[]), "reference run");
    let arts = artifacts(&dir);
    assert!(
        arts.keys().any(|k| k.ends_with("fig3.json"))
            && arts.keys().any(|k| k.ends_with("table3.json")),
        "reference run produced {:?}",
        arts.keys().collect::<Vec<_>>()
    );
    arts
}

/// Drive one fault case: run under `IMCOPT_FAULT=plan` (exit status is
/// the fault's business — a kill is *expected* to fail), then resume
/// single-process and demand byte-identity with `reference` plus zero
/// recompute on a second resume.
fn assert_fault_case(
    name: &str,
    plan: &str,
    workers: &[&str],
    reference: &BTreeMap<String, Vec<u8>>,
) {
    let dir = tmp(name);
    let faulted = run_cmd(&dir, workers)
        .env("IMCOPT_FAULT", plan)
        .output()
        .expect("spawn imcopt");
    // recovery, not the crash, is what must succeed
    let resume = run_ok(&mut run_cmd(&dir, &["--resume"]), &format!("{name}: resume"));
    let got = artifacts(&dir);
    assert_eq!(
        got.keys().collect::<Vec<_>>(),
        reference.keys().collect::<Vec<_>>(),
        "{name}: artifact sets differ after fault '{plan}' (faulted run: {}, resume stdout:\n{})",
        faulted.status,
        String::from_utf8_lossy(&resume.stdout),
    );
    for (file, bytes) in reference {
        assert_eq!(
            &got[file], bytes,
            "{name}: artifact {file} differs from the undisturbed run after fault '{plan}'"
        );
    }
    // a second resume replays everything: zero executed, zero recompute
    let again = run_ok(&mut run_cmd(&dir, &["--resume"]), &format!("{name}: second resume"));
    let stdout = String::from_utf8_lossy(&again.stdout);
    assert!(
        stdout.contains("executed=0") && stdout.contains("cells_computed=0"),
        "{name}: second resume recomputed work:\n{stdout}"
    );
}

#[test]
fn crash_matrix_single_process() {
    let reference = reference("ref-single");
    // hard kills at different cells, an injected panic (caught and
    // retried in-process), and a journal-append I/O fault
    for (name, plan) in [
        ("sp-exit-first-cell", "exit@cell=1"),
        ("sp-exit-third-cell", "exit@cell=3"),
        ("sp-panic-second-cell", "panic@cell=2"),
        ("sp-io-journal", "io@journal=2"),
    ] {
        assert_fault_case(name, plan, &[], &reference);
    }
}

#[test]
fn crash_matrix_four_workers() {
    let reference = reference("ref-workers");
    let w4: [&str; 2] = ["--workers", "4"];
    for (name, plan) in [
        // worker 1 dies at its first claimed cell — restarted once, dies
        // again, abandoned; survivors steal its stale leases
        ("w4-exit-w1", "w1:exit@cell=1"),
        ("w4-exit-w3", "w3:exit@cell=2"),
        // panics and I/O faults are isolated inside the worker
        ("w4-panic-w0", "w0:panic@cell=1"),
        ("w4-io-w2", "w2:io@journal=1"),
        // an unscoped fault fires in *every* worker
        ("w4-panic-all", "panic@cell=3"),
    ] {
        assert_fault_case(name, plan, &w4, &reference);
    }
}

#[test]
fn crashed_worker_is_restarted_and_the_sweep_completes() {
    let reference = reference("ref-steal");
    let dir = tmp("steal");
    // the orchestrated run itself must succeed despite worker 1
    // crash-looping into abandonment: restarts + lease stealing cover it
    let out = run_ok(
        run_cmd(&dir, &["--workers", "4"]).env("IMCOPT_FAULT", "w1:exit@cell=1"),
        "orchestrated run with a crashing worker",
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("run summary:"),
        "missing aggregate summary:\n{stdout}"
    );
    // the supervisor documents the outcome machine-readably
    let status_path = dir.join("orchestrator_status.json");
    let status = std::fs::read_to_string(&status_path).expect("orchestrator_status.json");
    for key in ["\"workers\":4", "\"worker_status\":", "\"completed\":", "\"quarantined\":"] {
        assert!(status.contains(key), "status missing {key}: {status}");
    }
    for id in IDS {
        assert!(status.contains(&format!("\"{id}\"")), "{id} not completed: {status}");
    }
    // and the artifacts match the undisturbed single-process run exactly
    let got = artifacts(&dir);
    assert_eq!(got.keys().collect::<Vec<_>>(), reference.keys().collect::<Vec<_>>());
    for (file, bytes) in &reference {
        assert_eq!(&got[file], bytes, "artifact {file} differs at 4 workers");
    }
}

#[test]
fn permanently_poisoned_cell_is_quarantined_and_the_sweep_degrades_gracefully() {
    let reference = reference("ref-poison");
    let dir = tmp("poison");
    // `=*` never stops firing: fig3's first RRAM cell panics on every
    // attempt, so retries are exhausted and fig3 is quarantined
    let out = run_cmd(&dir, &[])
        .env("IMCOPT_FAULT", "panic@cell:fig3:rram:joint=*")
        .output()
        .expect("spawn imcopt");
    assert_eq!(
        out.status.code(),
        Some(3),
        "quarantine must exit with the dedicated code, got {}:\nstdout:\n{}\nstderr:\n{}",
        out.status,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("quarantined=1"), "summary must count the loss:\n{stdout}");
    assert!(
        stderr.contains("quarantined: fig3") && stderr.contains("panicked"),
        "quarantine reason must be surfaced:\n{stderr}"
    );
    // graceful degradation: table3 still completed, byte-identical
    let got = artifacts(&dir);
    assert!(
        !got.keys().any(|k| k.starts_with("fig3")),
        "poisoned fig3 must not emit artifacts: {:?}",
        got.keys().collect::<Vec<_>>()
    );
    let table3: Vec<&String> =
        reference.keys().filter(|k| k.starts_with("table3")).collect();
    assert!(!table3.is_empty());
    for file in table3 {
        assert_eq!(
            got.get(file),
            reference.get(file),
            "table3 artifact {file} differs despite fig3's quarantine"
        );
    }
    // lifting the fault and resuming heals the sweep completely
    run_ok(&mut run_cmd(&dir, &["--resume"]), "healing resume");
    let healed = artifacts(&dir);
    assert_eq!(
        healed.keys().collect::<Vec<_>>(),
        reference.keys().collect::<Vec<_>>()
    );
    for (file, bytes) in &reference {
        assert_eq!(&healed[file], bytes, "healed artifact {file} differs");
    }
}
