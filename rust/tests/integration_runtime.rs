//! Cross-language consistency: the AOT PJRT fitness artifact must agree
//! with the native Rust evaluator on random designs across both memory
//! technologies and all workloads, and the accproxy artifact must behave
//! like the analytical noise model.
//!
//! Requires `make artifacts` and a build with the `pjrt` cargo feature;
//! when the artifacts (or the PJRT runtime) are unavailable these tests
//! skip with a notice instead of failing, so the default no-xla build
//! stays green.

use imcopt::model::{MemoryTech, NativeEvaluator};
use imcopt::runtime::Engine;
use imcopt::space::SearchSpace;
use imcopt::util::rng::Rng;
use imcopt::workloads::{by_name, WorkloadSet, ALL_NAMES};
use std::path::PathBuf;

fn artifact_dir() -> PathBuf {
    // tests run from the crate root
    PathBuf::from("artifacts")
}

fn engine() -> Option<Engine> {
    match Engine::load(&artifact_dir()) {
        Ok(e) => Some(e),
        Err(e) => {
            // only the *expected* unavailability skips: no pjrt feature
            // compiled in, or no exported artifacts. A pjrt build with
            // artifacts present that still fails to load is a real bug
            // and must fail loudly, not silently green-light CI.
            if cfg!(feature = "pjrt") && artifact_dir().join("manifest.json").exists() {
                panic!("artifacts present but the PJRT engine failed to load: {e:#}");
            }
            eprintln!("skipping PJRT integration test (artifacts unavailable: {e:#})");
            None
        }
    }
}

/// Relative-deviation check helper; skips designs within 1% of the area
/// constraint or the timing boundary, where f32-vs-f64 rounding may
/// legitimately flip feasibility.
fn check_agreement(
    engine: &Engine,
    space: &SearchSpace,
    mem: MemoryTech,
    workload_names: &[&str],
    n_designs: usize,
    seed: u64,
) {
    let native = NativeEvaluator::new(mem);
    let mut rng = Rng::seed_from(seed);
    let raws: Vec<[f64; 10]> = (0..n_designs)
        .map(|_| space.decode(&space.random(&mut rng)))
        .collect();
    for name in workload_names {
        let w = by_name(name).unwrap();
        let pjrt = engine.fitness(&raws, &w, mem).unwrap();
        for (raw, pm) in raws.iter().zip(&pjrt) {
            let nm = native.evaluate(raw, &w);
            let marginal = (nm.area / imcopt::model::consts::AREA_CONSTR_MM2 - 1.0)
                .abs()
                < 0.01;
            if !marginal {
                assert_eq!(
                    nm.feasible, pm.feasible,
                    "feasibility mismatch ({name}, {mem:?}): {raw:?}"
                );
            }
            for (label, a, b) in [
                ("energy", nm.energy, pm.energy),
                ("latency", nm.latency, pm.latency),
                ("area", nm.area, pm.area),
            ] {
                let rel = ((a - b) / a).abs();
                assert!(
                    rel < 5e-3,
                    "{label} deviates {rel:.2e} on {name} ({mem:?}): native {a:.6e} vs pjrt {b:.6e}"
                );
            }
        }
    }
}

#[test]
fn fitness_artifact_matches_native_rram() {
    let Some(engine) = engine() else { return };
    check_agreement(
        &engine,
        &SearchSpace::rram(),
        MemoryTech::Rram,
        &["resnet18", "vgg16", "alexnet", "mobilenetv3"],
        24,
        1,
    );
}

#[test]
fn fitness_artifact_matches_native_sram() {
    let Some(engine) = engine() else { return };
    check_agreement(
        &engine,
        &SearchSpace::sram(),
        MemoryTech::Sram,
        &["resnet18", "vgg16", "alexnet", "mobilenetv3"],
        24,
        2,
    );
}

#[test]
fn fitness_artifact_matches_native_all9_spot() {
    let Some(engine) = engine() else { return };
    check_agreement(
        &engine,
        &SearchSpace::sram(),
        MemoryTech::Sram,
        &ALL_NAMES,
        6,
        3,
    );
}

#[test]
fn fitness_artifact_matches_native_tech_variable() {
    let Some(engine) = engine() else { return };
    check_agreement(
        &engine,
        &SearchSpace::sram_tech(),
        MemoryTech::Sram,
        &["resnet18", "vgg16"],
        16,
        4,
    );
}

#[test]
fn batching_chunks_large_populations() {
    let Some(engine) = engine() else { return };
    let space = SearchSpace::rram();
    let mut rng = Rng::seed_from(5);
    // 300 designs forces both the b256 and b64 paths plus padding
    let raws: Vec<[f64; 10]> = (0..300)
        .map(|_| space.decode(&space.random(&mut rng)))
        .collect();
    let w = by_name("alexnet").unwrap();
    let all = engine.fitness(&raws, &w, MemoryTech::Rram).unwrap();
    assert_eq!(all.len(), 300);
    // chunk-invariance: same designs in two calls give identical results
    let head = engine.fitness(&raws[..64], &w, MemoryTech::Rram).unwrap();
    for (a, b) in head.iter().zip(&all[..64]) {
        assert_eq!(a.energy.to_bits(), b.energy.to_bits());
        assert_eq!(a.latency.to_bits(), b.latency.to_bits());
    }
}

#[test]
fn accproxy_monotone_and_near_analytical() {
    let Some(engine) = engine() else { return };
    assert!(engine.has_accproxy());
    // monotone in sigma
    let e0 = engine.accproxy_eps(0.0, 0.0).unwrap();
    let e1 = engine.accproxy_eps(0.03, 0.0).unwrap();
    let e2 = engine.accproxy_eps(0.08, 0.0).unwrap();
    assert!(e0 < e1 && e1 < e2, "{e0} {e1} {e2}");
    // monotone in IR drop
    let i1 = engine.accproxy_eps(0.0, 0.01).unwrap();
    let i2 = engine.accproxy_eps(0.0, 0.05).unwrap();
    assert!(e0 < i1 && i1 < i2);
    // same order of magnitude as the analytical fallback
    let spec = imcopt::accuracy::NoiseSpec::from_design(
        &[256.0, 256.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0],
        MemoryTech::Rram,
    );
    let measured = engine
        .accproxy_eps(spec.weight_sigma(), spec.ir_drop)
        .unwrap();
    let analytical = imcopt::accuracy::analytical_eps(&spec, 1);
    let ratio = measured / analytical;
    assert!(
        (0.2..5.0).contains(&ratio),
        "measured {measured} vs analytical {analytical}"
    );
}

#[test]
fn pjrt_backend_end_to_end_search() {
    use imcopt::coordinator::{EvalBackend, JointProblem};
    use imcopt::objective::Objective;
    use imcopt::search::{GaConfig, GeneticAlgorithm, InitStrategy, Optimizer, SearchBudget};
    use std::sync::{Arc, Mutex};

    let Some(eng) = engine() else { return };
    let engine = Arc::new(Mutex::new(eng));
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let problem = JointProblem::with_backend(
        &space,
        &set,
        EvalBackend::Pjrt(engine, MemoryTech::Rram),
        Objective::edap(),
    );
    let ga = GeneticAlgorithm::new(GaConfig {
        init: InitStrategy::HammingDiverse { p_h: 60, p_e: 30 },
        ..GaConfig::four_phase(SearchBudget { pop: 12, gens: 8 })
    });
    let r = ga.run(&problem, &mut Rng::seed_from(6));
    assert!(
        r.best_score.is_finite(),
        "PJRT-backed GA found no feasible design"
    );

    // the same search on the native backend must agree on the best score
    // (same seed, deterministic evaluators that agree to <0.5%)
    let native = JointProblem::with_backend(
        &space,
        &set,
        EvalBackend::native(MemoryTech::Rram),
        Objective::edap(),
    );
    let ga2 = GeneticAlgorithm::new(GaConfig {
        init: InitStrategy::HammingDiverse { p_h: 60, p_e: 30 },
        ..GaConfig::four_phase(SearchBudget { pop: 12, gens: 8 })
    });
    let r2 = ga2.run(&native, &mut Rng::seed_from(6));
    let rel = ((r.best_score - r2.best_score) / r2.best_score).abs();
    assert!(rel < 0.02, "pjrt {} vs native {}", r.best_score, r2.best_score);
}
