//! Surrogate screening determinism on the real (native) evaluator:
//!
//! - a screened GA run (`screen_frac < 1.0`) is bit-identical across
//!   worker-thread counts (the `--threads` knob), because training pairs
//!   accumulate in evaluation order and `score_batch` is itself
//!   thread-count-invariant;
//! - `--screen-frac 1.0` leaves the exact loop untouched, bit for bit
//!   (it is the default, so unscreened runs cannot drift);
//! - `ScreenState` ranking is a pure function of its observations: the
//!   same pool ranks identically no matter which thread count scored the
//!   training data, across many seeds (property test).

use imcopt::coordinator::{EvalBackend, JointProblem};
use imcopt::model::MemoryTech;
use imcopt::objective::Objective;
use imcopt::search::surrogate::ScreenState;
use imcopt::search::{GaConfig, GeneticAlgorithm, OptResult, Optimizer, Problem, SearchBudget};
use imcopt::space::{Design, SearchSpace};
use imcopt::util::proptest::check;
use imcopt::util::rng::Rng;
use imcopt::workloads::WorkloadSet;

fn problem<'a>(space: &'a SearchSpace, set: &'a WorkloadSet, threads: usize) -> JointProblem<'a> {
    JointProblem::with_backend(space, set, EvalBackend::native(MemoryTech::Rram), Objective::edap())
        .with_threads(threads)
}

fn assert_bit_identical(a: &OptResult, b: &OptResult, what: &str) {
    assert_eq!(a.best, b.best, "{what}: best designs differ");
    assert_eq!(
        a.best_score.to_bits(),
        b.best_score.to_bits(),
        "{what}: best scores differ: {} vs {}",
        a.best_score,
        b.best_score
    );
    assert_eq!(a.evals, b.evals, "{what}: eval counts differ");
    assert_eq!(a.history.len(), b.history.len(), "{what}: history lengths differ");
    for (g, (x, y)) in a.history.iter().zip(&b.history).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: history diverges at generation {g}: {x} vs {y}"
        );
    }
    assert_eq!(a.top.len(), b.top.len(), "{what}: top-k lengths differ");
    for ((d1, s1), (d2, s2)) in a.top.iter().zip(&b.top) {
        assert_eq!(d1, d2, "{what}: top-k designs differ");
        assert_eq!(s1.to_bits(), s2.to_bits(), "{what}: top-k scores differ");
    }
}

/// The tentpole invariant: a screened run is a pure function of
/// (problem, config, seed) — the thread count must not leak into the
/// surrogate's training set, ranking, or carry.
#[test]
fn screened_ga_is_bit_identical_across_thread_counts() {
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let cfg = GaConfig {
        screen_frac: 0.25,
        ..GaConfig::four_phase(SearchBudget { pop: 12, gens: 8 })
    };
    let run = |threads: usize| {
        let p = problem(&space, &set, threads);
        GeneticAlgorithm::new(cfg.clone()).run(&p, &mut Rng::seed_from(41))
    };
    assert_bit_identical(&run(1), &run(8), "screened GA t1 vs t8");
}

/// Compatibility invariant: an explicit `--screen-frac 1.0` takes the
/// exact (unscreened) code path and matches the default config bit for
/// bit — and both are seed-reproducible.
#[test]
fn screen_frac_one_matches_default_exact_loop() {
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let budget = SearchBudget { pop: 12, gens: 8 };
    let run = |cfg: GaConfig| {
        let p = problem(&space, &set, 4);
        GeneticAlgorithm::new(cfg).run(&p, &mut Rng::seed_from(17))
    };
    let default = run(GaConfig::four_phase(budget));
    let explicit = run(GaConfig {
        screen_frac: 1.0,
        ..GaConfig::four_phase(budget)
    });
    assert_bit_identical(&default, &explicit, "default vs --screen-frac 1.0");
    let replay = run(GaConfig::four_phase(budget));
    assert_bit_identical(&default, &replay, "default replay");
}

/// Screened runs stay seed-reproducible (same seed twice → identical
/// result, different seed → a genuinely different search).
#[test]
fn screened_ga_is_seed_deterministic() {
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let cfg = GaConfig {
        screen_frac: 0.5,
        ..GaConfig::four_phase(SearchBudget { pop: 12, gens: 8 })
    };
    let run = |seed: u64| {
        let p = problem(&space, &set, 4);
        GeneticAlgorithm::new(cfg.clone()).run(&p, &mut Rng::seed_from(seed))
    };
    assert_bit_identical(&run(23), &run(23), "screened GA seed replay");
    let (a, b) = (run(23), run(24));
    // different seeds normally reach different (even if close) scores;
    // equality of all three would suggest the seed is ignored
    assert!(a.best_score.to_bits() != b.best_score.to_bits() || a.best_score == b.best_score);
}

/// Property: `ScreenState` ranking is deterministic across thread counts
/// and seeds. Training scores from a 1-thread and an 8-thread evaluator
/// must produce identical selections and carries on an arbitrary pool.
#[test]
fn screen_ranking_is_thread_count_and_seed_invariant() {
    check("ScreenState rank t1 == t8", 10, |rng| {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p1 = problem(&space, &set, 1);
        let p8 = problem(&space, &set, 8);

        let n_train = 20 + rng.below(40);
        let train: Vec<Design> = (0..n_train).map(|_| p1.random_candidate(rng)).collect();
        let mut s1 = ScreenState::new(0.25).expect("0.25 screens");
        let mut s8 = s1.clone();
        s1.observe(&space, &train, &p1.score_batch(&train));
        s8.observe(&space, &train, &p8.score_batch(&train));
        if s1.observations() != s8.observations() {
            return Err(format!(
                "observation counts diverged: {} vs {}",
                s1.observations(),
                s8.observations()
            ));
        }

        let pool: Vec<Design> = (0..32).map(|_| p1.random_candidate(rng)).collect();
        let keep = 4 + rng.below(12);
        // a clone must rank identically (selection is a pure function of
        // the state and the pool, no interior randomness)
        let replay = s1.clone().select(&space, pool.clone(), keep);
        let kept1 = s1.select(&space, pool.clone(), keep);
        let kept8 = s8.select(&space, pool, keep);
        if kept1 != replay {
            return Err("clone replay selected a different set".into());
        }
        if kept1 != kept8 {
            return Err(format!(
                "thread counts selected different sets:\n t1: {kept1:?}\n t8: {kept8:?}"
            ));
        }
        if s1.take_carry() != s8.take_carry() {
            return Err("carries diverged between thread counts".into());
        }
        Ok(())
    });
}
