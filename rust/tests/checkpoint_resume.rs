//! Kill/resume integration: a checkpointed run interrupted mid-experiment
//! and resumed with `--resume` must complete with reports byte-identical
//! to an uninterrupted run, reusing journaled cells instead of
//! re-evaluating them.
//!
//! Uses `--stable` report mode (wall-clock columns render as `-`), which
//! makes every report a pure function of the seed — the property the
//! byte-comparison relies on.

use imcopt::coordinator::ExpContext;
use imcopt::experiments::{self, checkpoint::Checkpoint};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// fig3 journals GA cells; table3 journals non-GA optimizer cells and has
/// (stable-masked) timing columns — together they cover both cell kinds.
const IDS: [&str; 2] = ["fig3", "table3"];

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imcopt-resume-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn ctx_at(seed: u64, dir: &Path, resume: bool) -> ExpContext {
    let mut c = ExpContext::quick(seed);
    c.out_dir = dir.to_path_buf();
    c.stable = true;
    c.resume = resume;
    c
}

/// Every emitted artifact (md/json/csv) below `dir`, keyed by relative
/// path — checkpoint internals are excluded (journal layouts may differ
/// between an interrupted and a straight run; artifacts must not).
fn artifacts(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).expect("readable dir") {
            let entry = entry.unwrap();
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().to_string();
            if path.is_dir() {
                // telemetry is out-of-band: its append-only trace files
                // legitimately differ between straight and resumed runs
                if name == "checkpoints" || name == "telemetry" {
                    continue;
                }
                walk(root, &path, out);
            } else {
                let rel = path
                    .strip_prefix(root)
                    .unwrap()
                    .to_string_lossy()
                    .to_string();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

#[test]
fn interrupted_run_resumes_bit_identical() {
    let dir_a = tmp("straight");
    let dir_b = tmp("killed");

    // reference: uninterrupted checkpointed run
    let summary_a = experiments::run_selected(&IDS, &ctx_at(29, &dir_a, false)).unwrap();
    assert_eq!(summary_a.executed, IDS.len());
    assert_eq!(summary_a.replayed, 0);

    // interrupted run: the simulated-kill hook stops fig3 after its first
    // fresh cell, leaving a partial journal exactly like a hard kill
    {
        let ctx = ctx_at(29, &dir_b, false);
        let mut ckpt = Checkpoint::for_experiment(&ctx.out_dir, "fig3", false).unwrap();
        ckpt.abort_after_cells = Some(1);
        let err = experiments::run_with("fig3", &ctx, &mut ckpt).unwrap_err();
        assert!(
            format!("{err:#}").contains("simulated kill"),
            "unexpected error: {err:#}"
        );
        assert_eq!(ckpt.computed(), 1);
    }

    // resume completes the partial experiment and runs the rest
    let summary_b = experiments::run_selected(&IDS, &ctx_at(29, &dir_b, true)).unwrap();
    assert_eq!(summary_b.executed, IDS.len(), "nothing was complete yet");
    assert!(
        summary_b.cells_reused >= 1,
        "the journaled fig3 cell must be reused, not re-run"
    );
    assert_eq!(
        summary_b.cells_computed + summary_b.cells_reused,
        summary_a.cells_computed,
        "resume must account for every cell of a straight run"
    );

    // reports are byte-identical to the uninterrupted run
    let a = artifacts(&dir_a);
    let b = artifacts(&dir_b);
    let names_a: Vec<&String> = a.keys().collect();
    let names_b: Vec<&String> = b.keys().collect();
    assert_eq!(names_a, names_b, "artifact sets differ");
    assert!(
        a.keys().any(|k| k.ends_with("fig3.json")),
        "expected fig3 artifacts, got {names_a:?}"
    );
    for (name, bytes_a) in &a {
        assert_eq!(
            bytes_a, &b[name],
            "artifact {name} differs between straight and resumed runs"
        );
    }
}

/// The portfolio/multi-objective experiments journal differently shaped
/// cells (scenario cells, NSGA-II fronts, shared separate-search bounds)
/// than the optimizer experiments of [`IDS`] — the kill/resume contract
/// must hold for them too.
const IDS2: [&str; 2] = ["transfer", "pareto"];

/// A context narrowed to one custom scenario family so the sweep stays
/// CI-sized: `transfer` runs the split portfolios of the 2-workload set,
/// `pareto` one spec in metric mode.
fn ctx_portfolio(seed: u64, dir: &Path, resume: bool) -> ExpContext {
    let mut c = ctx_at(seed, dir, resume);
    c.spec = Some("resnet18+vgg16:rram".into());
    c.moo_mode = Some("metric".into());
    c.pareto_cap = 16;
    c
}

#[test]
fn killed_transfer_and_pareto_runs_resume_bit_identical() {
    let dir_a = tmp("portfolio-straight");
    let dir_b = tmp("portfolio-killed");

    // reference: uninterrupted checkpointed run
    let summary_a =
        experiments::run_selected(&IDS2, &ctx_portfolio(37, &dir_a, false)).unwrap();
    assert_eq!(summary_a.executed, IDS2.len());
    assert!(summary_a.quarantined.is_empty());

    // kill *each* experiment after its first fresh cell
    let ctx = ctx_portfolio(37, &dir_b, false);
    let mut killed_cells = 0usize;
    for id in IDS2 {
        let mut ckpt = Checkpoint::for_experiment(&ctx.out_dir, id, false).unwrap();
        ckpt.abort_after_cells = Some(1);
        let err = experiments::run_with(id, &ctx, &mut ckpt).unwrap_err();
        assert!(
            format!("{err:#}").contains("simulated kill"),
            "{id}: unexpected error: {err:#}"
        );
        assert_eq!(ckpt.computed(), 1, "{id} must die after exactly one cell");
        killed_cells += ckpt.computed();
    }

    // one resume completes both experiments
    let summary_b =
        experiments::run_selected(&IDS2, &ctx_portfolio(37, &dir_b, true)).unwrap();
    assert_eq!(summary_b.executed, IDS2.len(), "no report was stored yet");
    assert_eq!(summary_b.replayed, 0);
    assert!(
        summary_b.cells_reused >= killed_cells,
        "every pre-kill cell must be reused, not re-run (reused {} < {killed_cells})",
        summary_b.cells_reused
    );
    // both runs visit the same deterministic cell sequence; visits are
    // split between computed and reused differently, never lost
    assert_eq!(
        summary_b.cells_computed + summary_b.cells_reused,
        summary_a.cells_computed + summary_a.cells_reused,
        "resume must account for every cell visit of a straight run"
    );

    // artifacts are byte-identical to the uninterrupted run
    let a = artifacts(&dir_a);
    let b = artifacts(&dir_b);
    let names_a: Vec<&String> = a.keys().collect();
    let names_b: Vec<&String> = b.keys().collect();
    assert_eq!(names_a, names_b, "artifact sets differ");
    assert!(
        a.keys().any(|k| k.ends_with("pareto.json")),
        "expected pareto artifacts, got {names_a:?}"
    );
    assert!(
        a.keys().any(|k| k.ends_with("transfer.json")),
        "expected transfer artifacts, got {names_a:?}"
    );
    for (name, bytes_a) in &a {
        assert_eq!(
            bytes_a, &b[name],
            "artifact {name} differs between straight and resumed runs"
        );
    }

    // a second resume replays both stored reports with zero computation
    let again =
        experiments::run_selected(&IDS2, &ctx_portfolio(37, &dir_b, true)).unwrap();
    assert_eq!(again.replayed, IDS2.len());
    assert_eq!(again.executed, 0);
    assert_eq!(again.cells_computed, 0, "replay must not recompute cells");
}

/// The `surrogate` ablation journals screened GA cells whose RNG stream
/// threads through `ScreenState`'s carry between generations — the
/// kill/resume contract must hold for those too, and the run-config
/// fingerprint must pin `--screen-frac` so a resume under a different
/// screening fraction is rejected instead of silently mixing loops.
#[test]
fn killed_surrogate_run_resumes_bit_identical() {
    const ID: [&str; 1] = ["surrogate"];
    let dir_a = tmp("surrogate-straight");
    let dir_b = tmp("surrogate-killed");
    let ctx_screened = |dir: &Path, resume: bool, frac: f64| {
        let mut c = ctx_at(43, dir, resume);
        c.screen_frac = frac;
        c
    };

    // reference: uninterrupted checkpointed run
    let summary_a =
        experiments::run_selected(&ID, &ctx_screened(&dir_a, false, 0.25)).unwrap();
    assert_eq!(summary_a.executed, 1);
    assert!(summary_a.quarantined.is_empty());

    // kill after the first fresh cell (the frac-1.0 exact anchor); the
    // config is bound first, exactly as `run_session` does, so the
    // journal pins the fingerprint it was written under
    {
        let ctx = ctx_screened(&dir_b, false, 0.25);
        let mut ckpt = Checkpoint::for_experiment(&ctx.out_dir, "surrogate", false).unwrap();
        ckpt.bind_config(&experiments::config_fingerprint(&ctx)).unwrap();
        ckpt.abort_after_cells = Some(1);
        let err = experiments::run_with("surrogate", &ctx, &mut ckpt).unwrap_err();
        assert!(
            format!("{err:#}").contains("simulated kill"),
            "unexpected error: {err:#}"
        );
        assert_eq!(ckpt.computed(), 1);
    }

    // resuming under a different --screen-frac must be rejected: the
    // journaled cells were produced by a differently screened loop
    {
        let ctx = ctx_screened(&dir_b, true, 0.5);
        let mut ckpt = Checkpoint::for_experiment(&ctx.out_dir, "surrogate", true).unwrap();
        let err = ckpt
            .bind_config(&experiments::config_fingerprint(&ctx))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("different configuration"),
            "expected a config-fingerprint rejection, got: {err:#}"
        );
    }

    // resume under the original fraction completes bit-identically
    let summary_b =
        experiments::run_selected(&ID, &ctx_screened(&dir_b, true, 0.25)).unwrap();
    assert_eq!(summary_b.executed, 1, "the report was never stored");
    assert!(
        summary_b.cells_reused >= 1,
        "the journaled pre-kill cell must be reused, not re-run"
    );
    assert_eq!(
        summary_b.cells_computed + summary_b.cells_reused,
        summary_a.cells_computed + summary_a.cells_reused,
        "resume must account for every cell visit of a straight run"
    );

    let a = artifacts(&dir_a);
    let b = artifacts(&dir_b);
    let names_a: Vec<&String> = a.keys().collect();
    let names_b: Vec<&String> = b.keys().collect();
    assert_eq!(names_a, names_b, "artifact sets differ");
    assert!(
        a.keys().any(|k| k.ends_with("surrogate.json")),
        "expected surrogate artifacts, got {names_a:?}"
    );
    for (name, bytes_a) in &a {
        assert_eq!(
            bytes_a, &b[name],
            "artifact {name} differs between straight and resumed runs"
        );
    }

    // a second resume replays the stored report with zero computation
    let again = experiments::run_selected(&ID, &ctx_screened(&dir_b, true, 0.25)).unwrap();
    assert_eq!(again.replayed, 1);
    assert_eq!(again.executed, 0);
    assert_eq!(again.cells_computed, 0, "replay must not recompute cells");
}

/// `synth:` workload families ride the `--spec` string, so a population
/// run must be a pure function of the seed in the token: byte-identical
/// across `--threads 1` vs `--threads 8`, byte-identical across a
/// kill/`--resume`, and a resume under a *different* synth spec must be
/// rejected by the config fingerprint (its journaled cells were measured
/// on different generated networks).
#[test]
fn killed_population_run_resumes_bit_identical_across_threads() {
    const ID: [&str; 1] = ["population"];
    const SPEC: &str = "synth:mixed:6:11:rram";
    let dir_a = tmp("population-t1");
    let dir_b = tmp("population-t8");
    let dir_c = tmp("population-killed");
    let ctx_synth = |dir: &Path, resume: bool, threads: usize, spec: &str| {
        let mut c = ctx_at(41, dir, resume);
        c.threads = threads;
        c.spec = Some(spec.into());
        c
    };

    // straight runs at 1 and 8 threads generate the same family and the
    // same bytes
    let summary_a =
        experiments::run_selected(&ID, &ctx_synth(&dir_a, false, 1, SPEC)).unwrap();
    assert_eq!(summary_a.executed, 1);
    assert!(summary_a.quarantined.is_empty());
    let summary_b =
        experiments::run_selected(&ID, &ctx_synth(&dir_b, false, 8, SPEC)).unwrap();
    assert_eq!(summary_b.executed, 1);
    let a = artifacts(&dir_a);
    let b = artifacts(&dir_b);
    assert_eq!(
        a.keys().collect::<Vec<_>>(),
        b.keys().collect::<Vec<_>>(),
        "artifact sets differ across thread counts"
    );
    assert!(
        a.keys().any(|k| k.contains("population_cells/")),
        "expected portfolio cell artifacts, got {:?}",
        a.keys().collect::<Vec<_>>()
    );
    for (name, bytes_a) in &a {
        assert_eq!(
            bytes_a, &b[name],
            "artifact {name} differs between --threads 1 and --threads 8"
        );
    }

    // kill after the first fresh cell, config bound as run_session does
    {
        let ctx = ctx_synth(&dir_c, false, 8, SPEC);
        let mut ckpt = Checkpoint::for_experiment(&ctx.out_dir, "population", false).unwrap();
        ckpt.bind_config(&experiments::config_fingerprint(&ctx)).unwrap();
        ckpt.abort_after_cells = Some(1);
        let err = experiments::run_with("population", &ctx, &mut ckpt).unwrap_err();
        assert!(
            format!("{err:#}").contains("simulated kill"),
            "unexpected error: {err:#}"
        );
        assert_eq!(ckpt.computed(), 1);
    }

    // resuming under a different synth seed must be rejected: the journal
    // holds measurements of a different generated family
    {
        let ctx = ctx_synth(&dir_c, true, 8, "synth:mixed:6:12:rram");
        let mut ckpt = Checkpoint::for_experiment(&ctx.out_dir, "population", true).unwrap();
        let err = ckpt
            .bind_config(&experiments::config_fingerprint(&ctx))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("different configuration"),
            "expected a config-fingerprint rejection, got: {err:#}"
        );
    }

    // resume under the original spec completes byte-identically to the
    // single-thread straight run
    let summary_c =
        experiments::run_selected(&ID, &ctx_synth(&dir_c, true, 8, SPEC)).unwrap();
    assert_eq!(summary_c.executed, 1, "the report was never stored");
    assert!(
        summary_c.cells_reused >= 1,
        "the journaled pre-kill cell must be reused, not re-run"
    );
    assert_eq!(
        summary_c.cells_computed + summary_c.cells_reused,
        summary_a.cells_computed + summary_a.cells_reused,
        "resume must account for every cell visit of a straight run"
    );
    let c = artifacts(&dir_c);
    assert_eq!(
        a.keys().collect::<Vec<_>>(),
        c.keys().collect::<Vec<_>>(),
        "artifact sets differ"
    );
    for (name, bytes_a) in &a {
        assert_eq!(
            bytes_a, &c[name],
            "artifact {name} differs between straight and resumed runs"
        );
    }

    // a second resume replays the stored report with zero computation
    let again = experiments::run_selected(&ID, &ctx_synth(&dir_c, true, 8, SPEC)).unwrap();
    assert_eq!(again.replayed, 1);
    assert_eq!(again.executed, 0);
    assert_eq!(again.cells_computed, 0, "replay must not recompute cells");
}

#[test]
fn completed_experiments_replay_without_recomputation() {
    let dir = tmp("replay");
    let first = experiments::run_selected(&IDS, &ctx_at(31, &dir, false)).unwrap();
    assert_eq!(first.executed, IDS.len());
    let before = artifacts(&dir);

    let again = experiments::run_selected(&IDS, &ctx_at(31, &dir, true)).unwrap();
    assert_eq!(again.replayed, IDS.len(), "all experiments were complete");
    assert_eq!(again.executed, 0);
    assert_eq!(again.cells_computed, 0, "replay must not recompute cells");

    let after = artifacts(&dir);
    assert_eq!(before.len(), after.len());
    for (name, bytes) in &before {
        assert_eq!(bytes, &after[name], "replayed artifact {name} changed");
    }
}
