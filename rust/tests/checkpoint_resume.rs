//! Kill/resume integration: a checkpointed run interrupted mid-experiment
//! and resumed with `--resume` must complete with reports byte-identical
//! to an uninterrupted run, reusing journaled cells instead of
//! re-evaluating them.
//!
//! Uses `--stable` report mode (wall-clock columns render as `-`), which
//! makes every report a pure function of the seed — the property the
//! byte-comparison relies on.

use imcopt::coordinator::ExpContext;
use imcopt::experiments::{self, checkpoint::Checkpoint};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// fig3 journals GA cells; table3 journals non-GA optimizer cells and has
/// (stable-masked) timing columns — together they cover both cell kinds.
const IDS: [&str; 2] = ["fig3", "table3"];

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imcopt-resume-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn ctx_at(seed: u64, dir: &Path, resume: bool) -> ExpContext {
    let mut c = ExpContext::quick(seed);
    c.out_dir = dir.to_path_buf();
    c.stable = true;
    c.resume = resume;
    c
}

/// Every emitted artifact (md/json/csv) below `dir`, keyed by relative
/// path — checkpoint internals are excluded (journal layouts may differ
/// between an interrupted and a straight run; artifacts must not).
fn artifacts(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).expect("readable dir") {
            let entry = entry.unwrap();
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().to_string();
            if path.is_dir() {
                if name == "checkpoints" {
                    continue;
                }
                walk(root, &path, out);
            } else {
                let rel = path
                    .strip_prefix(root)
                    .unwrap()
                    .to_string_lossy()
                    .to_string();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

#[test]
fn interrupted_run_resumes_bit_identical() {
    let dir_a = tmp("straight");
    let dir_b = tmp("killed");

    // reference: uninterrupted checkpointed run
    let summary_a = experiments::run_selected(&IDS, &ctx_at(29, &dir_a, false)).unwrap();
    assert_eq!(summary_a.executed, IDS.len());
    assert_eq!(summary_a.replayed, 0);

    // interrupted run: the simulated-kill hook stops fig3 after its first
    // fresh cell, leaving a partial journal exactly like a hard kill
    {
        let ctx = ctx_at(29, &dir_b, false);
        let mut ckpt = Checkpoint::for_experiment(&ctx.out_dir, "fig3", false).unwrap();
        ckpt.abort_after_cells = Some(1);
        let err = experiments::run_with("fig3", &ctx, &mut ckpt).unwrap_err();
        assert!(
            format!("{err:#}").contains("simulated kill"),
            "unexpected error: {err:#}"
        );
        assert_eq!(ckpt.computed(), 1);
    }

    // resume completes the partial experiment and runs the rest
    let summary_b = experiments::run_selected(&IDS, &ctx_at(29, &dir_b, true)).unwrap();
    assert_eq!(summary_b.executed, IDS.len(), "nothing was complete yet");
    assert!(
        summary_b.cells_reused >= 1,
        "the journaled fig3 cell must be reused, not re-run"
    );
    assert_eq!(
        summary_b.cells_computed + summary_b.cells_reused,
        summary_a.cells_computed,
        "resume must account for every cell of a straight run"
    );

    // reports are byte-identical to the uninterrupted run
    let a = artifacts(&dir_a);
    let b = artifacts(&dir_b);
    let names_a: Vec<&String> = a.keys().collect();
    let names_b: Vec<&String> = b.keys().collect();
    assert_eq!(names_a, names_b, "artifact sets differ");
    assert!(
        a.keys().any(|k| k.ends_with("fig3.json")),
        "expected fig3 artifacts, got {names_a:?}"
    );
    for (name, bytes_a) in &a {
        assert_eq!(
            bytes_a, &b[name],
            "artifact {name} differs between straight and resumed runs"
        );
    }
}

#[test]
fn completed_experiments_replay_without_recomputation() {
    let dir = tmp("replay");
    let first = experiments::run_selected(&IDS, &ctx_at(31, &dir, false)).unwrap();
    assert_eq!(first.executed, IDS.len());
    let before = artifacts(&dir);

    let again = experiments::run_selected(&IDS, &ctx_at(31, &dir, true)).unwrap();
    assert_eq!(again.replayed, IDS.len(), "all experiments were complete");
    assert_eq!(again.executed, 0);
    assert_eq!(again.cells_computed, 0, "replay must not recompute cells");

    let after = artifacts(&dir);
    assert_eq!(before.len(), after.len());
    for (name, bytes) in &before {
        assert_eq!(bytes, &after[name], "replayed artifact {name} changed");
    }
}
