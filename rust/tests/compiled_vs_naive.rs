//! Property test pinning the compiled O(1) evaluator to the naive
//! layer-loop oracle: ≥200 random designs per memory technology × all 9
//! workloads × {RRAM, SRAM}, energy/latency within 1e-9 relative, area
//! bit-identical, feasibility (capacity/timing/area) exactly equal —
//! plus the same oracle over ≥100 generator-sampled synthetic workloads
//! per technology (the `population` experiment's substrate).
//!
//! The compiled path reorders float summations (aggregates first, factors
//! second), so bit-identity with the naive walk is *not* expected for
//! energy/latency; bit-identity of the compiled path with itself across
//! thread counts and resume replays is covered by
//! `tests/parallel_determinism.rs` and `tests/checkpoint_resume.rs`,
//! which now run against the compiled backend.

use imcopt::model::{DesignView, MemoryTech, NativeEvaluator};
use imcopt::space::SearchSpace;
use imcopt::util::rng::Rng;
use imcopt::workloads::WorkloadSet;

fn rel(a: f64, b: f64) -> f64 {
    if a == b {
        0.0
    } else {
        (a - b).abs() / b.abs().max(f64::MIN_POSITIVE)
    }
}

#[test]
fn compiled_matches_naive_oracle_within_1e9() {
    let set = WorkloadSet::all9();
    let cases = [
        (
            MemoryTech::Rram,
            [SearchSpace::rram(), SearchSpace::rram_reduced()],
        ),
        (
            MemoryTech::Sram,
            [SearchSpace::sram(), SearchSpace::sram_tech()],
        ),
    ];
    for (mem, spaces) in cases {
        let ev = NativeEvaluator::new(mem);
        let mut rng = Rng::seed_from(0xC0DE);
        let mut designs = 0usize;
        for space in &spaces {
            for _ in 0..110 {
                let raw = space.decode(&space.random(&mut rng));
                let view = DesignView::new(&raw, mem);
                for w in &set.workloads {
                    assert!(
                        w.compiled().covers(&view),
                        "{}: {:?} must be on-grid",
                        space.variant,
                        raw
                    );
                    let c = ev.evaluate(&raw, w);
                    let o = ev.evaluate_naive(&raw, w);
                    assert!(
                        rel(c.energy, o.energy) <= 1e-9,
                        "{}/{}/{}: energy {} vs {} (rel {})",
                        space.variant,
                        mem.name(),
                        w.name,
                        c.energy,
                        o.energy,
                        rel(c.energy, o.energy)
                    );
                    assert!(
                        rel(c.latency, o.latency) <= 1e-9,
                        "{}/{}/{}: latency {} vs {} (rel {})",
                        space.variant,
                        mem.name(),
                        w.name,
                        c.latency,
                        o.latency,
                        rel(c.latency, o.latency)
                    );
                    assert_eq!(
                        c.area.to_bits(),
                        o.area.to_bits(),
                        "{}: area must be the identical computation",
                        w.name
                    );
                    assert_eq!(
                        c.feasible, o.feasible,
                        "{}/{}/{}: feasibility must match exactly \
                         (capacity sums are integer-exact)",
                        space.variant,
                        mem.name(),
                        w.name
                    );
                }
                designs += 1;
            }
        }
        assert!(designs >= 200, "per-tech design budget");
    }
}

/// The oracle holds across the synthetic-workload generator's whole
/// range, not just the 9 hand-coded nets: 120 seeded samples from the
/// mixed distribution per technology, every one on-grid, energy/latency
/// within 1e-9 of the naive walk, area bit-identical, feasibility exact.
#[test]
fn compiled_matches_naive_on_generator_population() {
    let dist = imcopt::ingest::WorkloadDistribution::named("mixed").unwrap();
    let cases = [
        (MemoryTech::Rram, SearchSpace::rram(), 0xA11CEu64),
        (MemoryTech::Sram, SearchSpace::sram(), 0xB0B5u64),
    ];
    for (mem, space, seed) in cases {
        let pop = dist.population(120, seed);
        assert_eq!(pop.len(), 120);
        let ev = NativeEvaluator::new(mem);
        let mut rng = Rng::seed_from(seed ^ 0xF00D);
        for _ in 0..8 {
            let raw = space.decode(&space.random(&mut rng));
            let view = DesignView::new(&raw, mem);
            for w in &pop.workloads {
                assert!(
                    w.compiled().covers(&view),
                    "{}/{}: synthetic geometry must be on-grid",
                    space.variant,
                    w.name
                );
                let c = ev.evaluate(&raw, w);
                let o = ev.evaluate_naive(&raw, w);
                assert!(
                    rel(c.energy, o.energy) <= 1e-9,
                    "{}/{}: energy {} vs {} (rel {})",
                    mem.name(),
                    w.name,
                    c.energy,
                    o.energy,
                    rel(c.energy, o.energy)
                );
                assert!(
                    rel(c.latency, o.latency) <= 1e-9,
                    "{}/{}: latency {} vs {} (rel {})",
                    mem.name(),
                    w.name,
                    c.latency,
                    o.latency,
                    rel(c.latency, o.latency)
                );
                assert_eq!(c.area.to_bits(), o.area.to_bits(), "{}", w.name);
                assert_eq!(c.feasible, o.feasible, "{}/{}", mem.name(), w.name);
            }
        }
    }
}

/// The compiled path is a pure function of (design, workload): repeated
/// evaluation — including through a freshly cloned workload set, as a
/// resume replay constructs — is bit-identical.
#[test]
fn compiled_path_is_bit_stable_across_instances() {
    let set_a = WorkloadSet::all9();
    let set_b = WorkloadSet::all9(); // fresh instances, fresh tables
    let ev = NativeEvaluator::new(MemoryTech::Rram);
    let space = SearchSpace::rram();
    let mut rng = Rng::seed_from(5);
    for _ in 0..25 {
        let raw = space.decode(&space.random(&mut rng));
        for (wa, wb) in set_a.workloads.iter().zip(&set_b.workloads) {
            let a = ev.evaluate(&raw, wa);
            let b = ev.evaluate(&raw, wb);
            let c = ev.evaluate(&raw, &wa.clone());
            assert_eq!(a.energy.to_bits(), b.energy.to_bits());
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
            assert_eq!(a.energy.to_bits(), c.energy.to_bits());
            assert_eq!(a.latency.to_bits(), c.latency.to_bits());
            assert_eq!(a.feasible, b.feasible);
        }
    }
}
