//! Telemetry determinism contracts (the tentpole guarantee):
//!
//! * every report/journal/artifact is **byte-identical** with telemetry
//!   on vs off, and across `--threads 1` vs `--threads 8` — the
//!   subsystem is strictly out-of-band;
//! * trace JSONL and counter snapshots conform to their checked-in
//!   schemas, with wall-clock fields masked under `--stable`;
//! * counter totals are *exact* on a hand-sized run: a second identical
//!   `score_batch` is 100% memo hits, and the surrogate screen accounts
//!   for every pooled candidate (accepted = λ, rejected = pool − λ);
//! * a notice recorded twice renders once in report notes, with an
//!   `(x2)` occurrence suffix — identically whether telemetry is on.
//!
//! The counters, the enabled flag, and the trace sink are process-wide
//! statics shared by every test in this binary, so all tests serialize
//! on one mutex and assert deltas from a fresh `telemetry::reset()`.

use imcopt::coordinator::{EvalBackend, ExpContext, JointProblem};
use imcopt::experiments;
use imcopt::model::MemoryTech;
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::search::{Problem, ScreenState};
use imcopt::space::{Design, SearchSpace};
use imcopt::telemetry;
use imcopt::util::rng::Rng;
use imcopt::util::{json, schema};
use imcopt::workloads::WorkloadSet;
use std::collections::{BTreeMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::{Mutex, MutexGuard};

static LOCK: Mutex<()> = Mutex::new(());

/// Poison-tolerant serialization: a failed test must not wedge the rest.
fn lock() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imcopt-telemetry-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

fn read_json(path: &Path) -> json::Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    json::parse(&text).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

/// Quick, stable context over `dir` — the same shape every determinism
/// suite in this repo uses.
fn ctx_at(seed: u64, dir: &Path, threads: usize) -> ExpContext {
    let mut c = ExpContext::quick(seed);
    c.out_dir = dir.to_path_buf();
    c.stable = true;
    c.threads = threads;
    c
}

/// Every emitted artifact below `dir`, keyed by relative path —
/// checkpoint internals and the out-of-band `telemetry/` directory
/// excluded (the latter legitimately differs: it does not exist at all
/// when telemetry is off).
fn artifacts(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).expect("readable dir") {
            let entry = entry.unwrap();
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().to_string();
            if path.is_dir() {
                if name == "checkpoints" || name == "telemetry" {
                    continue;
                }
                walk(root, &path, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().to_string();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

fn assert_identical(a: &BTreeMap<String, Vec<u8>>, b: &BTreeMap<String, Vec<u8>>, what: &str) {
    let names_a: Vec<&String> = a.keys().collect();
    let names_b: Vec<&String> = b.keys().collect();
    assert_eq!(names_a, names_b, "{what}: artifact sets differ");
    for (name, bytes) in a {
        assert_eq!(bytes, &b[name], "{what}: artifact {name} differs");
    }
}

// ---- out-of-band: byte-identity on/off and across thread counts -----------

#[test]
fn artifacts_byte_identical_with_telemetry_on_and_off() {
    let _g = lock();
    let dir_on = tmp("on");
    let dir_off = tmp("off");

    telemetry::reset();
    telemetry::set_enabled(true);
    let s_on = experiments::run_selected(&["fig3"], &ctx_at(13, &dir_on, 2)).unwrap();
    assert_eq!(s_on.executed, 1);

    telemetry::reset();
    telemetry::set_enabled(false);
    let off = experiments::run_selected(&["fig3"], &ctx_at(13, &dir_off, 2));
    telemetry::set_enabled(true);
    assert_eq!(off.unwrap().executed, 1);

    // enabled: the run leaves an out-of-band trace and a counter snapshot
    assert!(
        dir_on.join("telemetry").join("trace.jsonl").is_file(),
        "enabled run must write telemetry/trace.jsonl"
    );
    assert!(dir_on.join("telemetry").join("counters.json").is_file());
    // disabled: nothing — not even the directory
    assert!(
        !dir_off.join("telemetry").exists(),
        "IMCOPT_TELEMETRY=0 must not create the telemetry directory"
    );

    assert_identical(&artifacts(&dir_on), &artifacts(&dir_off), "telemetry on vs off");
}

#[test]
fn artifacts_and_trace_byte_identical_across_thread_counts() {
    let _g = lock();
    let dir_t1 = tmp("t1");
    let dir_t8 = tmp("t8");

    telemetry::set_enabled(true);
    telemetry::reset();
    experiments::run_selected(&["fig3"], &ctx_at(19, &dir_t1, 1)).unwrap();
    telemetry::reset();
    experiments::run_selected(&["fig3"], &ctx_at(19, &dir_t8, 8)).unwrap();

    assert_identical(&artifacts(&dir_t1), &artifacts(&dir_t8), "threads 1 vs 8");

    // the trace itself is thread-count invariant under --stable: wall
    // clock is masked and every traced quantity derives from seeded state
    let t1 = std::fs::read(dir_t1.join("telemetry").join("trace.jsonl")).unwrap();
    let t8 = std::fs::read(dir_t8.join("telemetry").join("trace.jsonl")).unwrap();
    assert!(!t1.is_empty(), "a GA run must emit trace events");
    assert_eq!(t1, t8, "trace events must not depend on the thread count");
}

// ---- schema conformance ---------------------------------------------------

#[test]
fn trace_and_counter_snapshots_conform_to_their_schemas() {
    let _g = lock();
    let dir = tmp("schema");
    telemetry::set_enabled(true);
    telemetry::reset();
    experiments::run_selected(&["fig3"], &ctx_at(23, &dir, 2)).unwrap();

    let trace_schema = read_json(&repo_path("schemas/telemetry_trace.schema.json"));
    let text = std::fs::read_to_string(dir.join("telemetry").join("trace.jsonl")).unwrap();
    let mut generations = 0usize;
    for (i, line) in text.lines().enumerate() {
        let doc = json::parse(line).unwrap_or_else(|e| panic!("trace line {i}: {e}"));
        let errs = schema::validate(&trace_schema, &doc);
        assert!(errs.is_empty(), "trace line {i}: {errs:?}");
        assert!(
            doc.get("wall_ms").is_none(),
            "--stable must mask wall_ms (trace line {i})"
        );
        if doc.get("event").and_then(|e| e.as_str()) == Some("generation") {
            generations += 1;
        }
    }
    assert!(generations > 0, "a GA experiment must emit generation events");

    let counters_schema = read_json(&repo_path("schemas/telemetry_counters.schema.json"));
    let doc = read_json(&dir.join("telemetry").join("counters.json"));
    let errs = schema::validate(&counters_schema, &doc);
    assert!(errs.is_empty(), "counters.json: {errs:?}");
    // a cell-checkpointed GA run exercises the eval and journal paths
    let c = doc.get("counters").expect("counters object");
    for key in ["exact_evals", "journal_appends", "cells_computed"] {
        let v = c.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
        assert!(v > 0.0, "counter {key} stayed zero over a full experiment");
    }
}

// ---- exact counter totals on a hand-sized run -----------------------------

#[test]
fn counter_totals_are_exact_on_a_hand_sized_run() {
    let _g = lock();
    telemetry::set_enabled(true);
    telemetry::uninstall_sink();
    telemetry::reset();

    let space = SearchSpace::rram_reduced();
    let set = WorkloadSet::cnn4();
    let obj = Objective::new(ObjectiveKind::Edap, Aggregation::Max);
    let problem =
        JointProblem::with_backend(&space, &set, EvalBackend::native(MemoryTech::Rram), obj)
            .with_threads(2);

    // 12 pairwise-distinct designs: every memo key misses exactly once,
    // then hits exactly once
    let mut rng = Rng::seed_from(7);
    let mut seen: HashSet<Design> = HashSet::new();
    let mut batch: Vec<Design> = Vec::new();
    while batch.len() < 12 {
        let d = space.random(&mut rng);
        if seen.insert(d.clone()) {
            batch.push(d);
        }
    }

    let c = telemetry::counters();
    let hits =
        || c.eval_memo_hits.iter().map(|s| s.load(Ordering::Relaxed)).sum::<u64>();

    let h0 = hits();
    let m0 = c.eval_memo_misses.load(Ordering::Relaxed);
    let e0 = c.exact_evals.load(Ordering::Relaxed);
    let s1 = problem.score_batch(&batch);
    assert_eq!(hits(), h0, "a cold memo cannot hit");
    assert_eq!(c.eval_memo_misses.load(Ordering::Relaxed), m0 + 12);
    assert_eq!(c.exact_evals.load(Ordering::Relaxed), e0 + 12);

    let h1 = hits();
    let s2 = problem.score_batch(&batch);
    assert_eq!(hits(), h1 + 12, "a second identical batch must be 100% memo hits");
    assert_eq!(c.eval_memo_misses.load(Ordering::Relaxed), m0 + 12, "no new misses");
    assert_eq!(c.exact_evals.load(Ordering::Relaxed), e0 + 12, "no re-evaluation");
    for (i, (a, b)) in s1.iter().zip(&s2).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "memoized score[{i}] diverged");
    }

    // the surrogate screen accounts for every pooled candidate:
    // exactly λ accepted, exactly pool − λ screened out
    let mut screen = ScreenState::new(0.25).expect("frac < 1 enables screening");
    screen.observe(&space, &batch, &s1);
    let a0 = c.screen_accepted.load(Ordering::Relaxed);
    let r0 = c.screened_out.load(Ordering::Relaxed);
    let mut rng2 = Rng::seed_from(11);
    let pool: Vec<Design> = (0..16).map(|_| space.random(&mut rng2)).collect();
    let lambda = 4usize;
    let kept = screen.select(&space, pool, lambda);
    assert_eq!(kept.len(), lambda);
    assert_eq!(c.screen_accepted.load(Ordering::Relaxed), a0 + lambda as u64);
    assert_eq!(c.screened_out.load(Ordering::Relaxed), r0 + (16 - lambda) as u64);
}

// ---- notice occurrence rendering ------------------------------------------

#[test]
fn repeated_notices_render_once_with_an_occurrence_suffix() {
    let _g = lock();
    let dir = tmp("notices");
    telemetry::set_enabled(true);
    telemetry::reset();

    let ctx = ctx_at(31, &dir, 2);
    let probe = "telemetry-test: synthetic degradation notice";
    ctx.record_notice(probe.to_string());
    ctx.record_notice(probe.to_string());
    // the context stores the notice once...
    assert_eq!(ctx.notices().iter().filter(|n| n.as_str() == probe).count(), 1);

    experiments::run_selected(&["fig3"], &ctx).unwrap();

    // ...and the report renders it once, carrying the occurrence count
    let arts = artifacts(&dir);
    let (name, bytes) = arts
        .iter()
        .find(|(k, _)| k.ends_with("fig3.json"))
        .expect("fig3 report emitted");
    let report = String::from_utf8_lossy(bytes);
    let suffixed = format!("{probe} (x2)");
    assert!(report.contains(&suffixed), "{name} missing `{suffixed}`: {report}");
    assert_eq!(
        report.matches(probe).count(),
        1,
        "{name} must carry the notice exactly once"
    );
}
