//! Scenario-portfolio integration:
//!
//! * the checked-in experiment catalog (`docs/experiments.md`) cannot
//!   drift from `experiments::REGISTRY` (bless with `IMCOPT_BLESS=1`),
//!   and `catalog_json` conforms to `schemas/registry.schema.json`;
//! * the `k = 1` slice of `genmatrix_k` reproduces `genmatrix` bit for
//!   bit (same seeds, same GA configuration, same gap arithmetic);
//! * the portfolio experiments (`genmatrix_k`, `transfer`) emit
//!   schema-valid per-portfolio cells and, after a simulated mid-flight
//!   kill, resume to byte-identical artifacts.

use imcopt::coordinator::ExpContext;
use imcopt::experiments::{self, checkpoint::Checkpoint};
use imcopt::util::{json, schema};
use imcopt::workloads::WorkloadSet;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imcopt-portfolio-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

/// Quick, stable, hold-1-out context (the cheapest portfolio sweep).
fn ctx_at(seed: u64, dir: &Path, resume: bool) -> ExpContext {
    let mut c = ExpContext::quick(seed);
    c.out_dir = dir.to_path_buf();
    c.stable = true;
    c.resume = resume;
    c.hold_k = 1;
    c
}

#[test]
fn catalog_in_docs_matches_registry() {
    let path = repo_path("docs/experiments.md");
    let generated = experiments::catalog_markdown();
    if std::env::var("IMCOPT_BLESS").is_ok() {
        std::fs::write(&path, &generated).unwrap();
        return;
    }
    let on_disk = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    assert_eq!(
        on_disk, generated,
        "docs/experiments.md drifted from experiments::REGISTRY; regenerate \
         with `imcopt list --markdown > docs/experiments.md` (or \
         IMCOPT_BLESS=1 cargo test --test scenario_portfolios)"
    );
}

#[test]
fn catalog_json_conforms_to_registry_schema() {
    let schema_doc = json::parse(
        &std::fs::read_to_string(repo_path("schemas/registry.schema.json")).unwrap(),
    )
    .unwrap();
    // through the serialized form, exactly as `imcopt list --json` emits it
    let doc = json::parse(&experiments::catalog_json().to_string()).unwrap();
    let errs = schema::validate(&schema_doc, &doc);
    assert!(errs.is_empty(), "catalog violates registry schema: {errs:?}");
    assert_eq!(
        doc.get("count").and_then(|c| c.as_usize()),
        Some(experiments::REGISTRY.len())
    );
}

fn read_json(path: &Path) -> json::Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    json::parse(&text).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

fn f64_at<'a>(doc: &'a json::Json, keys: &[&str]) -> f64 {
    let mut v = doc;
    for k in keys {
        v = v.get(k).unwrap_or_else(|| panic!("missing '{k}'"));
    }
    v.as_f64_lenient().expect("numeric field")
}

#[test]
fn genmatrix_k1_slice_matches_genmatrix_bit_for_bit() {
    let dir = tmp("k1");
    let ctx = ctx_at(47, &dir, false);
    experiments::run("genmatrix", &ctx).unwrap();
    experiments::run("genmatrix_k", &ctx).unwrap();
    for (set, ws) in [("cnn4", WorkloadSet::cnn4()), ("all9", WorkloadSet::all9())] {
        for (wi, w) in ws.workloads.iter().enumerate() {
            let gm = read_json(
                &dir.join("genmatrix_cells").join(format!("{set}-{}.json", w.name)),
            );
            let pk = read_json(
                &dir.join("genmatrix_k_cells").join(format!("{set}-k1-{wi}.json")),
            );
            let gaps = pk.get("deploy_gaps").and_then(|g| g.as_arr()).unwrap();
            assert_eq!(gaps.len(), 1);
            assert_eq!(
                gaps[0].get("workload").and_then(|v| v.as_str()),
                Some(w.name.as_str()),
                "{set}:{wi} held-out workload mismatch"
            );
            // same joint search: identical score; same specialist bound;
            // identical deploy gap — bit for bit
            for (a, b) in [
                (
                    f64_at(&gm, &["joint", "joint_score"]),
                    f64_at(&pk, &["joint", "joint_score"]),
                ),
                (
                    f64_at(&gm, &["separate_bound", "edap"]),
                    f64_at(&gaps[0], &["edap_bound"]),
                ),
                (f64_at(&gm, &["gap"]), f64_at(&gaps[0], &["gap"])),
            ] {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{set}:{} k=1 slice diverged from genmatrix ({a} vs {b})",
                    w.name
                );
            }
        }
    }
}

/// Every emitted artifact (md/json/csv) below `dir`, keyed by relative
/// path — checkpoint internals excluded (journal layouts may differ
/// between an interrupted and a straight run; artifacts must not).
fn artifacts(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).expect("readable dir") {
            let entry = entry.unwrap();
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().to_string();
            if path.is_dir() {
                // out-of-band telemetry differs between straight/resumed runs
                if name == "checkpoints" || name == "telemetry" {
                    continue;
                }
                walk(root, &path, out);
            } else {
                let rel = path
                    .strip_prefix(root)
                    .unwrap()
                    .to_string_lossy()
                    .to_string();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

fn validate_cells(dir: &Path, schema_doc: &json::Json, expect_exp: &str) -> usize {
    let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .collect();
    paths.sort();
    let mut n = 0usize;
    for path in paths {
        let doc = read_json(&path);
        let errs = schema::validate(schema_doc, &doc);
        assert!(errs.is_empty(), "{}: {errs:?}", path.display());
        assert_eq!(
            doc.get("experiment").and_then(|v| v.as_str()),
            Some(expect_exp),
            "{}",
            path.display()
        );
        n += 1;
    }
    n
}

#[test]
fn portfolio_experiments_kill_resume_bit_identical() {
    const IDS: [&str; 2] = ["genmatrix_k", "transfer"];
    let dir_a = tmp("straight");
    let dir_b = tmp("killed");

    // reference: uninterrupted checkpointed run
    let summary_a = experiments::run_selected(&IDS, &ctx_at(29, &dir_a, false)).unwrap();
    assert_eq!(summary_a.executed, IDS.len());

    // straight-run artifacts are schema-valid portfolio cells
    let cell_schema = json::parse(
        &std::fs::read_to_string(repo_path("schemas/portfolio_cell.schema.json")).unwrap(),
    )
    .unwrap();
    assert_eq!(
        validate_cells(&dir_a.join("genmatrix_k_cells"), &cell_schema, "genmatrix_k"),
        13,
        "hold-1-out emits one cell per workload of each set (4 + 9)"
    );
    assert_eq!(
        validate_cells(&dir_a.join("transfer_cells"), &cell_schema, "transfer"),
        4,
        "three all9 portfolios plus the RRAM companion row"
    );

    // interrupted run: the simulated-kill hook stops genmatrix_k after
    // two fresh cells, leaving a partial journal exactly like a hard kill
    {
        let ctx = ctx_at(29, &dir_b, false);
        let mut ckpt =
            Checkpoint::for_experiment(&ctx.out_dir, "genmatrix_k", false).unwrap();
        ckpt.abort_after_cells = Some(2);
        let err = experiments::run_with("genmatrix_k", &ctx, &mut ckpt).unwrap_err();
        assert!(
            format!("{err:#}").contains("simulated kill"),
            "unexpected error: {err:#}"
        );
        assert_eq!(ckpt.computed(), 2);
    }

    // resume completes the partial experiment and runs the rest
    let summary_b = experiments::run_selected(&IDS, &ctx_at(29, &dir_b, true)).unwrap();
    assert_eq!(summary_b.executed, IDS.len(), "nothing was complete yet");
    assert!(
        summary_b.cells_reused >= 2,
        "the journaled genmatrix_k cells must be reused, not re-run"
    );

    // artifacts are byte-identical to the uninterrupted run
    let a = artifacts(&dir_a);
    let b = artifacts(&dir_b);
    let names_a: Vec<&String> = a.keys().collect();
    let names_b: Vec<&String> = b.keys().collect();
    assert_eq!(names_a, names_b, "artifact sets differ");
    assert!(
        a.keys().any(|k| k.contains("genmatrix_k_cells")),
        "expected portfolio cells, got {names_a:?}"
    );
    for (name, bytes_a) in &a {
        assert_eq!(
            bytes_a, &b[name],
            "artifact {name} differs between straight and resumed runs"
        );
    }

    // focused cross-experiment shared-bound check: wipe transfer's own
    // journals (keeping checkpoints/shared_bounds.jsonl, written by the
    // genmatrix_k leg and transfer's own straight run) and re-run
    // transfer alone with --resume. Its 9 all9 specialist bounds and 5
    // all9-rram bounds must all come from the shared `bound:<set>:<w>`
    // namespace — only the 4 portfolio joint searches may compute fresh.
    // If sharing regressed, this computes 18.
    for f in ["transfer.jsonl", "transfer.memo.jsonl", "transfer.acc.jsonl"] {
        let _ = std::fs::remove_file(dir_a.join("checkpoints").join(f));
    }
    let again = experiments::run_selected(&["transfer"], &ctx_at(29, &dir_a, true)).unwrap();
    assert_eq!(again.executed, 1, "transfer journal was deleted, so it re-runs");
    assert_eq!(
        again.cells_computed, 4,
        "all specialist bounds must replay from the shared namespace \
         (computed {}, reused {})",
        again.cells_computed, again.cells_reused
    );
    // ... and its artifacts come out byte-identical again
    let c = artifacts(&dir_a);
    assert_eq!(a.keys().collect::<Vec<_>>(), c.keys().collect::<Vec<_>>());
    for (name, bytes) in &a {
        assert_eq!(
            bytes, &c[name],
            "shared-bound replay changed artifact {name}"
        );
    }
}
