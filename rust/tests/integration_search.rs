//! Integration tests over the search stack on the real (native) hardware
//! evaluator: the paper's algorithmic claims at reduced-but-honest scale.

use imcopt::coordinator::{EvalBackend, JointProblem};
use imcopt::model::MemoryTech;
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::search::{
    Exhaustive, GaConfig, GeneticAlgorithm, InitStrategy, Optimizer, Problem, SearchBudget,
};
use imcopt::space::SearchSpace;
use imcopt::util::rng::Rng;
use imcopt::util::stats;
use imcopt::workloads::WorkloadSet;

fn problem<'a>(
    space: &'a SearchSpace,
    set: &'a WorkloadSet,
    mem: MemoryTech,
    objective: Objective,
) -> JointProblem<'a> {
    JointProblem::with_backend(space, set, EvalBackend::native(mem), objective)
}

/// The proposed 4-phase GA must find the exhaustive global minimum of the
/// reduced space (paper Table 3's GA row).
#[test]
fn four_phase_ga_reaches_reduced_space_global_minimum() {
    let space = SearchSpace::rram_reduced();
    let set = WorkloadSet::cnn4();
    let p = problem(&space, &set, MemoryTech::Rram, Objective::edap());
    let scored = Exhaustive::default().score_all(&p);
    let global = scored
        .iter()
        .map(|(_, s)| *s)
        .fold(f64::INFINITY, f64::min);
    assert!(global.is_finite());

    let ga = GeneticAlgorithm::new(GaConfig {
        init: InitStrategy::HammingDiverse { p_h: 150, p_e: 80 },
        ..GaConfig::four_phase(SearchBudget { pop: 20, gens: 16 })
    });
    let mut hits = 0;
    for seed in 0..3u64 {
        let r = ga.run(&p, &mut Rng::seed_from(seed));
        if r.best_score <= global * (1.0 + 1e-9) {
            hits += 1;
        }
    }
    assert!(hits >= 2, "GA hit global min only {hits}/3 times");
}

/// §IV-B at reduced scale: across seeds, the 4-phase GA's final scores
/// should have mean no worse than the classic GA's and (paper claim)
/// lower spread.
#[test]
fn four_phase_beats_classic_on_mean_across_seeds() {
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let budget = SearchBudget { pop: 16, gens: 12 };
    let seeds: Vec<u64> = (0..4).collect();
    let run = |cfg: GaConfig, seed: u64| {
        // fresh problem per run: no cache leakage between algorithms
        let p = problem(&space, &set, MemoryTech::Rram, Objective::edap());
        GeneticAlgorithm::new(cfg)
            .run(&p, &mut Rng::seed_from(seed))
            .best_score
    };
    let classic: Vec<f64> = seeds
        .iter()
        .map(|&s| run(GaConfig::classic(budget), s))
        .collect();
    let fourphase: Vec<f64> = seeds
        .iter()
        .map(|&s| {
            run(
                GaConfig {
                    init: InitStrategy::HammingDiverse { p_h: 200, p_e: 100 },
                    ..GaConfig::four_phase(budget)
                },
                s,
            )
        })
        .collect();
    assert!(
        stats::mean(&fourphase) <= stats::mean(&classic) * 1.02,
        "4-phase mean {} vs classic {} ({fourphase:?} vs {classic:?})",
        stats::mean(&fourphase),
        stats::mean(&classic)
    );
}

/// §IV-A at reduced scale: joint optimization must not lose to
/// largest-workload optimization on the joint objective, and should win
/// on at least one non-largest workload.
#[test]
fn joint_beats_largest_workload_on_joint_objective() {
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let objective = Objective::edap();
    let budget = SearchBudget { pop: 16, gens: 12 };
    let cfg = GaConfig {
        init: InitStrategy::HammingDiverse { p_h: 200, p_e: 100 },
        ..GaConfig::four_phase(budget)
    };

    let p_joint = problem(&space, &set, MemoryTech::Rram, objective);
    let joint = GeneticAlgorithm::new(cfg.clone()).run(&p_joint, &mut Rng::seed_from(9));

    let li = set.largest_by_total();
    let p_largest = problem(&space, &set, MemoryTech::Rram, objective).restricted(li);
    let largest = GeneticAlgorithm::new(cfg).run(&p_largest, &mut Rng::seed_from(9));

    // evaluate the largest-only design under the joint objective
    let joint_score_of_largest =
        p_joint.score_batch(std::slice::from_ref(&largest.best))[0];
    assert!(
        joint.best_score <= joint_score_of_largest * 1.001,
        "joint {} should beat largest-only {} on the joint objective",
        joint.best_score,
        joint_score_of_largest
    );
}

/// Aggregation schemes must all produce feasible designs and comparable
/// quality (§IV-C shape).
#[test]
fn aggregation_schemes_all_work() {
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let budget = SearchBudget { pop: 12, gens: 8 };
    let mut scores = Vec::new();
    for agg in [Aggregation::Max, Aggregation::All, Aggregation::Mean] {
        let objective = Objective::new(ObjectiveKind::Edap, agg);
        let p = problem(&space, &set, MemoryTech::Rram, objective);
        let cfg = GaConfig {
            init: InitStrategy::HammingDiverse { p_h: 100, p_e: 50 },
            ..GaConfig::four_phase(budget)
        };
        let r = GeneticAlgorithm::new(cfg).run(&p, &mut Rng::seed_from(11));
        assert!(r.best_score.is_finite(), "{agg:?} found nothing feasible");
        // report the design under plain EDAP for comparability
        let edap = Objective::edap();
        let ms = p.metrics_all_workloads(&r.best);
        scores.push(edap.score(&ms, None, 32.0));
    }
    let worst = stats::max(&scores);
    let best = stats::min(&scores);
    assert!(
        worst / best < 10.0,
        "aggregations should land within an order of magnitude: {scores:?}"
    );
}

/// SRAM designs swap weights: the optimizer must still find feasible
/// architectures for the 9-workload set (Fig. 10 substrate).
#[test]
fn sram_nine_workload_search_is_feasible() {
    let space = SearchSpace::sram();
    let set = WorkloadSet::all9();
    let objective = Objective::new(ObjectiveKind::Edap, Aggregation::Mean);
    let p = problem(&space, &set, MemoryTech::Sram, objective);
    let cfg = GaConfig {
        init: InitStrategy::HammingDiverse { p_h: 100, p_e: 50 },
        ..GaConfig::four_phase(SearchBudget { pop: 12, gens: 8 })
    };
    let r = GeneticAlgorithm::new(cfg).run(&p, &mut Rng::seed_from(13));
    assert!(r.best_score.is_finite());
    let ev = p.evaluate_design(&r.best);
    assert_eq!(ev.metrics.len(), 9);
    assert!(ev.metrics.iter().all(|m| m.feasible));
}

/// Determinism: the whole pipeline is seed-reproducible.
#[test]
fn searches_are_seed_deterministic() {
    let space = SearchSpace::rram();
    let set = WorkloadSet::cnn4();
    let run = |seed: u64| {
        let p = problem(&space, &set, MemoryTech::Rram, Objective::edap());
        let cfg = GaConfig {
            init: InitStrategy::HammingDiverse { p_h: 80, p_e: 40 },
            ..GaConfig::four_phase(SearchBudget { pop: 12, gens: 8 })
        };
        let r = GeneticAlgorithm::new(cfg).run(&p, &mut Rng::seed_from(seed));
        (r.best.clone(), r.best_score)
    };
    let (d1, s1) = run(99);
    let (d2, s2) = run(99);
    assert_eq!(d1, d2);
    assert_eq!(s1.to_bits(), s2.to_bits());
    let (_, s3) = run(100);
    // different seeds normally reach different (even if close) scores;
    // equality of all three would suggest the seed is ignored
    assert!(s1.to_bits() != s3.to_bits() || s1 == s3);
}
