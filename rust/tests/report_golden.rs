//! Golden-file tests for quick-budget JSON report artifacts.
//!
//! Two layers:
//!
//! 1. **Determinism** (always enforced): running an experiment twice with
//!    the same seed in fresh contexts must produce byte-identical
//!    `<id>.json` artifacts — the property checkpoint replay and the
//!    golden comparison both rest on.
//! 2. **Golden comparison**: when `rust/tests/golden/<id>.quick.json`
//!    exists (or `IMCOPT_GOLDEN_DIR` points elsewhere), the artifact must
//!    match it byte-for-byte. Bless goldens with `IMCOPT_BLESS=1`;
//!    `ci.sh` blesses into a scratch dir and re-verifies in a second
//!    process, catching any cross-process nondeterminism (hash ordering,
//!    ASLR-dependent iteration, ...).

use imcopt::coordinator::ExpContext;
use imcopt::experiments;
use std::path::PathBuf;

const GOLDEN_SEED: u64 = 5;

fn quick_artifact(id: &str, tag: &str) -> String {
    let mut ctx = ExpContext::quick(GOLDEN_SEED);
    ctx.stable = true;
    ctx.out_dir = std::env::temp_dir().join(format!("imcopt-golden-{id}-{tag}"));
    let _ = std::fs::remove_dir_all(&ctx.out_dir);
    experiments::run(id, &ctx).unwrap_or_else(|e| panic!("{id}: {e:#}"));
    std::fs::read_to_string(ctx.out_dir.join(format!("{id}.json")))
        .unwrap_or_else(|e| panic!("{id}.json missing: {e}"))
}

fn golden_dir() -> PathBuf {
    std::env::var("IMCOPT_GOLDEN_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden")
        })
}

fn check_golden(id: &str) {
    let artifact = quick_artifact(id, "a");
    let again = quick_artifact(id, "b");
    assert_eq!(
        artifact, again,
        "{id}: quick JSON artifact must be deterministic for a fixed seed"
    );

    let golden_path = golden_dir().join(format!("{id}.quick.json"));
    if golden_path.exists() {
        let want = std::fs::read_to_string(&golden_path).unwrap();
        assert_eq!(
            artifact,
            want,
            "{id}: artifact diverged from {} (re-bless with IMCOPT_BLESS=1 \
             if the change is intended)",
            golden_path.display()
        );
    } else if std::env::var("IMCOPT_BLESS").is_ok() {
        std::fs::create_dir_all(golden_path.parent().unwrap()).unwrap();
        std::fs::write(&golden_path, &artifact).unwrap();
        eprintln!("blessed {}", golden_path.display());
    } else {
        eprintln!(
            "note: no golden at {} — run with IMCOPT_BLESS=1 to create it",
            golden_path.display()
        );
    }
}

#[test]
fn fig3_quick_json_deterministic_and_golden() {
    check_golden("fig3");
}

#[test]
fn table5_quick_json_deterministic_and_golden() {
    check_golden("table5");
}

/// The population experiment's default family is `synth:mixed:200:<seed>`
/// — this doubles as the determinism gate for the synthetic generator at
/// full portfolio scale (200 nets, twice, byte-identical).
#[test]
fn population_quick_json_deterministic_and_golden() {
    check_golden("population");
}
