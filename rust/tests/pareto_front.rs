//! Pareto subsystem integration:
//!
//! * property tests for the front invariants — non-dominated sorting is
//!   mutually non-dominating and rank-complete, hypervolume is monotone
//!   under adding a dominating point;
//! * the acceptance check of the `pareto` experiment: on cnn4/RRAM at
//!   the quick budget, the minimum-EDAP corner of the NSGA-II front
//!   matches the scalarized four-phase GA best within 5% at an equal
//!   evaluation budget;
//! * determinism: the experiment's front artifacts are schema-valid and
//!   bit-identical across `--threads 1` vs `--threads 8` and across a
//!   simulated mid-run kill + `--resume` replay (the
//!   `checkpoint_resume.rs` pattern).

use imcopt::coordinator::ExpContext;
use imcopt::experiments::{self, checkpoint::Checkpoint};
use imcopt::pareto::{indicators, sort, MooMode, MooProblem, MultiObjectiveOptimizer};
use imcopt::prelude::*;
use imcopt::util::{json, proptest, schema};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imcopt-pareto-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn repo_path(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel)
}

// ---- front invariants (property tests) ------------------------------------

/// Random point cloud: dims in 2..=4, coords from a small grid so
/// duplicates and per-axis ties actually occur.
fn random_points(rng: &mut Rng) -> Vec<Vec<f64>> {
    let dims = 2 + rng.below(3);
    let n = 1 + rng.below(40);
    (0..n)
        .map(|_| (0..dims).map(|_| rng.below(6) as f64).collect())
        .collect()
}

#[test]
fn property_sort_is_rank_complete_and_mutually_non_dominating() {
    proptest::check("non-dominated sort invariants", 120, |rng| {
        let points = random_points(rng);
        let fronts = sort::non_dominated_sort(&points);
        // rank-complete: every index in exactly one front
        let mut seen = vec![0usize; points.len()];
        for front in &fronts {
            for &i in front {
                seen[i] += 1;
            }
        }
        if seen.iter().any(|&c| c != 1) {
            return Err(format!("indices not partitioned: {seen:?}"));
        }
        for (r, front) in fronts.iter().enumerate() {
            // mutually non-dominating within a front
            for &i in front {
                for &j in front {
                    if i != j && sort::dominates(&points[i], &points[j]) {
                        return Err(format!("front {r}: {i} dominates {j}"));
                    }
                }
            }
            // every member of front r >= 1 is dominated by someone in
            // front r - 1 (and nothing in r or beyond dominates front 0)
            if r > 0 {
                for &i in front {
                    let covered = fronts[r - 1]
                        .iter()
                        .any(|&j| sort::dominates(&points[j], &points[i]));
                    if !covered {
                        return Err(format!("front {r} member {i} uncovered"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn property_hypervolume_monotone_under_dominating_point() {
    proptest::check("hypervolume monotonicity", 60, |rng| {
        let dims = 2 + rng.below(3);
        let n = 1 + rng.below(12);
        let points: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..dims).map(|_| 0.1 + 0.9 * rng.f64()).collect())
            .collect();
        let reference = vec![2.0f64; dims];
        let base = indicators::hypervolume(&points, &reference);
        // a point strictly dominating a random member
        let q = &points[rng.below(points.len())];
        let dominating: Vec<f64> = q.iter().map(|&x| x / 2.0).collect();
        let mut more = points.clone();
        more.push(dominating);
        let grown = indicators::hypervolume(&more, &reference);
        // monotone: the dominated region can only grow (strict growth is
        // not guaranteed — another member may already dominate the new
        // point's region)
        if grown + 1e-12 < base {
            return Err(format!("hv shrank: {base} -> {grown} (dims {dims})"));
        }
        Ok(())
    });
}

// ---- acceptance: NSGA-II corner vs scalarized GA --------------------------

#[test]
fn nsga2_min_edap_corner_matches_scalarized_ga_within_5pct() {
    let ctx = ExpContext::quick(17);
    let spec = imcopt::scenarios::ScenarioSpec::cnn4();
    let problem = ctx.problem(&spec.space, &spec.set, spec.mem, spec.objective());
    let (p_h, p_e) = ctx.sampling();
    let seed = 17u64;

    // scalarized four-phase GA at the quick budget
    let ga_cfg = GaConfig {
        init: imcopt::search::InitStrategy::HammingDiverse { p_h, p_e },
        ..GaConfig::four_phase(ctx.budget())
    };
    let ga = GeneticAlgorithm::new(ga_cfg).run(&problem, &mut Rng::seed_from(seed));
    assert!(ga.best_score.is_finite(), "GA found no feasible design");

    // NSGA-II in metric mode: same budget, same sampling pools, same seed
    // (identical Hamming-sampled initial population)
    let moo = MooProblem::new(&problem, MooMode::Metric);
    let nsga = Nsga2::new(Nsga2Config {
        init: imcopt::search::InitStrategy::HammingDiverse { p_h, p_e },
        cap: 128,
        ..Nsga2Config::paper(ctx.budget())
    });
    let mr = nsga.run(&moo, &mut Rng::seed_from(seed));
    assert!(!mr.front.is_empty(), "empty front");

    // equal evaluation budget, by construction
    assert_eq!(
        ga.evals, mr.evals,
        "GA and NSGA-II must consume the same evaluation budget"
    );

    // the min-EDAP corner: metric-mode axis product == scalar EDAP
    let corner = mr
        .front
        .iter()
        .map(|(_, o)| o.iter().product::<f64>())
        .fold(f64::INFINITY, f64::min);
    assert!(corner.is_finite());
    assert!(
        corner <= ga.best_score * 1.05,
        "NSGA-II corner {corner} vs GA best {} (ratio {:.3})",
        ga.best_score,
        corner / ga.best_score
    );
}

// ---- experiment determinism (threads + kill/resume) -----------------------

fn ctx_at(seed: u64, dir: &Path, resume: bool, threads: usize) -> ExpContext {
    let mut c = ExpContext::quick(seed);
    c.out_dir = dir.to_path_buf();
    c.stable = true;
    c.resume = resume;
    c.threads = threads;
    c
}

/// Every emitted artifact below `dir`, keyed by relative path —
/// checkpoint internals excluded (journal layouts may differ between an
/// interrupted and a straight run; artifacts must not).
fn artifacts(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).expect("readable dir") {
            let entry = entry.unwrap();
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().to_string();
            if path.is_dir() {
                // out-of-band telemetry differs between straight/resumed runs
                if name == "checkpoints" || name == "telemetry" {
                    continue;
                }
                walk(root, &path, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().to_string();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

fn assert_identical(a: &BTreeMap<String, Vec<u8>>, b: &BTreeMap<String, Vec<u8>>, what: &str) {
    let names_a: Vec<&String> = a.keys().collect();
    let names_b: Vec<&String> = b.keys().collect();
    assert_eq!(names_a, names_b, "{what}: artifact sets differ");
    for (name, bytes) in a {
        assert_eq!(bytes, &b[name], "{what}: artifact {name} differs");
    }
}

#[test]
fn pareto_fronts_are_schema_valid_and_thread_invariant() {
    let dir_t1 = tmp("t1");
    let dir_t8 = tmp("t8");
    let s1 = experiments::run_selected(&["pareto"], &ctx_at(29, &dir_t1, false, 1)).unwrap();
    assert_eq!(s1.executed, 1);
    let _ = experiments::run_selected(&["pareto"], &ctx_at(29, &dir_t8, false, 8)).unwrap();

    // schema conformance of every front artifact
    let schema_doc = json::parse(
        &std::fs::read_to_string(repo_path("schemas/pareto_front.schema.json")).unwrap(),
    )
    .unwrap();
    let fronts_dir = dir_t1.join("pareto_fronts");
    let mut n = 0usize;
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&fronts_dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    paths.sort();
    for path in paths {
        let doc = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let errs = schema::validate(&schema_doc, &doc);
        assert!(errs.is_empty(), "{}: {errs:?}", path.display());
        n += 1;
    }
    assert_eq!(n, 4, "2 sets x 2 modes");

    // bit-identical fronts and reports at any worker-thread count
    assert_identical(&artifacts(&dir_t1), &artifacts(&dir_t8), "threads 1 vs 8");
}

#[test]
fn pareto_kill_resume_replays_bit_identical() {
    let dir_a = tmp("straight");
    let dir_b = tmp("killed");

    let summary_a = experiments::run_selected(&["pareto"], &ctx_at(31, &dir_a, false, 1)).unwrap();
    assert_eq!(summary_a.executed, 1);

    // interrupted run: the simulated-kill hook stops after two fresh
    // cells (the cnn4 GA reference + one front), like a hard kill
    {
        let ctx = ctx_at(31, &dir_b, false, 1);
        let mut ckpt = Checkpoint::for_experiment(&ctx.out_dir, "pareto", false).unwrap();
        ckpt.abort_after_cells = Some(2);
        let err = experiments::run_with("pareto", &ctx, &mut ckpt).unwrap_err();
        assert!(
            format!("{err:#}").contains("simulated kill"),
            "unexpected error: {err:#}"
        );
        assert_eq!(ckpt.computed(), 2);
    }

    let summary_b = experiments::run_selected(&["pareto"], &ctx_at(31, &dir_b, true, 1)).unwrap();
    assert_eq!(summary_b.executed, 1, "the experiment was not complete yet");
    assert!(
        summary_b.cells_reused >= 2,
        "journaled cells must be reused, not re-run"
    );
    assert_eq!(
        summary_b.cells_computed + summary_b.cells_reused,
        summary_a.cells_computed,
        "resume must account for every cell of a straight run"
    );

    let a = artifacts(&dir_a);
    assert!(
        a.keys().any(|k| k.contains("pareto_fronts")),
        "expected front artifacts, got {:?}",
        a.keys().collect::<Vec<_>>()
    );
    assert_identical(&a, &artifacts(&dir_b), "straight vs killed+resumed");
}
