//! Robust-objective determinism contracts:
//!
//! * robust score batches (`--robust` aggregates over a perturbation
//!   ensemble) are bit-identical across worker-thread counts;
//! * the `robustness` experiment, run with a robust mode, resumes after
//!   a completed run with ZERO recomputed cells and byte-identical
//!   artifacts;
//! * with `--robust` unset, a non-accuracy experiment's artifacts are
//!   byte-identical whether or not the flag is present in the context —
//!   the robust machinery is invisible to every default loop.

use imcopt::coordinator::{EvalBackend, ExpContext, JointProblem};
use imcopt::experiments;
use imcopt::model::MemoryTech;
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::robustness::{RobustConfig, RobustMode};
use imcopt::search::Problem;
use imcopt::space::{Design, SearchSpace};
use imcopt::util::rng::Rng;
use imcopt::workloads::WorkloadSet;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("imcopt-robustness-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn robust_problem<'a>(
    space: &'a SearchSpace,
    set: &'a WorkloadSet,
    threads: usize,
    seed: u64,
) -> JointProblem<'a> {
    let obj = Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max);
    let rc = RobustConfig::from_flag("cvar0.5", seed, 3).unwrap();
    JointProblem::with_backend(space, set, EvalBackend::native(MemoryTech::Rram), obj)
        .with_threads(threads)
        .with_robust(Some(rc))
}

#[test]
fn robust_scores_are_thread_count_invariant() {
    let space = SearchSpace::rram_reduced();
    let set = WorkloadSet::cnn4();
    let p1 = robust_problem(&space, &set, 1, 11);
    let p8 = robust_problem(&space, &set, 8, 11);
    let mut rng = Rng::seed_from(11);
    let batch: Vec<Design> = (0..24).map(|_| space.random(&mut rng)).collect();
    let s1 = p1.score_batch(&batch);
    let s8 = p8.score_batch(&batch);
    for (i, (a, b)) in s1.iter().zip(&s8).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "robust score[{i}] diverged: {a} vs {b}");
    }
    // the pert-id-extended accuracy memos agree entry for entry
    let a1 = p1.acc_snapshot();
    let a8 = p8.acc_snapshot();
    assert_eq!(a1.len(), a8.len());
    for ((k1, v1), (k8, v8)) in a1.iter().zip(&a8) {
        assert_eq!(k1, k8);
        assert_eq!(v1.to_bits(), v8.to_bits());
    }
}

#[test]
fn robust_mode_aggregates_match_hand_rolled() {
    // CVaR over an ensemble of member scores matches a by-hand fold on
    // the same members — pinned here against an independent computation
    let mut xs = [3.0, 1.0, 4.0, 1.5, 9.0, 2.5];
    assert_eq!(RobustMode::Worst.aggregate(&mut xs.clone()), 9.0);
    let m = RobustMode::Mean.aggregate(&mut xs.clone());
    assert!((m - xs.iter().sum::<f64>() / 6.0).abs() < 1e-12);
    // q=0.5 of 6 -> mean of the worst 3 = (9 + 4 + 3) / 3
    let c = RobustMode::Cvar(0.5).aggregate(&mut xs);
    assert!((c - 16.0 / 3.0).abs() < 1e-12, "{c}");
}

/// Every emitted artifact below `dir`, checkpoint internals excluded.
fn artifacts(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for entry in std::fs::read_dir(dir).expect("readable dir") {
            let entry = entry.unwrap();
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().to_string();
            if path.is_dir() {
                // out-of-band telemetry differs between straight/resumed runs
                if name == "checkpoints" || name == "telemetry" {
                    continue;
                }
                walk(root, &path, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().to_string();
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

fn robust_ctx(seed: u64, dir: &Path, resume: bool, threads: usize) -> ExpContext {
    let mut c = ExpContext::quick(seed);
    c.out_dir = dir.to_path_buf();
    c.stable = true;
    c.resume = resume;
    c.threads = threads;
    c.robust = Some("worst".into());
    c
}

#[test]
fn robustness_experiment_resumes_with_zero_recompute() {
    let dir = tmp("resume");
    let first = experiments::run_selected(&["robustness"], &robust_ctx(17, &dir, false, 2))
        .unwrap();
    assert_eq!(first.executed, 1);
    assert!(first.cells_computed > 0);
    let a = artifacts(&dir);
    assert!(
        a.keys().any(|k| k.contains("robustness_cells/gap.json")),
        "missing gap cell: {:?}",
        a.keys().collect::<Vec<_>>()
    );

    // resume replays the stored report and recomputes nothing, at a
    // different thread count
    let second = experiments::run_selected(&["robustness"], &robust_ctx(17, &dir, true, 8))
        .unwrap();
    assert_eq!(second.replayed, 1, "completed report must replay");
    assert_eq!(second.executed, 0);
    assert_eq!(second.cells_computed, 0, "zero recompute on resume");
    let b = artifacts(&dir);
    assert_eq!(a.keys().collect::<Vec<_>>(), b.keys().collect::<Vec<_>>());
    for (name, bytes) in &a {
        assert_eq!(bytes, &b[name], "artifact {name} differs after resume");
    }
}

#[test]
fn robust_flag_changes_robustness_artifacts_but_not_plain_experiments() {
    // fig9 never scores an accuracy-aware objective: its artifacts must
    // be byte-identical with and without --robust
    let dir_off = tmp("fig9-off");
    let dir_on = tmp("fig9-on");
    let mut ctx_off = ExpContext::quick(23);
    ctx_off.out_dir = dir_off.clone();
    ctx_off.stable = true;
    let mut ctx_on = ExpContext::quick(23);
    ctx_on.out_dir = dir_on.clone();
    ctx_on.stable = true;
    ctx_on.robust = Some("cvar0.25".into());
    experiments::run("fig9", &ctx_off).unwrap();
    experiments::run("fig9", &ctx_on).unwrap();
    let a = artifacts(&dir_off);
    let b = artifacts(&dir_on);
    assert_eq!(a.keys().collect::<Vec<_>>(), b.keys().collect::<Vec<_>>());
    for (name, bytes) in &a {
        assert_eq!(bytes, &b[name], "--robust leaked into plain artifact {name}");
    }

    // the robustness experiment, by contrast, must honor the mode: its
    // gap cell records the configured aggregate
    let dir_r = tmp("mode-honored");
    let mut ctx_r = robust_ctx(23, &dir_r, false, 2);
    ctx_r.robust = Some("cvar0.25".into());
    experiments::run_selected(&["robustness"], &ctx_r).unwrap();
    let gap = std::fs::read_to_string(dir_r.join("robustness_cells/gap.json")).unwrap();
    assert!(gap.contains("cvar0.25@ens-s23-k2"), "gap cell: {gap}");
}
