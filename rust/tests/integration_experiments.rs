//! Smoke-level integration of every paper experiment in `--quick` mode:
//! each must run, emit its report files, and keep its paper-shape notes.

use imcopt::coordinator::ExpContext;
use imcopt::experiments;

fn ctx(seed: u64) -> ExpContext {
    let mut c = ExpContext::quick(seed);
    c.out_dir = std::env::temp_dir().join(format!("imcopt-exp-it-{seed}"));
    c
}

#[test]
fn every_experiment_runs_quick() {
    // one shared seed keeps total time bounded; individual experiments
    // have their own focused tests in their modules
    let ctx = ctx(5);
    for id in experiments::ALL_IDS {
        let report = experiments::run(id, &ctx)
            .unwrap_or_else(|e| panic!("experiment {id} failed: {e:#}"));
        assert!(!report.tables.is_empty(), "{id} produced no tables");
        assert!(
            ctx.out_dir.join(format!("{id}.md")).exists(),
            "{id} did not persist markdown"
        );
    }
}

#[test]
fn unknown_experiment_is_rejected() {
    let err = experiments::run("fig99", &ctx(6)).unwrap_err();
    assert!(format!("{err}").contains("unknown experiment"));
}

#[test]
fn reports_are_parseable_csv() {
    let ctx = ctx(7);
    let report = experiments::run("fig3", &ctx).unwrap();
    for t in &report.tables {
        let csv = t.to_csv();
        let header_cols = csv.lines().next().unwrap().split(',').count();
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), header_cols, "ragged CSV: {line}");
        }
    }
}
