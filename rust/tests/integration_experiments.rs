//! Smoke-level integration of every registered experiment in `--quick`
//! mode: each must run, emit its report files, and produce a JSON
//! artifact conforming to `schemas/experiment_report.schema.json`.

use imcopt::coordinator::ExpContext;
use imcopt::experiments;
use imcopt::util::{json, schema};

fn ctx(seed: u64) -> ExpContext {
    let mut c = ExpContext::quick(seed);
    c.out_dir = std::env::temp_dir().join(format!("imcopt-exp-it-{seed}"));
    c
}

#[test]
fn every_experiment_runs_quick() {
    // one shared seed keeps total time bounded; individual experiments
    // have their own focused tests in their modules
    let ctx = ctx(5);
    let schema_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("schemas/experiment_report.schema.json");
    let report_schema =
        json::parse(&std::fs::read_to_string(&schema_path).unwrap()).unwrap();
    for id in experiments::ALL_IDS {
        let report = experiments::run(id, &ctx)
            .unwrap_or_else(|e| panic!("experiment {id} failed: {e:#}"));
        assert!(!report.tables.is_empty(), "{id} produced no tables");
        assert!(
            ctx.out_dir.join(format!("{id}.md")).exists(),
            "{id} did not persist markdown"
        );
        // machine-readable artifact: present, parseable, schema-conforming
        let artifact_path = ctx.out_dir.join(format!("{id}.json"));
        let text = std::fs::read_to_string(&artifact_path)
            .unwrap_or_else(|e| panic!("{id} did not persist JSON: {e}"));
        let doc = json::parse(&text).unwrap_or_else(|e| panic!("{id}.json: {e}"));
        let errs = schema::validate(&report_schema, &doc);
        assert!(errs.is_empty(), "{id}.json violates schema: {errs:?}");
        assert_eq!(doc.get("id").and_then(|v| v.as_str()), Some(id));
    }
    // the genmatrix sweep additionally emits one JSON cell per held-out
    // workload of each set (4 + 9)
    let cells: Vec<_> = std::fs::read_dir(ctx.out_dir.join("genmatrix_cells"))
        .expect("genmatrix_cells dir")
        .collect();
    assert_eq!(cells.len(), 13, "expected 13 hold-one-out cells");
}

#[test]
fn registry_ids_are_unique_and_resolvable() {
    let mut seen = std::collections::BTreeSet::new();
    for exp in experiments::REGISTRY {
        assert!(seen.insert(exp.id()), "duplicate id {}", exp.id());
        assert!(experiments::by_id(exp.id()).is_some());
    }
    assert!(experiments::by_id("fig99").is_none());
}

#[test]
fn unknown_experiment_is_rejected() {
    let err = experiments::run("fig99", &ctx(6)).unwrap_err();
    assert!(format!("{err}").contains("unknown experiment"));
}

#[test]
fn reports_are_parseable_csv() {
    let ctx = ctx(7);
    let report = experiments::run("fig3", &ctx).unwrap();
    for t in &report.tables {
        let csv = t.to_csv();
        let header_cols = csv.lines().next().unwrap().split(',').count();
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), header_cols, "ragged CSV: {line}");
        }
    }
}
