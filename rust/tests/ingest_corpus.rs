//! Parser corpus tests: every file under `rust/tests/ingest/valid/`
//! parses and round-trips bit-identically; every file under
//! `rust/tests/ingest/malformed/` yields the *expected typed*
//! [`IngestError`] — never a panic. The ONNX leg synthesizes real
//! protobuf wire bytes with a minimal in-test encoder (Conv / Gemm /
//! dynamic-MatMul models, plus every truncation prefix of a valid
//! model).

use imcopt::ingest::{
    load_path, parse_workload_text, workload_from_onnx, workload_to_json, IngestError,
    WorkloadDistribution,
};
use imcopt::workloads::LayerKind;
use std::path::{Path, PathBuf};

fn corpus(sub: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/ingest")
        .join(sub)
}

fn corpus_files(sub: &str) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(corpus(sub))
        .unwrap_or_else(|e| panic!("corpus dir {sub}: {e}"))
        .map(|e| e.unwrap().path())
        .collect();
    files.sort();
    assert!(!files.is_empty(), "empty corpus dir {sub}");
    files
}

/// Valid corpus: parses via the path-dispatch entry point, and the
/// canonical emission round-trips bit-identically (text → Workload →
/// text → Workload → text).
#[test]
fn valid_corpus_parses_and_round_trips_bit_identically() {
    for path in corpus_files("valid") {
        let w = load_path(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(!w.layers.is_empty());
        let text = workload_to_json(&w).to_string();
        let back = parse_workload_text(&text, "fallback")
            .unwrap_or_else(|e| panic!("{}: re-parse: {e}", path.display()));
        assert_eq!(
            text,
            workload_to_json(&back).to_string(),
            "{}: canonical JSON must be a fixed point",
            path.display()
        );
        assert_eq!(w.name, back.name);
        for (a, b) in w.layers.iter().zip(&back.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.kind, b.kind);
            assert_eq!(
                [a.k, a.n, a.passes, a.weights, a.in_bytes, a.out_bytes],
                [b.k, b.n, b.passes, b.weights, b.in_bytes, b.out_bytes]
            );
        }
    }
}

/// A document without a `name` key takes the file stem as its name.
#[test]
fn file_stem_is_the_fallback_name() {
    let w = load_path(&corpus("valid").join("unnamed.json")).unwrap();
    assert_eq!(w.name, "unnamed");
}

/// Malformed corpus: each file maps to its expected typed error —
/// checked per-file by name so a new corpus entry must declare what it
/// exercises — and none of them panic.
#[test]
fn malformed_corpus_yields_expected_typed_errors() {
    let mut seen = 0;
    for path in corpus_files("malformed") {
        let stem = path.file_stem().unwrap().to_str().unwrap().to_string();
        let err = load_path(&path)
            .expect_err(&format!("{stem} must be rejected"));
        let ok = match stem.as_str() {
            "truncated" => matches!(err, IngestError::Json(_)),
            "wrong_dtype" => matches!(err, IngestError::WrongType { .. }),
            "zero_dim" => matches!(err, IngestError::ZeroDim { .. }),
            "huge_dim" => matches!(err, IngestError::DimTooLarge { .. }),
            "unknown_kind" => matches!(err, IngestError::UnknownKind(_)),
            "empty_layers" => matches!(err, IngestError::BadLayerCount(0)),
            "dynamic_with_weights" => matches!(err, IngestError::DynamicWithWeights { .. }),
            "not_an_object" => matches!(err, IngestError::WrongType { .. }),
            "missing_field" => matches!(err, IngestError::Missing(_)),
            other => panic!("corpus file '{other}.json' has no expected-error entry"),
        };
        assert!(ok, "{stem}: unexpected error variant: {err}");
        // Display never panics and is prefixed for log grepping
        assert!(err.to_string().starts_with("ingest:"), "{err}");
        seen += 1;
    }
    assert!(seen >= 9, "malformed corpus shrank to {seen} files");
}

/// Generator output is inside the interchange format's exact-integer
/// window: every sampled workload survives JSON text round trip with
/// all six dims bit-identical.
#[test]
fn generator_samples_round_trip_through_json() {
    let d = WorkloadDistribution::named("mixed").unwrap();
    for w in &d.population(50, 1234).workloads {
        let text = workload_to_json(w).to_string();
        let back = parse_workload_text(&text, "x").unwrap();
        assert_eq!(w.name, back.name);
        for (a, b) in w.layers.iter().zip(&back.layers) {
            assert_eq!(
                [a.k, a.n, a.passes, a.weights, a.in_bytes, a.out_bytes],
                [b.k, b.n, b.passes, b.weights, b.in_bytes, b.out_bytes],
                "{}:{}",
                w.name,
                a.name
            );
        }
    }
}

// ------------------------------------------------------ ONNX encoding
//
// Minimal protobuf wire encoder — just enough of ModelProto to exercise
// the reader with byte-accurate inputs (and their truncations).

fn varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

fn field_varint(out: &mut Vec<u8>, field: u64, v: u64) {
    varint(out, field << 3);
    varint(out, v);
}

fn field_len(out: &mut Vec<u8>, field: u64, payload: &[u8]) {
    varint(out, field << 3 | 2);
    varint(out, payload.len() as u64);
    out.extend_from_slice(payload);
}

fn field_str(out: &mut Vec<u8>, field: u64, s: &str) {
    field_len(out, field, s.as_bytes());
}

/// AttributeProto with repeated ints (name=1, ints=8, unpacked).
fn attr_ints(name: &str, ints: &[i64]) -> Vec<u8> {
    let mut b = Vec::new();
    field_str(&mut b, 1, name);
    for &i in ints {
        field_varint(&mut b, 8, i as u64);
    }
    b
}

/// AttributeProto with a single int (name=1, i=3).
fn attr_i(name: &str, v: i64) -> Vec<u8> {
    let mut b = Vec::new();
    field_str(&mut b, 1, name);
    field_varint(&mut b, 3, v as u64);
    b
}

/// NodeProto: input=1, output=2, name=3, op_type=4, attribute=5.
fn node(op: &str, name: &str, inputs: &[&str], outputs: &[&str], attrs: &[Vec<u8>]) -> Vec<u8> {
    let mut b = Vec::new();
    for i in inputs {
        field_str(&mut b, 1, i);
    }
    for o in outputs {
        field_str(&mut b, 2, o);
    }
    field_str(&mut b, 3, name);
    field_str(&mut b, 4, op);
    for a in attrs {
        field_len(&mut b, 5, a);
    }
    b
}

/// TensorProto initializer: dims=1, data_type=2, name=8 (1 = float).
fn tensor(name: &str, dims: &[u64]) -> Vec<u8> {
    let mut b = Vec::new();
    for &d in dims {
        field_varint(&mut b, 1, d);
    }
    field_varint(&mut b, 2, 1);
    field_str(&mut b, 8, name);
    b
}

/// ValueInfoProto: name=1, type=2 → tensor_type=1 → shape=2 → dim=1 →
/// dim_value=1.
fn value_info(name: &str, dims: &[u64]) -> Vec<u8> {
    let mut shape = Vec::new();
    for &d in dims {
        let mut dim = Vec::new();
        field_varint(&mut dim, 1, d);
        field_len(&mut shape, 1, &dim);
    }
    let mut tensor_type = Vec::new();
    field_len(&mut tensor_type, 2, &shape);
    let mut ty = Vec::new();
    field_len(&mut ty, 1, &tensor_type);
    let mut b = Vec::new();
    field_str(&mut b, 1, name);
    field_len(&mut b, 2, &ty);
    b
}

/// ModelProto (graph=7) around a GraphProto (node=1, initializer=5,
/// input=11).
fn model(nodes: &[Vec<u8>], inits: &[Vec<u8>], inputs: &[Vec<u8>]) -> Vec<u8> {
    let mut g = Vec::new();
    for n in nodes {
        field_len(&mut g, 1, n);
    }
    for t in inits {
        field_len(&mut g, 5, t);
    }
    for i in inputs {
        field_len(&mut g, 11, i);
    }
    let mut m = Vec::new();
    field_len(&mut m, 7, &g);
    m
}

/// Conv → Relu → Flatten → Gemm(transB): a minimal CNN. Checks the
/// im2col matmul view (k = kh·kw·cin, passes = oh·ow) and shape
/// plumbing through the passthrough/Flatten ops.
#[test]
fn onnx_conv_gemm_model_maps_to_matmul_view() {
    let bytes = model(
        &[
            node(
                "Conv",
                "conv1",
                &["x", "w1"],
                &["c1"],
                &[
                    attr_ints("pads", &[1, 1, 1, 1]),
                    attr_ints("strides", &[1, 1]),
                    attr_ints("kernel_shape", &[3, 3]),
                ],
            ),
            node("Relu", "relu1", &["c1"], &["r1"], &[]),
            node("Flatten", "flat", &["r1"], &["f1"], &[]),
            node("Gemm", "fc", &["f1", "w2"], &["y"], &[attr_i("transB", 1)]),
        ],
        &[tensor("w1", &[4, 3, 3, 3]), tensor("w2", &[10, 256])],
        &[value_info("x", &[1, 3, 8, 8])],
    );
    let w = workload_from_onnx(&bytes, "tiny").unwrap();
    assert_eq!(w.name, "tiny");
    assert_eq!(w.layers.len(), 2, "only compute ops become layers");
    let conv = &w.layers[0];
    assert_eq!(conv.name, "conv1");
    assert_eq!(conv.kind, LayerKind::Conv);
    assert_eq!((conv.k, conv.n, conv.passes), (27, 4, 64));
    assert_eq!(conv.weights, 4 * 3 * 3 * 3);
    let fc = &w.layers[1];
    assert_eq!(fc.kind, LayerKind::Fc);
    assert_eq!((fc.k, fc.n, fc.passes), (256, 10, 1));
}

/// Depthwise Conv (group == channels, 1 input channel per group) maps
/// to [`LayerKind::DepthwiseConv`] with k = kh·kw.
#[test]
fn onnx_grouped_conv_maps_to_depthwise() {
    let bytes = model(
        &[node(
            "Conv",
            "dw",
            &["x", "w1"],
            &["y"],
            &[attr_i("group", 8), attr_ints("pads", &[1, 1, 1, 1])],
        )],
        &[tensor("w1", &[8, 1, 3, 3])],
        &[value_info("x", &[1, 8, 8, 8])],
    );
    let w = workload_from_onnx(&bytes, "dwnet").unwrap();
    assert_eq!(w.layers.len(), 1);
    assert_eq!(w.layers[0].kind, LayerKind::DepthwiseConv);
    assert_eq!((w.layers[0].k, w.layers[0].n), (9, 8));
    assert_eq!(w.layers[0].passes, 64);
}

/// MatMul of two activations (neither an initializer) is the attention
/// pattern: a weightless [`LayerKind::Dynamic`] layer.
#[test]
fn onnx_activation_matmul_is_dynamic() {
    let bytes = model(
        &[node("MatMul", "scores", &["a", "b"], &["s"], &[])],
        &[],
        &[
            value_info("a", &[1, 4, 16, 32]),
            value_info("b", &[1, 4, 32, 16]),
        ],
    );
    let w = workload_from_onnx(&bytes, "attn").unwrap();
    assert_eq!(w.layers.len(), 1);
    let l = &w.layers[0];
    assert_eq!(l.kind, LayerKind::Dynamic);
    assert_eq!((l.k, l.n, l.passes), (32, 16, 64));
    assert_eq!(l.weights, 0, "dynamic matmuls store no weights");
}

/// Every strict prefix of a valid model is rejected with a typed ONNX
/// error — no prefix length panics or silently half-parses.
#[test]
fn onnx_truncations_never_panic() {
    let bytes = model(
        &[node("Gemm", "fc", &["x", "w"], &["y"], &[])],
        &[tensor("w", &[16, 4])],
        &[value_info("x", &[1, 16])],
    );
    assert!(workload_from_onnx(&bytes, "ok").is_ok());
    for cut in 0..bytes.len() {
        let e = workload_from_onnx(&bytes[..cut], "cut").unwrap_err();
        assert!(
            matches!(e, IngestError::Onnx(_)),
            "prefix {cut}/{}: {e}",
            bytes.len()
        );
    }
}

/// A Gemm whose weight tensor never appears as an initializer is a
/// typed error naming the missing tensor, not a panic.
#[test]
fn onnx_missing_initializer_is_typed() {
    let bytes = model(
        &[node("Gemm", "fc", &["x", "ghost"], &["y"], &[])],
        &[],
        &[value_info("x", &[1, 16])],
    );
    let e = workload_from_onnx(&bytes, "t").unwrap_err();
    assert!(matches!(e, IngestError::Onnx(_)));
    assert!(e.to_string().contains("ghost"), "{e}");
}
