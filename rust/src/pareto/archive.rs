//! A bounded, deterministic archive of non-dominated designs.
//!
//! The archive is the front the `pareto` experiment ultimately reports:
//! every feasible evaluated design is offered to it, dominated entries
//! are evicted, and when the capacity (`--pareto-cap`) is exceeded the
//! most crowded interior point is dropped — per-objective extremes carry
//! infinite crowding distance and are never pruned, so the front's
//! extent is stable under capacity pressure. The **min-product corner**
//! (the front's best scalar-EDAP point, see
//! [`min_product_index`](crate::pareto::indicators::min_product_index))
//! is likewise pinned: it is the design the `pareto` report compares
//! against the GA best, and it sits in the front's interior where
//! crowding pressure would otherwise prune it.
//!
//! Determinism contract: the archive's contents are a pure function of
//! the *sequence* of [`ParetoArchive::offer`] calls. Rejection uses weak
//! dominance (an incoming duplicate of a stored objective vector is
//! rejected, first-seen wins), pruning breaks crowding ties by dropping
//! the youngest entry, and [`ParetoArchive::entries`] orders the front
//! lexicographically by objective vector (then insertion sequence) so
//! artifacts serialize bit-identically across runs, thread counts and
//! resume replays.

use super::sort::{crowding_distance, dominates, weakly_dominates};
use crate::space::Design;

/// One archived design with its objective vector.
#[derive(Clone, Debug)]
pub struct ArchiveEntry {
    pub design: Design,
    pub objectives: Vec<f64>,
    /// Insertion sequence number (deterministic tie-breaker).
    pub seq: u64,
}

/// See the module docs.
#[derive(Clone, Debug)]
pub struct ParetoArchive {
    cap: usize,
    entries: Vec<ArchiveEntry>,
    seq: u64,
    offered: u64,
}

impl ParetoArchive {
    /// An archive holding at most `cap` mutually non-dominated entries.
    pub fn new(cap: usize) -> ParetoArchive {
        ParetoArchive {
            cap: cap.max(1),
            entries: Vec::new(),
            seq: 0,
            offered: 0,
        }
    }

    /// Offer one design. Non-finite vectors are rejected outright
    /// (infeasible designs have no place on a front). Returns `true` if
    /// the design entered the archive.
    pub fn offer(&mut self, design: &Design, objectives: &[f64]) -> bool {
        self.offered += 1;
        if !objectives.iter().all(|x| x.is_finite()) {
            return false;
        }
        if self
            .entries
            .iter()
            .any(|e| weakly_dominates(&e.objectives, objectives))
        {
            return false;
        }
        self.entries
            .retain(|e| !dominates(objectives, &e.objectives));
        self.seq += 1;
        self.entries.push(ArchiveEntry {
            design: design.clone(),
            objectives: objectives.to_vec(),
            seq: self.seq,
        });
        if self.entries.len() > self.cap {
            self.prune_one();
        }
        true
    }

    /// Offer a batch in order (designs parallel to objective vectors).
    pub fn offer_batch(&mut self, designs: &[Design], objectives: &[Vec<f64>]) {
        debug_assert_eq!(designs.len(), objectives.len());
        for (d, o) in designs.iter().zip(objectives) {
            self.offer(d, o);
        }
    }

    /// Drop the most crowded interior entry (smallest crowding distance;
    /// ties drop the youngest). All entries are mutually non-dominated,
    /// so crowding over the whole set is well-defined; extremes have
    /// infinite distance and survive unless *every* entry is extreme, in
    /// which case the youngest goes. The min-product corner is exempt
    /// from victim selection (see the module docs).
    fn prune_one(&mut self) {
        let points: Vec<Vec<f64>> = self.entries.iter().map(|e| e.objectives.clone()).collect();
        let front: Vec<usize> = (0..points.len()).collect();
        let crowd = crowding_distance(&points, &front);
        // pruning only happens at len == cap + 1 >= 2, so excluding one
        // pinned index always leaves a victim candidate
        let pinned = crate::pareto::indicators::min_product_index(&points);
        let victim = (0..self.entries.len())
            .filter(|&i| Some(i) != pinned)
            .min_by(|&a, &b| {
                crowd[a]
                    .total_cmp(&crowd[b])
                    // equal crowding (incl. all-infinite): drop the youngest
                    .then(self.entries[b].seq.cmp(&self.entries[a].seq))
            })
            .expect("non-empty archive");
        self.entries.remove(victim);
    }

    /// Number of archived entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total designs offered (feasible or not) — diagnostics.
    pub fn offered(&self) -> u64 {
        self.offered
    }

    /// The front in canonical order: lexicographic by objective vector
    /// (`total_cmp` per axis), insertion sequence as the final tie-break.
    pub fn entries(&self) -> Vec<ArchiveEntry> {
        let mut out = self.entries.clone();
        out.sort_by(|a, b| {
            for (x, y) in a.objectives.iter().zip(&b.objectives) {
                let c = x.total_cmp(y);
                if c != std::cmp::Ordering::Equal {
                    return c;
                }
            }
            a.seq.cmp(&b.seq)
        });
        out
    }

    /// The canonical-order objective vectors (indicator inputs).
    pub fn objective_vectors(&self) -> Vec<Vec<f64>> {
        self.entries().into_iter().map(|e| e.objectives).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(i: u16) -> Design {
        Design(vec![i; 10])
    }

    #[test]
    fn keeps_only_non_dominated() {
        let mut a = ParetoArchive::new(16);
        assert!(a.offer(&d(0), &[2.0, 2.0]));
        assert!(!a.offer(&d(1), &[3.0, 3.0]), "dominated incoming rejected");
        assert!(a.offer(&d(2), &[1.0, 3.0]));
        // dominates both stored entries -> they are evicted
        assert!(a.offer(&d(3), &[0.5, 0.5]));
        assert_eq!(a.len(), 1);
        assert_eq!(a.entries()[0].design, d(3));
        assert_eq!(a.offered(), 4);
    }

    #[test]
    fn duplicate_vectors_keep_first_seen() {
        let mut a = ParetoArchive::new(16);
        assert!(a.offer(&d(0), &[1.0, 2.0]));
        assert!(!a.offer(&d(1), &[1.0, 2.0]), "equal vector weakly dominated");
        assert_eq!(a.len(), 1);
        assert_eq!(a.entries()[0].design, d(0));
    }

    #[test]
    fn rejects_non_finite() {
        let mut a = ParetoArchive::new(4);
        assert!(!a.offer(&d(0), &[f64::INFINITY, 1.0]));
        assert!(!a.offer(&d(1), &[f64::NAN, 1.0]));
        assert!(a.is_empty());
    }

    #[test]
    fn capacity_pruning_protects_extremes() {
        let mut a = ParetoArchive::new(3);
        // four mutually non-dominated points on the anti-diagonal; the
        // interior pair is denser near (1,3)
        a.offer(&d(0), &[0.0, 4.0]);
        a.offer(&d(1), &[1.0, 3.0]);
        a.offer(&d(2), &[1.2, 2.8]);
        a.offer(&d(3), &[4.0, 0.0]);
        assert_eq!(a.len(), 3);
        let objs = a.objective_vectors();
        // the extremes survive
        assert!(objs.contains(&vec![0.0, 4.0]));
        assert!(objs.contains(&vec![4.0, 0.0]));
        // exactly one of the crowded interior pair survives
        let interior = objs
            .iter()
            .filter(|o| o[0] > 0.0 && o[0] < 4.0)
            .count();
        assert_eq!(interior, 1);
    }

    #[test]
    fn pruning_pins_the_min_product_corner() {
        let mut a = ParetoArchive::new(3);
        a.offer(&d(0), &[1.0, 5.0]);
        a.offer(&d(1), &[2.0, 2.0]);
        a.offer(&d(2), &[5.0, 1.0]);
        // (2.1, 1.9): product 3.99 — the front's new min-EDAP corner, but
        // also the youngest, least-crowded interior point; unpinned
        // pruning would drop exactly this entry
        a.offer(&d(3), &[2.1, 1.9]);
        assert_eq!(a.len(), 3);
        let objs = a.objective_vectors();
        assert!(objs.contains(&vec![2.1, 1.9]), "corner must survive: {objs:?}");
        assert!(!objs.contains(&vec![2.0, 2.0]), "next candidate goes: {objs:?}");
        // the per-axis extremes keep their usual protection
        assert!(objs.contains(&vec![1.0, 5.0]));
        assert!(objs.contains(&vec![5.0, 1.0]));
    }

    #[test]
    fn entries_order_is_canonical_and_stable() {
        let offers: Vec<(Design, Vec<f64>)> = vec![
            (d(5), vec![3.0, 1.0]),
            (d(1), vec![1.0, 3.0]),
            (d(7), vec![2.0, 2.0]),
        ];
        let mut a = ParetoArchive::new(8);
        for (de, o) in &offers {
            a.offer(de, o);
        }
        let e = a.entries();
        let objs: Vec<&[f64]> = e.iter().map(|x| x.objectives.as_slice()).collect();
        assert_eq!(objs, vec![&[1.0, 3.0][..], &[2.0, 2.0], &[3.0, 1.0]]);
        // same offers in the same order -> identical archive, whatever the
        // process/thread context
        let mut b = ParetoArchive::new(8);
        for (de, o) in &offers {
            b.offer(de, o);
        }
        let eb = b.entries();
        for (x, y) in e.iter().zip(&eb) {
            assert_eq!(x.design, y.design);
            assert_eq!(x.objectives, y.objectives);
            assert_eq!(x.seq, y.seq);
        }
    }

    #[test]
    fn under_pressure_archive_stays_bounded_and_non_dominated() {
        let mut a = ParetoArchive::new(8);
        for i in 0..40u16 {
            let x = 0.5 + i as f64 * 0.25;
            let y = 10.0 / x; // mutually non-dominated trade-off curve
            a.offer(&d(i), &[x, y]);
            assert!(a.len() <= 8, "cap exceeded at offer {i}");
        }
        let objs = a.objective_vectors();
        for (i, p) in objs.iter().enumerate() {
            for (j, q) in objs.iter().enumerate() {
                if i != j {
                    assert!(!dominates(p, q), "{p:?} dominates {q:?}");
                }
            }
        }
        // extremes of the streamed curve survive the whole run
        assert!(objs.contains(&vec![0.5, 20.0]));
        assert!(objs.contains(&vec![0.5 + 39.0 * 0.25, 10.0 / (0.5 + 39.0 * 0.25)]));
    }
}
