//! Front-quality indicators: hypervolume, spacing, knee / corner points.
//!
//! Hypervolume (minimization, against a reference point that every front
//! point must weakly dominate) dispatches on dimensionality:
//!
//! * **≤ 4 objectives** — exact, via the WFG-style exclusive-contribution
//!   recursion: `HV(S) = Σᵢ (vol(pᵢ) − HV(nds(limit(S[i+1..], pᵢ))))`,
//!   where `limit` clamps the remaining points onto pᵢ's dominated box.
//!   Worst-case exponential but fast on real fronts (the limit + nds
//!   steps shrink the set quickly); the CI microbench pins the cost on a
//!   1k-point cloud.
//! * **> 4 objectives** — the *dominated-hypervolume* fallback: a
//!   deterministic low-discrepancy (R-sequence) sample of the
//!   `[front ideal, reference]` box, reporting the dominated fraction
//!   times the box volume. No RNG is involved, so the estimate is
//!   bit-stable run to run. It is monotone under adding points *as long
//!   as the front's ideal (and therefore the sampling box) is
//!   unchanged — a larger front then dominates a superset of the same
//!   samples; a point that lowers the ideal re-scales the box and can
//!   perturb the estimate by its discretization error, unlike the exact
//!   ≤ 4-dim path, which is unconditionally monotone.
//!
//! Raw EDAP-scale fronts span orders of magnitude per axis, so reports
//! use [`normalized_hypervolume`], which maps the front onto the unit box
//! by its own ideal/nadir and measures against the reference `1.1`ᵈ —
//! comparable across scenarios and modes.

use super::sort::{dominates, weakly_dominates};

/// Number of low-discrepancy samples for the > 4-objective fallback.
/// Fixed (not configurable) so every report/artifact is reproducible.
const FALLBACK_SAMPLES: usize = 4096;

/// Exact-vs-fallback dispatch threshold (see module docs).
pub const EXACT_DIMS_MAX: usize = 4;

/// Hypervolume of `points` against `reference` (minimization: the measure
/// of the region dominated by the front and bounded by the reference).
/// Points outside the reference box are clamped to contribute nothing on
/// the offending axes. Empty input → 0.
pub fn hypervolume(points: &[Vec<f64>], reference: &[f64]) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    let dims = reference.len();
    debug_assert!(points.iter().all(|p| p.len() == dims));
    // only finite, mutually non-dominated points contribute
    let mut front: Vec<Vec<f64>> = Vec::new();
    for p in points {
        if p.iter().all(|x| x.is_finite()) {
            front.push(p.clone());
        }
    }
    let mut front = nds(front);
    if front.is_empty() {
        return 0.0;
    }
    // canonical lexicographic order: makes the result independent of the
    // caller's point order and keeps the WFG limit-sets collapsing early
    front.sort_by(|a, b| {
        a.iter()
            .zip(b)
            .map(|(x, y)| x.total_cmp(y))
            .find(|c| *c != std::cmp::Ordering::Equal)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    if dims <= EXACT_DIMS_MAX {
        wfg(&front, reference)
    } else {
        dominated_fraction(&front, reference)
    }
}

/// Keep the non-dominated subset, first-seen representative per vector
/// (weak dominance removes exact duplicates). Deterministic: input order
/// decides survivors among equals.
fn nds(points: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
    let mut front: Vec<Vec<f64>> = Vec::new();
    for p in points {
        if front.iter().any(|q| weakly_dominates(q, &p)) {
            continue;
        }
        front.retain(|q| !dominates(&p, q));
        front.push(p);
    }
    front
}

/// Volume of the box `[p, reference]` (zero if `p` exceeds the reference
/// on any axis).
fn inclusive_volume(p: &[f64], reference: &[f64]) -> f64 {
    p.iter()
        .zip(reference)
        .map(|(&x, &r)| (r - x).max(0.0))
        .product()
}

/// WFG exclusive-contribution recursion over a non-dominated set.
fn wfg(front: &[Vec<f64>], reference: &[f64]) -> f64 {
    let mut total = 0.0;
    for (i, p) in front.iter().enumerate() {
        let rest = &front[i + 1..];
        // limit: clamp the remaining points onto p's dominated region
        let limited: Vec<Vec<f64>> = rest
            .iter()
            .map(|q| q.iter().zip(p).map(|(&x, &y)| x.max(y)).collect())
            .collect();
        let limited = nds(limited);
        let overlap = if limited.is_empty() {
            0.0
        } else {
            wfg(&limited, reference)
        };
        total += inclusive_volume(p, reference) - overlap;
    }
    total
}

/// Deterministic dominated-volume estimate for > 4 objectives: fraction
/// of an R-sequence sample of the `[ideal, reference]` box dominated by
/// the front, times the box volume.
fn dominated_fraction(front: &[Vec<f64>], reference: &[f64]) -> f64 {
    let dims = reference.len();
    // sampling box: front ideal .. reference (anything below the ideal is
    // dominated by nothing and would only dilute the estimate)
    let mut ideal = vec![f64::INFINITY; dims];
    for p in front {
        for (a, &x) in ideal.iter_mut().zip(p) {
            *a = a.min(x);
        }
    }
    let extent: Vec<f64> = ideal
        .iter()
        .zip(reference)
        .map(|(&lo, &hi)| (hi - lo).max(0.0))
        .collect();
    let box_vol: f64 = extent.iter().product();
    if box_vol <= 0.0 || !box_vol.is_finite() {
        return 0.0;
    }
    let alphas = r_sequence_alphas(dims);
    let mut dominated = 0usize;
    let mut sample = vec![0.0f64; dims];
    for k in 1..=FALLBACK_SAMPLES {
        for j in 0..dims {
            let u = (k as f64 * alphas[j]).fract();
            sample[j] = ideal[j] + extent[j] * u;
        }
        if front.iter().any(|p| weakly_dominates(p, &sample)) {
            dominated += 1;
        }
    }
    box_vol * dominated as f64 / FALLBACK_SAMPLES as f64
}

/// Per-axis irrational step sizes of the Rd low-discrepancy sequence
/// (powers of the inverse of the d-dimensional plastic constant, the
/// unique positive root of `x^(d+1) = x + 1`).
fn r_sequence_alphas(dims: usize) -> Vec<f64> {
    // Newton's iteration converges in a handful of steps from 1.5
    let mut phi = 1.5f64;
    for _ in 0..64 {
        let f = phi.powi(dims as i32 + 1) - phi - 1.0;
        let df = (dims as f64 + 1.0) * phi.powi(dims as i32) - 1.0;
        phi -= f / df;
    }
    (1..=dims).map(|j| (1.0 / phi.powi(j as i32)).fract()).collect()
}

/// Normalized hypervolume of a front: axes mapped to `[0, 1]` by the
/// front's own ideal/nadir (degenerate axes collapse to 0), measured
/// against the reference `1.1`ᵈ. A single-point front scores
/// `1.1ᵈ − ...` trivially, so callers usually report it alongside the
/// front size. Result is in `[0, 1.1ᵈ]`.
pub fn normalized_hypervolume(points: &[Vec<f64>]) -> f64 {
    let scaled = normalize_unit(points);
    let Some(first) = scaled.first() else {
        return 0.0;
    };
    let reference = vec![1.1f64; first.len()];
    hypervolume(&scaled, &reference)
}

/// Schott's spacing metric: standard deviation of nearest-neighbor
/// (Euclidean, on normalized axes) distances across the front. 0 for
/// fronts of fewer than three points — and for perfectly even fronts.
pub fn spacing(points: &[Vec<f64>]) -> f64 {
    let scaled = normalize_unit(points);
    let n = scaled.len();
    if n < 3 {
        return 0.0;
    }
    let mut nearest = Vec::with_capacity(n);
    for i in 0..n {
        let mut best = f64::INFINITY;
        for j in 0..n {
            if i == j {
                continue;
            }
            let d2: f64 = scaled[i]
                .iter()
                .zip(&scaled[j])
                .map(|(&a, &b)| (a - b) * (a - b))
                .sum();
            best = best.min(d2.sqrt());
        }
        nearest.push(best);
    }
    crate::util::stats::std_dev(&nearest)
}

/// Knee point: index of the front member closest (Euclidean) to the
/// ideal point on per-axis-normalized coordinates — the classic "best
/// compromise" read of a front. Ties break toward the lower index; `None`
/// for fronts with no finite point.
pub fn knee_index(points: &[Vec<f64>]) -> Option<usize> {
    let scaled = normalize_unit(points);
    let finite_indices: Vec<usize> = points
        .iter()
        .enumerate()
        .filter(|(_, p)| p.iter().all(|x| x.is_finite()))
        .map(|(i, _)| i)
        .collect();
    debug_assert_eq!(scaled.len(), finite_indices.len());
    let mut best: Option<(usize, f64)> = None;
    for (p, &i) in scaled.iter().zip(&finite_indices) {
        let d2: f64 = p.iter().map(|&x| x * x).sum();
        match best {
            Some((_, bd)) if d2 >= bd => {}
            _ => best = Some((i, d2)),
        }
    }
    best.map(|(i, _)| i)
}

/// Index of the front member with the smallest product of objectives —
/// the minimum-EDAP corner when the axes are `(agg E, agg L, A)` (their
/// product *is* the scalar EDAP). Ties break toward the lower index;
/// `None` when no point is finite.
pub fn min_product_index(points: &[Vec<f64>]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, p) in points.iter().enumerate() {
        if !p.iter().all(|x| x.is_finite()) {
            continue;
        }
        let prod: f64 = p.iter().product();
        match best {
            Some((_, bp)) if prod >= bp => {}
            _ => best = Some((i, prod)),
        }
    }
    best.map(|(i, _)| i)
}

/// Finite points mapped per-axis onto `[0, 1]` by the set's own
/// ideal/nadir (degenerate axes collapse to 0). Non-finite points are
/// dropped, preserving order.
fn normalize_unit(points: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let finite: Vec<&Vec<f64>> = points
        .iter()
        .filter(|p| p.iter().all(|x| x.is_finite()))
        .collect();
    let Some(first) = finite.first() else {
        return Vec::new();
    };
    let dims = first.len();
    let mut lo = vec![f64::INFINITY; dims];
    let mut hi = vec![f64::NEG_INFINITY; dims];
    for p in &finite {
        for j in 0..dims {
            lo[j] = lo[j].min(p[j]);
            hi[j] = hi[j].max(p[j]);
        }
    }
    finite
        .iter()
        .map(|p| {
            (0..dims)
                .map(|j| {
                    let ext = hi[j] - lo[j];
                    if ext > 0.0 {
                        (p[j] - lo[j]) / ext
                    } else {
                        0.0
                    }
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point_is_its_box() {
        let hv = hypervolume(&[vec![1.0, 2.0]], &[3.0, 4.0]);
        assert!((hv - 4.0).abs() < 1e-12, "{hv}");
        let hv3 = hypervolume(&[vec![0.0, 0.0, 0.0]], &[1.0, 2.0, 3.0]);
        assert!((hv3 - 6.0).abs() < 1e-12, "{hv3}");
    }

    #[test]
    fn two_point_overlap_counts_once() {
        // boxes 2x1 and 1x2 overlapping in a 1x1 square -> 3
        let hv = hypervolume(&[vec![0.0, 1.0], vec![1.0, 0.0]], &[2.0, 2.0]);
        assert!((hv - 3.0).abs() < 1e-12, "{hv}");
    }

    #[test]
    fn dominated_and_duplicate_points_add_nothing() {
        let base = hypervolume(&[vec![0.0, 1.0], vec![1.0, 0.0]], &[2.0, 2.0]);
        let more = hypervolume(
            &[vec![0.0, 1.0], vec![1.0, 0.0], vec![1.5, 1.5], vec![0.0, 1.0]],
            &[2.0, 2.0],
        );
        assert!((base - more).abs() < 1e-12);
    }

    #[test]
    fn three_objective_staircase() {
        // two disjoint unit boxes below ref (2,2,2): each 1x1x2 and 1x2x1
        // overlapping in 1x1x1 -> 2 + 2 - 1 = 3
        let hv = hypervolume(&[vec![1.0, 1.0, 0.0], vec![1.0, 0.0, 1.0]], &[2.0, 2.0, 2.0]);
        assert!((hv - 3.0).abs() < 1e-12, "{hv}");
    }

    #[test]
    fn four_dims_exact_and_five_dims_fallback_agree_roughly() {
        // a single point: both paths must report (close to) its box volume
        let p4 = vec![vec![0.5; 4]];
        let r4 = vec![1.0; 4];
        assert!((hypervolume(&p4, &r4) - 0.5f64.powi(4)).abs() < 1e-12);
        let p5 = vec![vec![0.5; 5]];
        let r5 = vec![1.0; 5];
        // fallback box is [ideal, ref] = [0.5, 1]^5, fully dominated
        let est = hypervolume(&p5, &r5);
        assert!((est - 0.5f64.powi(5)).abs() < 1e-9, "{est}");
    }

    #[test]
    fn fallback_is_monotone_and_deterministic() {
        // the added point keeps the front's ideal unchanged, so both
        // estimates sample the same box and the dominated sample set can
        // only grow
        let reference = vec![1.0; 5];
        let a = vec![
            vec![0.2, 0.8, 0.5, 0.5, 0.5],
            vec![0.8, 0.2, 0.5, 0.5, 0.5],
        ];
        let mut b = a.clone();
        b.push(vec![0.5, 0.5, 0.5, 0.5, 0.5]);
        let hv_a = hypervolume(&a, &reference);
        let hv_b = hypervolume(&b, &reference);
        assert!(hv_b >= hv_a, "{hv_b} < {hv_a}");
        assert!(hv_a > 0.0);
        assert_eq!(
            hypervolume(&a, &reference).to_bits(),
            hv_a.to_bits(),
            "fallback must be bit-stable"
        );
    }

    #[test]
    fn normalized_hv_ignores_scale() {
        let small = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        let big: Vec<Vec<f64>> = small
            .iter()
            .map(|p| p.iter().map(|&x| 1e6 * x + 42.0).collect())
            .collect();
        let a = normalized_hypervolume(&small);
        let b = normalized_hypervolume(&big);
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        assert!(a > 0.0 && a <= 1.1f64.powi(2) + 1e-12);
    }

    #[test]
    fn spacing_prefers_even_fronts() {
        let even = vec![vec![0.0, 3.0], vec![1.0, 2.0], vec![2.0, 1.0], vec![3.0, 0.0]];
        let clumped = vec![vec![0.0, 3.0], vec![0.1, 2.9], vec![0.2, 2.8], vec![3.0, 0.0]];
        assert!(spacing(&even) < spacing(&clumped));
        assert_eq!(spacing(&even[..2]), 0.0);
    }

    #[test]
    fn knee_and_corner_selection() {
        let pts = vec![
            vec![0.0, 10.0],
            vec![3.0, 3.0], // compromise: closest to the normalized ideal
            vec![10.0, 0.0],
        ];
        assert_eq!(knee_index(&pts), Some(1));
        // min product: 0 * 10 = 0 at either extreme; ties -> lower index
        assert_eq!(min_product_index(&pts), Some(0));
        let with_inf = vec![vec![f64::INFINITY, 0.0], vec![2.0, 2.0]];
        assert_eq!(knee_index(&with_inf), Some(1));
        assert_eq!(min_product_index(&with_inf), Some(1));
        assert_eq!(knee_index(&[]), None);
    }
}
