//! Multi-objective (Pareto) search over the joint co-optimization
//! problem.
//!
//! The rest of the crate scores a design with **one** number
//! ([`crate::objective::Objective::score`] — scalarized EDAP under an
//! aggregation). This subsystem exposes the trade-offs that number hides:
//! a [`VectorObjective`] maps a design's per-workload
//! [`crate::model::Metrics`] to an objective *vector* under two modes
//! ([`MooMode`]):
//!
//! * **metric** — `(agg(E), agg(L), A)`: the three EDAP factors as
//!   separate axes (their product *is* the scalar EDAP, so the front's
//!   minimum-product corner is directly comparable to the scalarized GA
//!   best);
//! * **workload** — one EDAP axis per active (train-set) workload: the
//!   literal cross-workload trade-off surface the paper's joint
//!   optimization navigates.
//!
//! [`MooProblem`] adapts a [`JointProblem`] into the [`MultiObjective`]
//! trait, riding the existing batch-evaluation pipeline: a vector batch
//! first warms the sharded memo cache through the parallel
//! `score_batch` path (PR 1's threading, PR 3's O(1) compiled
//! evaluator), then assembles vectors from the cached per-workload
//! metrics — so multi-objective search inherits caching, threading and
//! bit-determinism for free.
//!
//! The optimizer is [`Nsga2`] (fast non-dominated sorting + crowding
//! distance + constraint-domination, [`sort`]), archiving every feasible
//! evaluation into a bounded deterministic [`ParetoArchive`]
//! ([`archive`]); front quality is measured by [`indicators`]
//! (hypervolume — exact WFG-style recursion up to 4 objectives, a
//! deterministic dominated-volume estimate beyond — plus spacing and
//! knee/corner extraction). The `pareto` registry experiment
//! (`experiments::pareto`, `docs/pareto.md`) wires it end to end.

pub mod archive;
pub mod indicators;
pub mod nsga2;
pub mod sort;

pub use archive::{ArchiveEntry, ParetoArchive};
pub use nsga2::{MooResult, MultiObjectiveOptimizer, Nsga2, Nsga2Config};

use crate::coordinator::JointProblem;
use crate::model::Metrics;
use crate::objective::Aggregation;
use crate::search::Problem;
use crate::space::Design;
use crate::util::rng::Rng;
use crate::workloads::WorkloadSet;
use anyhow::bail;

/// How a design's metrics become an objective vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MooMode {
    /// `(agg(E) mJ, agg(L) ms, A mm²)` — 3 axes whose product is the
    /// scalar EDAP.
    Metric,
    /// One per-workload EDAP axis (mJ·ms·mm²) per active workload.
    Workload,
}

impl MooMode {
    pub fn name(&self) -> &'static str {
        match self {
            MooMode::Metric => "metric",
            MooMode::Workload => "workload",
        }
    }

    /// Parse a `--moo-mode` value (`metric` | `workload`).
    pub fn parse(s: &str) -> anyhow::Result<MooMode> {
        match s {
            "metric" => Ok(MooMode::Metric),
            "workload" => Ok(MooMode::Workload),
            other => bail!("unknown moo mode '{other}' (metric|workload)"),
        }
    }
}

/// Maps per-workload metrics to a minimized objective vector. Infeasible
/// designs (any infeasible workload, or area over the constraint) map to
/// an all-`+∞` vector so feasibility survives the vector view — the
/// NSGA-II selection routes those through constraint-domination instead
/// of the Pareto ranking.
#[derive(Clone, Copy, Debug)]
pub struct VectorObjective {
    pub mode: MooMode,
    /// Cross-workload aggregation for [`MooMode::Metric`] (matches the
    /// scenario's scalar objective, so the product-corner comparison is
    /// apples to apples).
    pub agg: Aggregation,
    /// Area constraint (mm²), as in the scalar objective.
    pub area_constraint: f64,
    /// Minimum nominal accuracy a design must reach on every active
    /// workload to be front-eligible (`--acc-floor`). Enforced by
    /// [`MooProblem`] through constraint-domination: below-floor designs
    /// get an all-`+∞` vector plus a graded violation term, exactly like
    /// capacity/area infeasibility. Requires every active workload to
    /// carry a Fig. 8 accuracy baseline; `None` (the default) changes
    /// nothing.
    pub acc_floor: Option<f64>,
}

impl VectorObjective {
    pub fn new(mode: MooMode, agg: Aggregation) -> VectorObjective {
        VectorObjective {
            mode,
            agg,
            area_constraint: crate::model::consts::AREA_CONSTR_MM2,
            acc_floor: None,
        }
    }

    /// Set the accuracy floor (builder-style).
    pub fn with_acc_floor(mut self, floor: Option<f64>) -> VectorObjective {
        self.acc_floor = floor;
        self
    }

    /// Vector length for a problem with `active_workloads` active
    /// (train-set) workloads.
    pub fn dim(&self, active_workloads: usize) -> usize {
        match self.mode {
            MooMode::Metric => 3,
            MooMode::Workload => active_workloads,
        }
    }

    /// The objective vector of one design from its active-set metrics
    /// (paper units: mJ / ms / mm², as in the scalar objective).
    pub fn vector(&self, per_workload: &[Metrics]) -> Vec<f64> {
        assert!(!per_workload.is_empty());
        let dim = self.dim(per_workload.len());
        if per_workload.iter().any(|m| !m.feasible) {
            return vec![f64::INFINITY; dim];
        }
        let area = per_workload[0].area;
        if area > self.area_constraint {
            return vec![f64::INFINITY; dim];
        }
        match self.mode {
            MooMode::Metric => {
                let e: Vec<f64> = per_workload.iter().map(|m| m.energy * 1e3).collect();
                let l: Vec<f64> = per_workload.iter().map(|m| m.latency * 1e3).collect();
                vec![self.agg.apply(&e), self.agg.apply(&l), area]
            }
            MooMode::Workload => per_workload
                .iter()
                .map(|m| (m.energy * 1e3) * (m.latency * 1e3) * area)
                .collect(),
        }
    }

    /// Human-readable axis names (reports / artifacts): metric mode gets
    /// the aggregated factor names, workload mode the active workloads'.
    pub fn axes(&self, set: &WorkloadSet, active: &[usize]) -> Vec<String> {
        match self.mode {
            MooMode::Metric => vec![
                format!("{}(E) mJ", self.agg.name()),
                format!("{}(L) ms", self.agg.name()),
                "A mm2".to_string(),
            ],
            MooMode::Workload => active
                .iter()
                .map(|&i| format!("EDAP {}", set.workloads[i].name))
                .collect(),
        }
    }
}

/// A problem whose designs score as vectors (implemented by
/// [`MooProblem`]; the [`Problem`] supertrait supplies the space, the
/// feasibility-prefiltered sampling and the scalar view used by the
/// Hamming-init pipeline).
pub trait MultiObjective: Problem {
    /// Objective-vector length.
    fn objectives(&self) -> usize;
    /// Vector scores for a batch (order-preserving; infeasible designs
    /// yield all-`+∞` vectors).
    fn objective_batch(&self, designs: &[Design]) -> Vec<Vec<f64>>;
}

/// [`JointProblem`] adapted to [`MultiObjective`]. Scalar calls delegate
/// to the wrapped problem (same memo cache, same backend, same
/// feasibility pre-filter), so a scalarized GA and an NSGA-II run over
/// the same `MooProblem`/`JointProblem` pair share every evaluation.
pub struct MooProblem<'p, 'w> {
    pub inner: &'p JointProblem<'w>,
    pub vector_objective: VectorObjective,
}

impl<'p, 'w> MooProblem<'p, 'w> {
    /// Wrap a joint problem; the aggregation is taken from the problem's
    /// scalar objective so metric-mode products match scalar scores.
    pub fn new(inner: &'p JointProblem<'w>, mode: MooMode) -> MooProblem<'p, 'w> {
        let mut vector_objective = VectorObjective::new(mode, inner.objective.agg);
        vector_objective.area_constraint = inner.objective.area_constraint;
        MooProblem {
            inner,
            vector_objective,
        }
    }

    /// Set the accuracy floor (builder-style; see
    /// [`VectorObjective::acc_floor`]).
    pub fn with_acc_floor(mut self, floor: Option<f64>) -> Self {
        self.vector_objective = self.vector_objective.with_acc_floor(floor);
        self
    }

    /// Active workload indices (the train set of a restricted problem).
    pub fn active_indices(&self) -> Vec<usize> {
        self.inner
            .subset
            .clone()
            .unwrap_or_else(|| (0..self.inner.workloads.len()).collect())
    }

    /// Smallest nominal accuracy across the active workloads (memoized
    /// per design geometry through the joint problem's accuracy cache).
    fn min_nominal_accuracy(&self, d: &Design) -> f64 {
        self.inner
            .nominal_accuracies(d)
            .iter()
            .copied()
            .fold(f64::INFINITY, f64::min)
    }
}

impl Problem for MooProblem<'_, '_> {
    fn space(&self) -> &crate::space::SearchSpace {
        self.inner.space
    }
    fn score_batch(&self, designs: &[Design]) -> Vec<f64> {
        self.inner.score_batch(designs)
    }
    fn random_candidate(&self, rng: &mut Rng) -> Design {
        self.inner.random_candidate(rng)
    }
    fn violation(&self, design: &Design) -> f64 {
        let mut v = self.inner.violation(design);
        // graded accuracy-floor shortfall: below-floor designs compare
        // by how far below they are (constraint-domination), like the
        // capacity and area terms of the inner violation
        if let Some(floor) = self.vector_objective.acc_floor {
            v += (floor - self.min_nominal_accuracy(design)).max(0.0) / floor;
        }
        v
    }
    fn evals(&self) -> usize {
        self.inner.evals()
    }
}

impl MultiObjective for MooProblem<'_, '_> {
    fn objectives(&self) -> usize {
        self.vector_objective.dim(self.active_indices().len())
    }

    fn objective_batch(&self, designs: &[Design]) -> Vec<Vec<f64>> {
        // warm the sharded memo cache through the parallel scalar
        // pipeline; the per-design reads below are then pure cache hits
        let _ = self.inner.score_batch(designs);
        designs
            .iter()
            .map(|d| {
                let v = self
                    .vector_objective
                    .vector(&self.inner.evaluate_design(d).metrics);
                // accuracy floor: an otherwise-feasible design below the
                // floor becomes infeasible (all-+∞) and competes through
                // the graded violation instead of the Pareto ranking
                if let Some(floor) = self.vector_objective.acc_floor {
                    if v.iter().all(|x| x.is_finite())
                        && self.min_nominal_accuracy(d) < floor
                    {
                        return vec![f64::INFINITY; v.len()];
                    }
                }
                v
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EvalBackend;
    use crate::model::MemoryTech;
    use crate::objective::Objective;
    use crate::space::SearchSpace;

    fn m(e_mj: f64, l_ms: f64, a: f64) -> Metrics {
        Metrics {
            energy: e_mj * 1e-3,
            latency: l_ms * 1e-3,
            area: a,
            feasible: true,
        }
    }

    #[test]
    fn metric_mode_product_is_the_scalar_edap() {
        let ms = [m(1.0, 2.0, 50.0), m(3.0, 1.0, 50.0)];
        for agg in [Aggregation::Max, Aggregation::Mean, Aggregation::All] {
            let v = VectorObjective::new(MooMode::Metric, agg).vector(&ms);
            assert_eq!(v.len(), 3);
            let product: f64 = v.iter().product();
            let scalar = Objective::new(crate::objective::ObjectiveKind::Edap, agg)
                .score(&ms, None, 32.0);
            assert_eq!(
                product.to_bits(),
                scalar.to_bits(),
                "{agg:?}: product {product} != scalar {scalar}"
            );
        }
    }

    #[test]
    fn workload_mode_is_one_edap_axis_per_workload() {
        let ms = [m(1.0, 2.0, 50.0), m(3.0, 1.0, 50.0)];
        let v = VectorObjective::new(MooMode::Workload, Aggregation::Max).vector(&ms);
        assert_eq!(v.len(), 2);
        assert!((v[0] - 100.0).abs() < 1e-9, "{v:?}");
        assert!((v[1] - 150.0).abs() < 1e-9, "{v:?}");
    }

    #[test]
    fn infeasible_maps_to_all_infinite() {
        let mut bad = m(1.0, 1.0, 10.0);
        bad.feasible = false;
        let vo = VectorObjective::new(MooMode::Metric, Aggregation::Max);
        assert!(vo.vector(&[bad]).iter().all(|x| x.is_infinite()));
        let big = m(1.0, 1.0, 900.0);
        assert!(vo.vector(&[big]).iter().all(|x| x.is_infinite()));
        let wo = VectorObjective::new(MooMode::Workload, Aggregation::Max);
        let v = wo.vector(&[m(1.0, 1.0, 10.0), big]);
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|x| x.is_infinite()));
    }

    #[test]
    fn mode_parse_and_axes() {
        assert_eq!(MooMode::parse("metric").unwrap(), MooMode::Metric);
        assert_eq!(MooMode::parse("workload").unwrap(), MooMode::Workload);
        assert!(MooMode::parse("nope").is_err());
        let set = WorkloadSet::cnn4();
        let vo = VectorObjective::new(MooMode::Metric, Aggregation::Max);
        assert_eq!(
            vo.axes(&set, &[0, 1, 2, 3]),
            vec!["Max(E) mJ", "Max(L) ms", "A mm2"]
        );
        let wo = VectorObjective::new(MooMode::Workload, Aggregation::Max);
        assert_eq!(wo.axes(&set, &[0, 2]), vec!["EDAP resnet18", "EDAP alexnet"]);
    }

    #[test]
    fn moo_problem_rides_the_joint_cache() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let inner = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        );
        let moo = MooProblem::new(&inner, MooMode::Metric);
        assert_eq!(moo.objectives(), 3);
        let mut rng = Rng::seed_from(5);
        let designs: Vec<Design> = (0..6).map(|_| moo.random_candidate(&mut rng)).collect();
        let vecs = moo.objective_batch(&designs);
        let evals_after = inner.evals();
        assert_eq!(vecs.len(), 6);
        // a second vector batch is pure cache hits
        let again = moo.objective_batch(&designs);
        assert_eq!(inner.evals(), evals_after);
        for (a, b) in vecs.iter().zip(&again) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // metric-mode product equals the scalar joint score, bit for bit
        let scalars = moo.score_batch(&designs);
        for (v, s) in vecs.iter().zip(&scalars) {
            if s.is_finite() {
                let prod: f64 = v.iter().product();
                assert_eq!(prod.to_bits(), s.to_bits());
            } else {
                assert!(v.iter().all(|x| x.is_infinite()));
            }
        }
        // workload mode: one axis per active workload on a restricted set
        let restricted = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        )
        .restricted_to(vec![0, 2, 3]);
        let wmoo = MooProblem::new(&restricted, MooMode::Workload);
        assert_eq!(wmoo.objectives(), 3);
        assert_eq!(wmoo.active_indices(), vec![0, 2, 3]);
        let wv = wmoo.objective_batch(&designs[..1]);
        assert_eq!(wv[0].len(), 3);
    }

    #[test]
    fn acc_floor_gates_front_membership() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let inner = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        );
        let mut rng = Rng::seed_from(14);
        let plain = MooProblem::new(&inner, MooMode::Metric);
        let designs: Vec<Design> =
            (0..8).map(|_| plain.random_candidate(&mut rng)).collect();
        let base = plain.objective_batch(&designs);
        // a vacuous floor changes nothing, bit for bit
        let loose = MooProblem::new(&inner, MooMode::Metric).with_acc_floor(Some(1e-6));
        for (a, b) in base.iter().zip(&loose.objective_batch(&designs)) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // an unreachable floor (above every 8-bit baseline) kills every
        // design and grades the violation by the shortfall
        let strict =
            MooProblem::new(&inner, MooMode::Metric).with_acc_floor(Some(0.999));
        for v in strict.objective_batch(&designs) {
            assert!(v.iter().all(|x| x.is_infinite()));
        }
        let d = &designs[0];
        assert!(strict.violation(d) > plain.violation(d));
        assert!(loose.violation(d).to_bits() == plain.violation(d).to_bits());
        // a tighter floor violates harder (constraint-domination ordering)
        let tighter =
            MooProblem::new(&inner, MooMode::Metric).with_acc_floor(Some(0.9999));
        assert!(tighter.violation(d) > strict.violation(d));
    }

    #[test]
    fn nsga2_end_to_end_on_the_joint_problem() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let inner = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        );
        let moo = MooProblem::new(&inner, MooMode::Metric);
        let nsga = Nsga2::new(Nsga2Config {
            init: crate::search::InitStrategy::HammingDiverse { p_h: 40, p_e: 20 },
            cap: 16,
            ..Nsga2Config::paper(crate::search::SearchBudget { pop: 8, gens: 4 })
        });
        let r = nsga.run(&moo, &mut Rng::seed_from(9));
        assert!(!r.front.is_empty(), "no feasible front found");
        assert!(r.front.len() <= 16);
        for (_, o) in &r.front {
            assert_eq!(o.len(), 3);
            assert!(o.iter().all(|x| x.is_finite()));
        }
    }
}
