//! NSGA-II over the joint co-optimization problem (Deb et al. 2002),
//! with the paper GA's variation operators.
//!
//! The optimizer reuses the exact ingredients of the scalarized
//! four-phase GA — SBX crossover + polynomial mutation per
//! [`crate::search::ga::PhaseParams`] (including the phased
//! Exploration → Fine-tuning schedule of Table 4), Hamming-diversity
//! initial sampling, and [`crate::search::SearchBudget`] — so a
//! front-vs-scalar comparison at equal budget isolates the *selection*
//! strategy, not the operators.
//!
//! Selection is classic (μ+λ) NSGA-II with constraint-domination:
//! feasible beats infeasible, infeasible candidates rank by
//! [`crate::search::Problem::violation`], feasible ones by
//! (non-domination rank, crowding distance). Every feasible evaluation is
//! offered to a bounded [`ParetoArchive`], which is what
//! [`MooResult::front`] reports — while the archive stays under its
//! capacity it can only gain dominated volume over time, independent of
//! population churn; once `--pareto-cap` pruning fires, interior points
//! may be dropped (per-axis extremes are always preserved).
//!
//! Determinism: all tie-breaks are total (`total_cmp`, then index /
//! insertion order) and all randomness flows through the seeded [`Rng`],
//! so a run is a pure function of (problem, config, seed) — thread
//! counts only change evaluation throughput (the underlying
//! `JointProblem` pipeline is bit-identical at any `--threads`).

use super::archive::ParetoArchive;
use super::sort::{crowding_distance, non_dominated_sort};
use super::MultiObjective;
use crate::search::ga::{variate, PhaseParams, PAPER_PHASES};
use crate::search::{sampling, InitStrategy, SearchBudget};
use crate::space::Design;
use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Full NSGA-II configuration.
#[derive(Clone, Debug)]
pub struct Nsga2Config {
    /// Operator schedule; generations split evenly across entries (one
    /// entry = constant operators, [`PAPER_PHASES`] = the 4-phase
    /// schedule).
    pub phases: Vec<PhaseParams>,
    pub init: InitStrategy,
    pub budget: SearchBudget,
    /// Archive capacity (`--pareto-cap`): the reported front never
    /// exceeds this many points.
    pub cap: usize,
    /// Fraction of each generation's offspring pool that reaches the
    /// exact evaluator (`--screen-frac`). `1.0` (the default) runs the
    /// exact pre-surrogate loop bit-identically; below `1.0` a
    /// [`ScreenState`](crate::search::surrogate::ScreenState) trained on
    /// the log geometric mean of the observed objective vectors screens a
    /// `1/frac`-times larger variation pool down to λ exact evaluations.
    pub screen_frac: f64,
    pub label: String,
}

impl Nsga2Config {
    /// Paper-aligned defaults: 4-phase operators, Hamming sampling, a
    /// 128-point archive.
    pub fn paper(budget: SearchBudget) -> Nsga2Config {
        Nsga2Config {
            phases: PAPER_PHASES.to_vec(),
            init: InitStrategy::HammingDiverse {
                p_h: sampling::P_H,
                p_e: sampling::P_E,
            },
            budget,
            cap: 128,
            screen_frac: 1.0,
            label: "NSGA-II (4-phase operators)".into(),
        }
    }
}

/// Result of one multi-objective run.
#[derive(Clone, Debug)]
pub struct MooResult {
    pub algorithm: String,
    /// The archived front in canonical order (see
    /// [`ParetoArchive::entries`]): designs with their objective vectors.
    pub front: Vec<(Design, Vec<f64>)>,
    /// Archive size after each generation (coverage growth curve).
    pub front_sizes: Vec<usize>,
    /// Evaluator submissions consumed (cache hits included, as in
    /// [`crate::search::OptResult::evals`]).
    pub evals: usize,
    pub wall: Duration,
}

impl MooResult {
    /// Objective vectors of the front, in front order.
    pub fn objective_vectors(&self) -> Vec<Vec<f64>> {
        self.front.iter().map(|(_, o)| o.clone()).collect()
    }
}

/// A multi-objective search algorithm (implemented by [`Nsga2`]).
pub trait MultiObjectiveOptimizer {
    fn name(&self) -> String;
    fn run<P: MultiObjective>(&self, problem: &P, rng: &mut Rng) -> MooResult;
}

/// The NSGA-II engine.
#[derive(Clone, Debug)]
pub struct Nsga2 {
    pub config: Nsga2Config,
}

impl Nsga2 {
    pub fn new(config: Nsga2Config) -> Nsga2 {
        Nsga2 { config }
    }
}

/// Per-individual selection key under constraint-domination. Ordering:
/// any feasible < any infeasible; feasible by (rank asc, crowding desc);
/// infeasible by violation asc. `idx` breaks every remaining tie.
#[derive(Clone, Copy, Debug)]
struct SelKey {
    feasible: bool,
    rank: usize,
    crowd: f64,
    violation: f64,
    idx: usize,
}

impl SelKey {
    fn better(&self, other: &SelKey) -> bool {
        self.cmp_key(other) == std::cmp::Ordering::Less
    }

    fn cmp_key(&self, other: &SelKey) -> std::cmp::Ordering {
        match (self.feasible, other.feasible) {
            (true, false) => return std::cmp::Ordering::Less,
            (false, true) => return std::cmp::Ordering::Greater,
            (false, false) => {
                return self
                    .violation
                    .total_cmp(&other.violation)
                    .then(self.idx.cmp(&other.idx))
            }
            (true, true) => {}
        }
        self.rank
            .cmp(&other.rank)
            // larger crowding first
            .then(other.crowd.total_cmp(&self.crowd))
            .then(self.idx.cmp(&other.idx))
    }
}

/// Rank a scored population: non-dominated sort + crowding over the
/// feasible members, graded violation for the rest.
fn rank_population<P: MultiObjective>(
    problem: &P,
    pop: &[Design],
    objs: &[Vec<f64>],
) -> Vec<SelKey> {
    let feasible_idx: Vec<usize> = (0..pop.len())
        .filter(|&i| objs[i].iter().all(|x| x.is_finite()))
        .collect();
    let feasible_pts: Vec<Vec<f64>> = feasible_idx.iter().map(|&i| objs[i].clone()).collect();
    let fronts = non_dominated_sort(&feasible_pts);
    let mut keys: Vec<SelKey> = (0..pop.len())
        .map(|i| SelKey {
            feasible: false,
            rank: usize::MAX,
            crowd: 0.0,
            violation: f64::INFINITY,
            idx: i,
        })
        .collect();
    for (r, front) in fronts.iter().enumerate() {
        let crowd = crowding_distance(&feasible_pts, front);
        for (&fi, &c) in front.iter().zip(&crowd) {
            let i = feasible_idx[fi];
            keys[i] = SelKey {
                feasible: true,
                rank: r,
                crowd: c,
                violation: 0.0,
                idx: i,
            };
        }
    }
    for i in 0..pop.len() {
        if !keys[i].feasible {
            keys[i].violation = problem.violation(&pop[i]);
        }
    }
    keys
}

/// Emit a per-generation Pareto trace event (front size + normalized
/// hypervolume). The hypervolume is computed only when a telemetry sink
/// is active — it feeds nothing but the trace, so skipping it is free.
fn trace_front(gen: usize, evals: usize, archive: &ParetoArchive) {
    if !crate::telemetry::active() {
        return;
    }
    let hv = super::indicators::normalized_hypervolume(&archive.objective_vectors());
    crate::telemetry::emit_front(gen, evals, archive.len(), hv);
}

/// Constrained binary tournament over a ranked population.
fn tournament<'a>(pop: &'a [Design], keys: &[SelKey], rng: &mut Rng) -> &'a Design {
    let a = rng.below(pop.len());
    let b = rng.below(pop.len());
    if keys[b].better(&keys[a]) {
        &pop[b]
    } else {
        &pop[a]
    }
}

/// (μ+λ) environmental selection: the `target` best combined indices
/// under the [`SelKey`] total order (rank-complete fronts first, partial
/// front by crowding). Returned in selection order — deterministic.
fn environmental_selection<P: MultiObjective>(
    problem: &P,
    pool: &[Design],
    objs: &[Vec<f64>],
    target: usize,
) -> Vec<usize> {
    let keys = rank_population(problem, pool, objs);
    let mut order: Vec<usize> = (0..pool.len()).collect();
    order.sort_by(|&a, &b| keys[a].cmp_key(&keys[b]));
    order.truncate(target);
    order
}

impl MultiObjectiveOptimizer for Nsga2 {
    fn name(&self) -> String {
        self.config.label.clone()
    }

    fn run<P: MultiObjective>(&self, problem: &P, rng: &mut Rng) -> MooResult {
        let t0 = Instant::now();
        let cfg = &self.config;
        let space = problem.space();
        let pop_size = cfg.budget.pop.max(2);
        let mut evals = 0usize;
        let mut archive = ParetoArchive::new(cfg.cap);
        let mut front_sizes: Vec<usize> = Vec::new();
        // `None` at `screen_frac >= 1.0`: the loop below then runs the
        // exact pre-surrogate code path (same RNG draws, bit-identical)
        let mut screen = crate::search::surrogate::ScreenState::new(cfg.screen_frac);

        // ---- initial population (same pipeline as the scalar GA) ----------
        let mut pop: Vec<Design> = match cfg.init {
            InitStrategy::Random => (0..pop_size)
                .map(|_| problem.random_candidate(rng))
                .collect(),
            InitStrategy::HammingDiverse { p_h, p_e } => {
                let (init, used) = sampling::hamming_init(problem, p_h, p_e, pop_size, rng);
                evals += used;
                init
            }
        };
        let mut pop_objs = problem.objective_batch(&pop);
        evals += pop.len();
        archive.offer_batch(&pop, &pop_objs);
        front_sizes.push(archive.len());
        trace_front(0, evals, &archive);
        if let Some(s) = screen.as_mut() {
            s.observe_vec(space, &pop, &pop_objs);
        }

        let phases = &cfg.phases;
        let gens_per_phase = (cfg.budget.gens / phases.len()).max(1);

        for ph in phases {
            for _gen in 0..gens_per_phase {
                let keys = rank_population(problem, &pop, &pop_objs);

                // offspring via constrained tournament + SBX/poly mutation
                let off: Vec<Design> = match screen.as_mut() {
                    None => {
                        // exact path (--screen-frac 1.0 / default)
                        let mut off: Vec<Design> = Vec::with_capacity(pop_size);
                        while off.len() < pop_size {
                            let p1 = tournament(&pop, &keys, rng).clone();
                            let p2 = tournament(&pop, &keys, rng).clone();
                            let (c1, c2) = variate(space, &p1, &p2, ph, rng);
                            off.push(c1);
                            if off.len() < pop_size {
                                off.push(c2);
                            }
                        }
                        off
                    }
                    Some(s) => {
                        // two-stage path: recycle last round's rejects,
                        // variate up to a 1/frac-times larger pool, keep
                        // the surrogate's top λ for exact evaluation
                        let target = s.pool_target(pop_size);
                        let mut pool = s.take_carry();
                        while pool.len() < target {
                            let p1 = tournament(&pop, &keys, rng).clone();
                            let p2 = tournament(&pop, &keys, rng).clone();
                            let (c1, c2) = variate(space, &p1, &p2, ph, rng);
                            pool.push(c1);
                            if pool.len() < target {
                                pool.push(c2);
                            }
                        }
                        s.select(space, pool, pop_size)
                    }
                };
                let off_objs = problem.objective_batch(&off);
                evals += off.len();
                archive.offer_batch(&off, &off_objs);
                if let Some(s) = screen.as_mut() {
                    s.observe_vec(space, &off, &off_objs);
                }

                // (μ+λ): parents compete with offspring
                let mut pool = std::mem::take(&mut pop);
                pool.extend(off);
                let mut pool_objs = std::mem::take(&mut pop_objs);
                pool_objs.extend(off_objs);
                let survivors =
                    environmental_selection(problem, &pool, &pool_objs, pop_size);
                pop = survivors.iter().map(|&i| pool[i].clone()).collect();
                pop_objs = survivors.iter().map(|&i| pool_objs[i].clone()).collect();
                front_sizes.push(archive.len());
                trace_front(front_sizes.len() - 1, evals, &archive);
            }
        }

        let front = archive
            .entries()
            .into_iter()
            .map(|e| (e.design, e.objectives))
            .collect();
        MooResult {
            algorithm: self.name(),
            front,
            front_sizes,
            evals,
            wall: t0.elapsed(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pareto::sort::dominates;
    use crate::search::Problem;
    use crate::space::SearchSpace;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Synthetic bi-objective problem: distance to two distinct target
    /// corners of the index space. Its true Pareto set is the "segment"
    /// of designs between the corners.
    struct TwoCorners {
        space: SearchSpace,
        count: AtomicUsize,
    }

    impl TwoCorners {
        fn new() -> TwoCorners {
            TwoCorners {
                space: SearchSpace::rram_reduced(),
                count: AtomicUsize::new(0),
            }
        }

        fn objectives_of(&self, d: &Design) -> Vec<f64> {
            let lo: f64 = d.0.iter().map(|&x| (x as f64).powi(2)).sum();
            let hi: f64 = d
                .0
                .iter()
                .enumerate()
                .map(|(i, &x)| {
                    let top = self.space.params[i].cardinality() as f64 - 1.0;
                    (x as f64 - top).powi(2)
                })
                .sum();
            vec![lo, hi]
        }
    }

    impl Problem for TwoCorners {
        fn space(&self) -> &SearchSpace {
            &self.space
        }
        fn score_batch(&self, designs: &[Design]) -> Vec<f64> {
            self.count.fetch_add(designs.len(), Ordering::Relaxed);
            // scalar view: sum of both objectives
            designs
                .iter()
                .map(|d| self.objectives_of(d).iter().sum())
                .collect()
        }
        fn evals(&self) -> usize {
            self.count.load(Ordering::Relaxed)
        }
    }

    impl MultiObjective for TwoCorners {
        fn objectives(&self) -> usize {
            2
        }
        fn objective_batch(&self, designs: &[Design]) -> Vec<Vec<f64>> {
            self.count.fetch_add(designs.len(), Ordering::Relaxed);
            designs.iter().map(|d| self.objectives_of(d)).collect()
        }
    }

    fn small() -> Nsga2 {
        Nsga2::new(Nsga2Config {
            init: InitStrategy::HammingDiverse { p_h: 60, p_e: 30 },
            cap: 32,
            ..Nsga2Config::paper(SearchBudget { pop: 16, gens: 12 })
        })
    }

    #[test]
    fn front_is_mutually_non_dominating_and_spans_both_corners() {
        let p = TwoCorners::new();
        let r = small().run(&p, &mut Rng::seed_from(3));
        assert!(!r.front.is_empty() && r.front.len() <= 32);
        let objs = r.objective_vectors();
        for (i, a) in objs.iter().enumerate() {
            for (j, b) in objs.iter().enumerate() {
                if i != j {
                    assert!(!dominates(a, b), "front member dominates another");
                }
            }
        }
        // the two extremes must pull apart: best-axis-0 point is much
        // closer to the low corner than the best-axis-1 point is
        let min0 = objs.iter().map(|o| o[0]).fold(f64::INFINITY, f64::min);
        let min1 = objs.iter().map(|o| o[1]).fold(f64::INFINITY, f64::min);
        let max0 = objs.iter().map(|o| o[0]).fold(f64::NEG_INFINITY, f64::max);
        assert!(min0 < max0, "front collapsed to a point");
        assert!(min0.is_finite() && min1.is_finite());
        assert!(r.evals > 0);
        assert!(!r.front_sizes.is_empty());
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let p = TwoCorners::new();
        let a = small().run(&p, &mut Rng::seed_from(7));
        let b = small().run(&TwoCorners::new(), &mut Rng::seed_from(7));
        assert_eq!(a.front.len(), b.front.len());
        for ((da, oa), (db, ob)) in a.front.iter().zip(&b.front) {
            assert_eq!(da, db);
            for (x, y) in oa.iter().zip(ob) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        let c = small().run(&TwoCorners::new(), &mut Rng::seed_from(8));
        // different seed explores differently (coarse check)
        assert!(
            a.front.len() != c.front.len()
                || a.front.iter().zip(&c.front).any(|((da, _), (dc, _))| da != dc)
        );
    }

    #[test]
    fn screened_runs_match_budget_and_explicit_one_matches_default() {
        // explicit screen_frac 1.0 must be the exact loop, bit for bit
        let exact = small().run(&TwoCorners::new(), &mut Rng::seed_from(15));
        let mut one_cfg = small().config;
        one_cfg.screen_frac = 1.0;
        let one = Nsga2::new(one_cfg).run(&TwoCorners::new(), &mut Rng::seed_from(15));
        assert_eq!(exact.front.len(), one.front.len());
        for ((da, oa), (db, ob)) in exact.front.iter().zip(&one.front) {
            assert_eq!(da, db);
            for (x, y) in oa.iter().zip(ob) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // a screened run spends the same exact-evaluation budget and is
        // deterministic per seed
        let mut cfg = small().config;
        cfg.screen_frac = 0.25;
        let a = Nsga2::new(cfg.clone()).run(&TwoCorners::new(), &mut Rng::seed_from(15));
        let b = Nsga2::new(cfg).run(&TwoCorners::new(), &mut Rng::seed_from(15));
        assert_eq!(a.evals, exact.evals, "screening must not change evaluator calls");
        assert_eq!(a.front.len(), b.front.len());
        for ((da, oa), (db, ob)) in a.front.iter().zip(&b.front) {
            assert_eq!(da, db);
            for (x, y) in oa.iter().zip(ob) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn archive_growth_is_monotone_in_coverage() {
        // front size can shrink (better points evict many), but the
        // recorded sizes never exceed the cap and end non-empty
        let p = TwoCorners::new();
        let r = small().run(&p, &mut Rng::seed_from(11));
        assert!(r.front_sizes.iter().all(|&s| s <= 32));
        assert!(*r.front_sizes.last().unwrap() > 0);
    }

    #[test]
    fn selection_keys_order_constraints_first() {
        let feas = SelKey { feasible: true, rank: 3, crowd: 0.0, violation: 0.0, idx: 5 };
        let infeas = SelKey { feasible: false, rank: usize::MAX, crowd: 0.0, violation: 0.1, idx: 0 };
        assert!(feas.better(&infeas));
        assert!(!infeas.better(&feas));
        let worse_v = SelKey { violation: 0.9, ..infeas };
        assert!(infeas.better(&worse_v));
        let better_rank = SelKey { rank: 1, ..feas };
        assert!(better_rank.better(&feas));
        let roomier = SelKey { crowd: 2.0, idx: 9, ..feas };
        assert!(roomier.better(&feas));
        // full tie -> lower index wins, and a key never beats itself
        let tie = SelKey { idx: 6, ..feas };
        assert!(feas.better(&tie));
        assert!(!feas.better(&feas));
    }
}
