//! Fast non-dominated sorting and crowding distance (Deb et al., NSGA-II).
//!
//! All comparisons minimize every objective. The sort is the O(M·N²)
//! "fast non-dominated sort" of the NSGA-II paper: one pass computes each
//! point's domination count and dominated set, then fronts peel off in
//! waves. Output order is deterministic — within a front, points appear
//! in ascending input index — so every consumer (selection, archives,
//! artifacts) is bit-stable across runs and thread counts.
//!
//! Non-finite coordinates carry no dominance information here (`NaN`
//! compares false both ways, so a NaN point ends up mutually
//! non-dominating with everything). Callers that can see infeasible
//! points must keep them out of the sort and rank them separately —
//! [`crate::pareto::nsga2::Nsga2`] does exactly that via
//! constraint-domination on [`crate::search::Problem::violation`].

/// `a` dominates `b`: no worse in every objective, strictly better in at
/// least one (minimization).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strict = false;
    for (&x, &y) in a.iter().zip(b) {
        if x > y {
            return false;
        }
        if x < y {
            strict = true;
        }
    }
    strict
}

/// `a` weakly dominates `b`: no worse in every objective (equal vectors
/// weakly dominate each other). The archive uses this to keep exactly one
/// representative per objective vector.
pub fn weakly_dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).all(|(&x, &y)| x <= y)
}

/// Fast non-dominated sort: partition point indices into fronts.
/// `fronts[0]` is the non-dominated set; every point of `fronts[i]`
/// (i ≥ 1) is dominated by at least one point of `fronts[i − 1]` and by
/// none of `fronts[i..]`. Within a front, indices ascend.
pub fn non_dominated_sort(points: &[Vec<f64>]) -> Vec<Vec<usize>> {
    let n = points.len();
    if n == 0 {
        return Vec::new();
    }
    // dominated[i] = indices i dominates; count[i] = how many dominate i
    let mut dominated: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut count = vec![0usize; n];
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&points[i], &points[j]) {
                dominated[i].push(j);
                count[j] += 1;
            } else if dominates(&points[j], &points[i]) {
                dominated[j].push(i);
                count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| count[i] == 0).collect();
    while !current.is_empty() {
        let mut next: Vec<usize> = Vec::new();
        for &i in &current {
            for &j in &dominated[i] {
                count[j] -= 1;
                if count[j] == 0 {
                    next.push(j);
                }
            }
        }
        // ascending input index keeps the output deterministic regardless
        // of discovery order
        next.sort_unstable();
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Rank of every point: `rank[i]` = index of the front containing `i`
/// (0 = non-dominated). Convenience over [`non_dominated_sort`].
pub fn ranks(points: &[Vec<f64>]) -> Vec<usize> {
    let mut rank = vec![0usize; points.len()];
    for (r, front) in non_dominated_sort(points).iter().enumerate() {
        for &i in front {
            rank[i] = r;
        }
    }
    rank
}

/// Crowding distance of each member of one front (parallel to `front`):
/// the NSGA-II density estimate. Boundary points (per-objective extremes)
/// get `+∞`; interior points sum their normalized neighbor gaps per
/// objective. Degenerate objectives (zero extent) contribute nothing.
/// Ties in an objective sort break by point index, so the assignment is
/// deterministic.
pub fn crowding_distance(points: &[Vec<f64>], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m == 0 {
        return dist;
    }
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let dims = points[front[0]].len();
    for obj in 0..dims {
        // positions into `front`, sorted by this objective (ties by index)
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            points[front[a]][obj]
                .total_cmp(&points[front[b]][obj])
                .then(front[a].cmp(&front[b]))
        });
        let lo = points[front[order[0]]][obj];
        let hi = points[front[order[m - 1]]][obj];
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let extent = hi - lo;
        if extent <= 0.0 || !extent.is_finite() {
            continue;
        }
        for w in 1..m - 1 {
            let gap = points[front[order[w + 1]]][obj] - points[front[order[w - 1]]][obj];
            dist[order[w]] += gap / extent;
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 2.0], &[2.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equal is not strict");
        assert!(weakly_dominates(&[1.0, 1.0], &[1.0, 1.0]));
        assert!(!weakly_dominates(&[1.0, 2.0], &[2.0, 1.0]));
        // NaN carries no dominance either way
        assert!(!dominates(&[f64::NAN, 0.0], &[1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[f64::NAN, 0.0]));
    }

    #[test]
    fn sort_peels_fronts_in_order() {
        // three clear layers on the anti-diagonal plus a dominated tail
        let pts = vec![
            vec![1.0, 4.0], // front 0
            vec![4.0, 1.0], // front 0
            vec![2.0, 5.0], // front 1 (dominated by [1,4])
            vec![5.0, 2.0], // front 1
            vec![6.0, 6.0], // front 2
        ];
        let fronts = non_dominated_sort(&pts);
        assert_eq!(fronts, vec![vec![0, 1], vec![2, 3], vec![4]]);
        assert_eq!(ranks(&pts), vec![0, 0, 1, 1, 2]);
    }

    #[test]
    fn sort_handles_duplicates_and_singletons() {
        // duplicates do not dominate each other -> same front
        let pts = vec![vec![1.0, 1.0], vec![1.0, 1.0], vec![2.0, 2.0]];
        let fronts = non_dominated_sort(&pts);
        assert_eq!(fronts, vec![vec![0, 1], vec![2]]);
        assert!(non_dominated_sort(&[]).is_empty());
        assert_eq!(non_dominated_sort(&[vec![3.0]]), vec![vec![0]]);
    }

    #[test]
    fn crowding_rewards_isolation() {
        // five points on a line; the middle one sits in the densest spot
        let pts = vec![
            vec![0.0, 4.0],
            vec![1.0, 3.0],
            vec![1.5, 2.5], // crowded between neighbors
            vec![2.0, 2.0],
            vec![4.0, 0.0],
        ];
        let front: Vec<usize> = (0..5).collect();
        let d = crowding_distance(&pts, &front);
        assert!(d[0].is_infinite() && d[4].is_infinite());
        assert!(d[2] < d[1] && d[2] < d[3], "{d:?}");
        // small fronts are all-boundary
        assert_eq!(crowding_distance(&pts, &[1, 3]), vec![f64::INFINITY; 2]);
    }

    #[test]
    fn crowding_is_deterministic_under_ties() {
        let pts = vec![vec![1.0, 1.0], vec![1.0, 1.0], vec![1.0, 1.0], vec![1.0, 1.0]];
        let front: Vec<usize> = (0..4).collect();
        let a = crowding_distance(&pts, &front);
        let b = crowding_distance(&pts, &front);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
