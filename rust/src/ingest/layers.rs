//! Layer-list JSON codec — the native workload interchange format.
//!
//! Shape (pinned by `schemas/workload.schema.json`):
//!
//! ```json
//! {
//!   "name": "tiny-cnn",
//!   "layers": [
//!     {"name": "conv1", "kind": "conv", "k": 27, "n": 16, "passes": 12544,
//!      "weights": 432, "in_bytes": 150528, "out_bytes": 200704}
//!   ]
//! }
//! ```
//!
//! Layers are already in matmul view (see `workloads`): the parser
//! validates — positive dims, [`super::MAX_DIM`] caps, weightless dynamic
//! layers — and never derives shapes. Workload → JSON → Workload is
//! bit-identical for every workload this crate can construct (all fields
//! are integers below the exact-f64 window).

use super::{validate_layers, IngestError};
use crate::util::json::{self, Json};
use crate::workloads::{Layer, LayerKind, Workload};

fn kind_str(k: LayerKind) -> &'static str {
    match k {
        LayerKind::Conv => "conv",
        LayerKind::DepthwiseConv => "depthwise_conv",
        LayerKind::Fc => "fc",
        LayerKind::Dynamic => "dynamic",
    }
}

fn kind_from_str(s: &str) -> Result<LayerKind, IngestError> {
    Ok(match s {
        "conv" => LayerKind::Conv,
        "depthwise_conv" => LayerKind::DepthwiseConv,
        "fc" => LayerKind::Fc,
        "dynamic" => LayerKind::Dynamic,
        other => return Err(IngestError::UnknownKind(other.to_string())),
    })
}

/// Read a non-negative integer field (rejects floats, strings, negatives).
fn req_u64(obj: &Json, field: &str, idx: usize) -> Result<u64, IngestError> {
    let at = format!("layers[{idx}].{field}");
    let v = obj.get(field).ok_or(IngestError::Missing(at.clone()))?;
    match v {
        Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x <= (1u64 << 53) as f64 => {
            Ok(*x as u64)
        }
        _ => Err(IngestError::WrongType {
            at,
            expected: "non-negative integer",
        }),
    }
}

fn req_str<'a>(obj: &'a Json, field: &str, at: String) -> Result<&'a str, IngestError> {
    let v = obj.get(field).ok_or(IngestError::Missing(at.clone()))?;
    v.as_str().ok_or(IngestError::WrongType {
        at,
        expected: "string",
    })
}

/// Decode one workload from a parsed JSON document. `fallback_name` is
/// used when the document has no `name` key (e.g. the file stem).
pub fn workload_from_json(j: &Json, fallback_name: &str) -> Result<Workload, IngestError> {
    if !matches!(j, Json::Obj(_)) {
        return Err(IngestError::WrongType {
            at: "$".into(),
            expected: "object",
        });
    }
    let name = match j.get("name") {
        Some(v) => v
            .as_str()
            .ok_or(IngestError::WrongType {
                at: "$.name".into(),
                expected: "string",
            })?
            .to_string(),
        None => fallback_name.to_string(),
    };
    let arr = j
        .get("layers")
        .ok_or(IngestError::Missing("$.layers".into()))?
        .as_arr()
        .ok_or(IngestError::WrongType {
            at: "$.layers".into(),
            expected: "array",
        })?;
    let mut layers = Vec::with_capacity(arr.len());
    for (i, lj) in arr.iter().enumerate() {
        if !matches!(lj, Json::Obj(_)) {
            return Err(IngestError::WrongType {
                at: format!("layers[{i}]"),
                expected: "object",
            });
        }
        let lname = req_str(lj, "name", format!("layers[{i}].name"))?.to_string();
        let kind = kind_from_str(req_str(lj, "kind", format!("layers[{i}].kind"))?)?;
        layers.push(Layer {
            name: lname,
            kind,
            k: req_u64(lj, "k", i)?,
            n: req_u64(lj, "n", i)?,
            passes: req_u64(lj, "passes", i)?,
            weights: req_u64(lj, "weights", i)?,
            in_bytes: req_u64(lj, "in_bytes", i)?,
            out_bytes: req_u64(lj, "out_bytes", i)?,
        });
    }
    validate_layers(&layers)?;
    Ok(Workload::new(name, layers))
}

/// Parse a layer-list JSON document from text.
pub fn parse_workload_text(text: &str, fallback_name: &str) -> Result<Workload, IngestError> {
    let j = json::parse(text).map_err(IngestError::Json)?;
    workload_from_json(&j, fallback_name)
}

/// Encode a workload in the layer-list format (inverse of
/// [`workload_from_json`], bit-identical round trip).
pub fn workload_to_json(w: &Workload) -> Json {
    Json::obj(vec![
        ("name", Json::Str(w.name.clone())),
        (
            "layers",
            Json::Arr(
                w.layers
                    .iter()
                    .map(|l| {
                        Json::obj(vec![
                            ("name", Json::Str(l.name.clone())),
                            ("kind", Json::Str(kind_str(l.kind).into())),
                            ("k", Json::Num(l.k as f64)),
                            ("n", Json::Num(l.n as f64)),
                            ("passes", Json::Num(l.passes as f64)),
                            ("weights", Json::Num(l.weights as f64)),
                            ("in_bytes", Json::Num(l.in_bytes as f64)),
                            ("out_bytes", Json::Num(l.out_bytes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_nets_round_trip_bit_identically() {
        for name in crate::workloads::ALL_NAMES {
            let w = crate::workloads::by_name(name).unwrap();
            let text = workload_to_json(&w).to_string();
            let back = parse_workload_text(&text, "fallback").unwrap();
            assert_eq!(w.name, back.name);
            assert_eq!(w.layers.len(), back.layers.len());
            for (a, b) in w.layers.iter().zip(&back.layers) {
                assert_eq!(a.name, b.name, "{name}");
                assert_eq!(a.kind, b.kind, "{name}");
                assert_eq!(
                    [a.k, a.n, a.passes, a.weights, a.in_bytes, a.out_bytes],
                    [b.k, b.n, b.passes, b.weights, b.in_bytes, b.out_bytes],
                    "{name}:{}",
                    a.name
                );
            }
        }
    }

    #[test]
    fn typed_errors_on_malformed_documents() {
        // truncated JSON
        let err = parse_workload_text("{\"name\": \"x\", \"layers\": [", "f").unwrap_err();
        assert!(matches!(err, IngestError::Json(_)));
        // wrong dtype
        let bad = r#"{"layers": [{"name":"c","kind":"conv","k":"many","n":8,"passes":4,"weights":0,"in_bytes":0,"out_bytes":0}]}"#;
        assert!(matches!(
            parse_workload_text(bad, "f").unwrap_err(),
            IngestError::WrongType { .. }
        ));
        // zero dim
        let zero = r#"{"layers": [{"name":"c","kind":"conv","k":0,"n":8,"passes":4,"weights":0,"in_bytes":0,"out_bytes":0}]}"#;
        assert!(matches!(
            parse_workload_text(zero, "f").unwrap_err(),
            IngestError::ZeroDim { .. }
        ));
        // huge dim
        let huge = r#"{"layers": [{"name":"c","kind":"conv","k":2097152,"n":8,"passes":4,"weights":0,"in_bytes":0,"out_bytes":0}]}"#;
        assert!(matches!(
            parse_workload_text(huge, "f").unwrap_err(),
            IngestError::DimTooLarge { .. }
        ));
        // unknown kind
        let kind = r#"{"layers": [{"name":"c","kind":"pool","k":1,"n":8,"passes":4,"weights":0,"in_bytes":0,"out_bytes":0}]}"#;
        assert!(matches!(
            parse_workload_text(kind, "f").unwrap_err(),
            IngestError::UnknownKind(_)
        ));
        // empty layer list
        assert!(matches!(
            parse_workload_text(r#"{"layers": []}"#, "f").unwrap_err(),
            IngestError::BadLayerCount(0)
        ));
    }

    #[test]
    fn fallback_name_applies_only_without_name_key() {
        let doc = r#"{"layers": [{"name":"c","kind":"fc","k":4,"n":4,"passes":1,"weights":16,"in_bytes":4,"out_bytes":4}]}"#;
        assert_eq!(parse_workload_text(doc, "stem").unwrap().name, "stem");
    }
}
