//! Seeded synthetic workload generator.
//!
//! A [`WorkloadDistribution`] describes a family of plausible networks
//! (depth / channel / kernel / attention-dimension ranges); sampling is a
//! **pure function of `(distribution, seed, index)`** — each population
//! member derives its own RNG from the seed and its index, with no state
//! threaded between members. Populations are therefore bit-identical
//! regardless of `--threads`, `--workers`, construction order, or
//! kill/`--resume` (the `synth:` token rides the `--spec` string, which
//! is already part of the checkpoint config fingerprint).
//!
//! Every emitted layer uses the same matmul-view formulas as the
//! hand-coded tables in `workloads/cnn.rs` / `workloads/transformer.rs`:
//! im2col convs, per-channel depthwise convs, `passes = seq` projections
//! and weightless dynamic attention matmuls. Generated dims stay far
//! inside [`super::MAX_DIM`], so every sample passes ingestion
//! validation and — like all workloads — is covered by the compiled
//! evaluator's geometry grid (see `model::compiled`).

use super::IngestError;
use crate::util::rng::Rng;
use crate::workloads::{Layer, LayerKind, Workload, WorkloadSet};

/// Parameterized distribution over synthetic networks.
#[derive(Clone, Debug)]
pub struct WorkloadDistribution {
    /// Preset name (`cnn` | `transformer` | `mixed`).
    pub id: String,
    /// Probability a sample is a CNN (the rest are transformers).
    pub cnn_frac: f64,
    /// Conv stages per CNN (inclusive range).
    pub stages: (usize, usize),
    /// Convs per stage (inclusive range).
    pub convs_per_stage: (usize, usize),
    /// Stem channel choices.
    pub base_channels: Vec<u64>,
    /// Conv kernel-size choices.
    pub kernels: Vec<u64>,
    /// Chance a stage uses depthwise-separable convs.
    pub depthwise_frac: f64,
    /// Classifier output classes (inclusive range).
    pub classes: (u64, u64),
    /// Transformer model-dimension choices.
    pub d_model: Vec<u64>,
    /// Attention head-count choices (must divide the sampled `d_model`).
    pub heads: Vec<u64>,
    /// Sequence-length choices.
    pub seq: Vec<u64>,
    /// Transformer blocks (inclusive range).
    pub blocks: (usize, usize),
    /// FFN expansion-factor choices.
    pub ffn_mult: Vec<u64>,
}

impl WorkloadDistribution {
    /// Look up a named preset.
    pub fn named(id: &str) -> Result<WorkloadDistribution, IngestError> {
        let base = WorkloadDistribution {
            id: id.to_string(),
            cnn_frac: 0.5,
            stages: (3, 5),
            convs_per_stage: (1, 3),
            base_channels: vec![16, 24, 32, 48, 64],
            kernels: vec![1, 3, 3, 5, 7],
            depthwise_frac: 0.3,
            classes: (10, 1000),
            d_model: vec![128, 192, 256, 384, 512, 768],
            heads: vec![2, 4, 8, 12],
            seq: vec![64, 128, 196, 256, 384, 512],
            blocks: (2, 12),
            ffn_mult: vec![2, 3, 4],
        };
        match id {
            "mixed" => Ok(base),
            "cnn" => Ok(WorkloadDistribution {
                cnn_frac: 1.0,
                ..base
            }),
            "transformer" => Ok(WorkloadDistribution {
                cnn_frac: 0.0,
                ..base
            }),
            other => Err(IngestError::Synth(format!(
                "unknown distribution '{other}' (cnn|transformer|mixed)"
            ))),
        }
    }

    /// Draw one network. Pure in `rng`: the same RNG state always yields
    /// the same workload.
    pub fn sample(&self, name: impl Into<String>, rng: &mut Rng) -> Workload {
        if rng.chance(self.cnn_frac) {
            self.sample_cnn(name, rng)
        } else {
            self.sample_transformer(name, rng)
        }
    }

    fn sample_cnn(&self, name: impl Into<String>, rng: &mut Rng) -> Workload {
        let mut layers = Vec::new();
        let mut hw: u64 = *rng.choose(&[32, 64, 96, 128, 224]);
        let mut c: u64 = 3;
        let mut cout = *rng.choose(&self.base_channels);
        // stem: stride-2 conv
        let k0 = *rng.choose(&[3, 5, 7]);
        hw = conv_out(hw, k0, 2);
        layers.push(conv("stem", c, cout, k0, hw));
        c = cout;
        let stages = rng.range(self.stages.0, self.stages.1);
        for s in 0..stages {
            let depthwise = rng.chance(self.depthwise_frac);
            let convs = rng.range(self.convs_per_stage.0, self.convs_per_stage.1);
            for j in 0..convs {
                let kk = *rng.choose(&self.kernels);
                if depthwise {
                    layers.push(Layer {
                        name: format!("s{s}.dw{j}"),
                        kind: LayerKind::DepthwiseConv,
                        k: kk * kk,
                        n: c,
                        passes: hw * hw,
                        weights: kk * kk * c,
                        in_bytes: c * hw * hw,
                        out_bytes: c * hw * hw,
                    });
                    layers.push(conv(&format!("s{s}.pw{j}"), c, cout, 1, hw));
                } else {
                    layers.push(conv(&format!("s{s}.conv{j}"), c, cout, kk, hw));
                }
                c = cout;
            }
            // downsample and widen between stages (cap width at 512)
            if hw > 7 {
                hw = conv_out(hw, 3, 2);
            }
            cout = (cout * 2).min(512);
        }
        // global average pool -> classifier
        let classes = self.classes.0 + rng.below((self.classes.1 - self.classes.0 + 1) as usize) as u64;
        layers.push(Layer {
            name: "fc".into(),
            kind: LayerKind::Fc,
            k: c,
            n: classes,
            passes: 1,
            weights: c * classes,
            in_bytes: c,
            out_bytes: classes,
        });
        Workload::new(name, layers)
    }

    fn sample_transformer(&self, name: impl Into<String>, rng: &mut Rng) -> Workload {
        let d = *rng.choose(&self.d_model);
        let divisors: Vec<u64> = self.heads.iter().copied().filter(|h| d % h == 0).collect();
        let heads = *rng.choose(&divisors);
        let hd = d / heads;
        let seq = *rng.choose(&self.seq);
        let blocks = rng.range(self.blocks.0, self.blocks.1);
        let ffn = *rng.choose(&self.ffn_mult) * d;
        let mut layers = Vec::new();
        for b in 0..blocks {
            layers.push(proj(&format!("blk{b}.qkv"), d, 3 * d, seq));
            layers.push(attn(&format!("blk{b}.scores"), heads, hd, seq));
            layers.push(attn(&format!("blk{b}.context"), heads, hd, seq));
            layers.push(proj(&format!("blk{b}.attn_out"), d, d, seq));
            layers.push(proj(&format!("blk{b}.ffn_up"), d, ffn, seq));
            layers.push(proj(&format!("blk{b}.ffn_down"), ffn, d, seq));
        }
        let classes = self.classes.0 + rng.below((self.classes.1 - self.classes.0 + 1) as usize) as u64;
        layers.push(proj("head", d, classes, 1));
        Workload::new(name, layers)
    }

    /// Generate a population of `n` networks. Member `i` is a pure
    /// function of `(self.id, seed, i)` — no RNG state crosses members,
    /// so any subset can be regenerated independently and the set is
    /// identical for every thread/worker/resume schedule.
    pub fn population(&self, n: usize, seed: u64) -> WorkloadSet {
        let workloads = (0..n)
            .map(|i| {
                let mut rng = self.member_rng(seed, i);
                self.sample(format!("syn-{}-s{seed}-{i:03}", self.id), &mut rng)
            })
            .collect();
        WorkloadSet { workloads }
    }

    fn member_rng(&self, seed: u64, i: usize) -> Rng {
        // fold the distribution id in so e.g. cnn/mixed populations at the
        // same seed differ; FNV-1a over the id bytes
        let mut h: u64 = 0xcbf29ce484222325;
        for b in self.id.as_bytes() {
            h = (h ^ u64::from(*b)).wrapping_mul(0x100000001b3);
        }
        Rng::seed_from(
            seed ^ h ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15),
        )
    }
}

/// `(hw + 2·pad − k)/stride + 1` with same-ish padding `k/2`.
fn conv_out(hw: u64, k: u64, stride: u64) -> u64 {
    ((hw + 2 * (k / 2) - k) / stride + 1).max(1)
}

fn conv(name: &str, cin: u64, cout: u64, k: u64, out_hw: u64) -> Layer {
    Layer {
        name: name.to_string(),
        kind: LayerKind::Conv,
        k: k * k * cin,
        n: cout,
        passes: out_hw * out_hw,
        weights: k * k * cin * cout,
        in_bytes: cin * out_hw * out_hw,
        out_bytes: cout * out_hw * out_hw,
    }
}

fn proj(name: &str, k: u64, n: u64, seq: u64) -> Layer {
    Layer {
        name: name.to_string(),
        kind: LayerKind::Fc,
        k,
        n,
        passes: seq,
        weights: k * n,
        in_bytes: seq * k,
        out_bytes: seq * n,
    }
}

fn attn(name: &str, heads: u64, head_dim: u64, seq: u64) -> Layer {
    Layer {
        name: name.to_string(),
        kind: LayerKind::Dynamic,
        k: heads * head_dim,
        n: seq,
        passes: seq,
        weights: 0,
        in_bytes: 2 * seq * heads * head_dim,
        out_bytes: seq * seq * heads / 8,
    }
}

/// Parse a `synth:<dist>:<n>:<seed>` token into its population.
/// (`ScenarioSpec::parse` recognizes the `synth:` prefix and hands the
/// first three `:`-separated fields here.)
pub fn parse_synth_parts(dist: &str, n: &str, seed: &str) -> Result<(WorkloadDistribution, usize, u64), IngestError> {
    let d = WorkloadDistribution::named(dist)?;
    let n: usize = n
        .parse()
        .map_err(|_| IngestError::Synth(format!("bad population size '{n}'")))?;
    if n == 0 || n > 4096 {
        return Err(IngestError::Synth(format!(
            "population size {n} outside 1..=4096"
        )));
    }
    let seed: u64 = seed
        .parse()
        .map_err(|_| IngestError::Synth(format!("bad seed '{seed}'")))?;
    Ok((d, n, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn populations_are_pure_functions_of_seed_and_index() {
        let d = WorkloadDistribution::named("mixed").unwrap();
        let a = d.population(20, 7);
        let b = d.population(20, 7);
        for (x, y) in a.workloads.iter().zip(&b.workloads) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.layers.len(), y.layers.len());
            for (la, lb) in x.layers.iter().zip(&y.layers) {
                assert_eq!(
                    [la.k, la.n, la.passes, la.weights, la.in_bytes, la.out_bytes],
                    [lb.k, lb.n, lb.passes, lb.weights, lb.in_bytes, lb.out_bytes]
                );
            }
        }
        // member i alone matches member i of the full population
        let mut rng = d.member_rng(7, 13);
        let solo = d.sample("syn-mixed-s7-013".to_string(), &mut rng);
        assert_eq!(solo.layers.len(), a.workloads[13].layers.len());
        assert_eq!(solo.layers[0].k, a.workloads[13].layers[0].k);
    }

    #[test]
    fn different_seeds_and_distributions_differ() {
        let d = WorkloadDistribution::named("mixed").unwrap();
        let a = d.population(10, 1);
        let b = d.population(10, 2);
        let same = a
            .workloads
            .iter()
            .zip(&b.workloads)
            .all(|(x, y)| x.layers.len() == y.layers.len() && x.layers[0].k == y.layers[0].k);
        assert!(!same, "seed must matter");
        let cnn = WorkloadDistribution::named("cnn").unwrap().population(10, 1);
        assert!(cnn
            .workloads
            .iter()
            .all(|w| w.layers.iter().all(|l| !l.dynamic())));
        let tf = WorkloadDistribution::named("transformer")
            .unwrap()
            .population(10, 1);
        assert!(tf
            .workloads
            .iter()
            .all(|w| w.layers.iter().any(|l| l.dynamic())));
    }

    #[test]
    fn every_sample_passes_ingestion_validation() {
        for dist in ["cnn", "transformer", "mixed"] {
            let d = WorkloadDistribution::named(dist).unwrap();
            for (i, w) in d.population(50, 99).workloads.iter().enumerate() {
                super::super::validate_layers(&w.layers)
                    .unwrap_or_else(|e| panic!("{dist}[{i}] {}: {e}", w.name));
                assert!(!w.layers.is_empty());
                assert!(w.total_weights() > 0, "{dist}[{i}]");
            }
        }
    }

    #[test]
    fn token_parsing_rejects_bad_fields() {
        assert!(parse_synth_parts("mixed", "200", "11").is_ok());
        assert!(matches!(
            parse_synth_parts("gan", "10", "1").unwrap_err(),
            IngestError::Synth(_)
        ));
        assert!(parse_synth_parts("cnn", "0", "1").is_err());
        assert!(parse_synth_parts("cnn", "9999", "1").is_err());
        assert!(parse_synth_parts("cnn", "ten", "1").is_err());
        assert!(parse_synth_parts("cnn", "10", "-3").is_err());
    }
}
