//! Workload ingestion: external model files and synthetic populations.
//!
//! The repo's credibility at "hundreds of workloads" scale (ROADMAP
//! direction 2) needs more than the 9 hand-coded nets of `workloads/`.
//! This module turns three external sources into [`Workload`] values that
//! flow through the exact same compiled-evaluator path:
//!
//! * **Layer-list JSON** ([`layers`]) — the repo's native interchange
//!   format, schema-pinned under `schemas/workload.schema.json`. Every
//!   layer is already in matmul view (`k`/`n`/`passes`/traffic), so the
//!   parser only validates; it never guesses shapes.
//! * **ONNX subset** ([`onnx`]) — a pragmatic reader for the protobuf
//!   wire format covering Conv / Gemm / MatMul (weight-stationary and
//!   activation×activation) plus the shape-plumbing ops between them,
//!   in the spirit of ZigZag-IMC's model ingestion. No protobuf
//!   dependency: the subset decoder is ~200 lines of varint walking.
//! * **Seeded synthetic generator** ([`synth`]) — parameterized
//!   [`WorkloadDistribution`]s over depth/channel/kernel/attention dims.
//!   Sampling is a pure function of `(distribution, seed, index)`, so
//!   populations are bit-identical across `--threads`, `--workers` and
//!   kill/`--resume`.
//!
//! All parsers return typed [`IngestError`]s and never panic on
//! malformed input (fuzz-style corpus under `rust/tests/ingest/`).

pub mod layers;
pub mod onnx;
pub mod synth;

pub use layers::{parse_workload_text, workload_from_json, workload_to_json};
pub use onnx::workload_from_onnx;
pub use synth::WorkloadDistribution;

use crate::workloads::{Workload, L_MAX};
use std::path::Path;

/// Hard cap on the matmul dimensions (`k`, `n`, `passes`) of an ingested
/// layer. Well below 2^53, so every derived quantity (weights ≤ `k·n` ≤
/// 2^40, MACs per layer ≤ 2^60 summed in f64-exact buckets) survives the
/// JSON round trip through `util::json`'s f64 numbers bit-identically.
pub const MAX_DIM: u64 = 1 << 20;

/// Cap on explicit byte/weight counts — kept under `1e15` so
/// `util::json` prints them via its exact-integer path.
pub const MAX_BYTES: u64 = 1 << 49;

/// Typed ingestion failure. Parsers return these — they never panic on
/// malformed input.
#[derive(Debug, Clone, PartialEq)]
pub enum IngestError {
    /// File could not be read.
    Io(String),
    /// Text is not valid JSON (truncation lands here).
    Json(String),
    /// A field exists but has the wrong JSON type.
    WrongType {
        at: String,
        expected: &'static str,
    },
    /// A required field is missing.
    Missing(String),
    /// A layer kind string outside the enum.
    UnknownKind(String),
    /// A matmul dimension is zero.
    ZeroDim { at: String },
    /// A dimension exceeds [`MAX_DIM`] / [`MAX_BYTES`].
    DimTooLarge { at: String, value: u64, max: u64 },
    /// No layers / more than [`L_MAX`] layers.
    BadLayerCount(usize),
    /// A dynamic layer declaring stored weights.
    DynamicWithWeights { at: String },
    /// Malformed ONNX protobuf or unsupported construct.
    Onnx(String),
    /// Unknown synthetic distribution or bad `synth:` token.
    Synth(String),
    /// Path has no recognized extension.
    UnknownFormat(String),
}

impl std::fmt::Display for IngestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IngestError::Io(m) => write!(f, "ingest: io error: {m}"),
            IngestError::Json(m) => write!(f, "ingest: invalid JSON: {m}"),
            IngestError::WrongType { at, expected } => {
                write!(f, "ingest: {at}: expected {expected}")
            }
            IngestError::Missing(at) => write!(f, "ingest: missing required field {at}"),
            IngestError::UnknownKind(k) => write!(
                f,
                "ingest: unknown layer kind '{k}' (conv|depthwise_conv|fc|dynamic)"
            ),
            IngestError::ZeroDim { at } => write!(f, "ingest: {at}: dimension must be >= 1"),
            IngestError::DimTooLarge { at, value, max } => {
                write!(f, "ingest: {at}: {value} exceeds the maximum {max}")
            }
            IngestError::BadLayerCount(n) => {
                write!(f, "ingest: workload must have 1..={L_MAX} layers, got {n}")
            }
            IngestError::DynamicWithWeights { at } => {
                write!(f, "ingest: {at}: dynamic layers carry no stored weights")
            }
            IngestError::Onnx(m) => write!(f, "ingest: onnx: {m}"),
            IngestError::Synth(m) => write!(f, "ingest: synth: {m}"),
            IngestError::UnknownFormat(p) => {
                write!(f, "ingest: unrecognized workload file format: {p} (.json or .onnx)")
            }
        }
    }
}

impl std::error::Error for IngestError {}

/// Load a workload from a file path, dispatching on extension:
/// `.json` → layer-list format, `.onnx` → ONNX subset. The file stem is
/// the fallback workload name (layer-list files may override it).
pub fn load_path(path: &Path) -> Result<Workload, IngestError> {
    let stem = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("workload")
        .to_string();
    let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
    match ext {
        "json" => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| IngestError::Io(format!("{}: {e}", path.display())))?;
            parse_workload_text(&text, &stem)
        }
        "onnx" => {
            let bytes = std::fs::read(path)
                .map_err(|e| IngestError::Io(format!("{}: {e}", path.display())))?;
            workload_from_onnx(&bytes, &stem)
        }
        _ => Err(IngestError::UnknownFormat(path.display().to_string())),
    }
}

/// Whether a `--spec` workload token names a file (vs a canonical
/// workload): anything with a path separator or a recognized extension.
pub fn looks_like_path(token: &str) -> bool {
    token.contains('/') || token.ends_with(".json") || token.ends_with(".onnx")
}

/// Shared per-layer validation used by every ingestion path (and by the
/// generator's tests): positive on-grid-cappable dims, bounded traffic,
/// weightless dynamic layers.
pub(crate) fn validate_layer(l: &crate::workloads::Layer, idx: usize) -> Result<(), IngestError> {
    let at = |field: &str| format!("layers[{idx}].{field}");
    for (field, v) in [("k", l.k), ("n", l.n), ("passes", l.passes)] {
        if v == 0 {
            return Err(IngestError::ZeroDim { at: at(field) });
        }
        if v > MAX_DIM {
            return Err(IngestError::DimTooLarge {
                at: at(field),
                value: v,
                max: MAX_DIM,
            });
        }
    }
    for (field, v) in [
        ("weights", l.weights),
        ("in_bytes", l.in_bytes),
        ("out_bytes", l.out_bytes),
    ] {
        if v > MAX_BYTES {
            return Err(IngestError::DimTooLarge {
                at: at(field),
                value: v,
                max: MAX_BYTES,
            });
        }
    }
    if l.dynamic() && l.weights != 0 {
        return Err(IngestError::DynamicWithWeights { at: at("weights") });
    }
    Ok(())
}

/// Validate a whole layer list (count + per-layer rules).
pub(crate) fn validate_layers(layers: &[crate::workloads::Layer]) -> Result<(), IngestError> {
    if layers.is_empty() || layers.len() > L_MAX {
        return Err(IngestError::BadLayerCount(layers.len()));
    }
    for (i, l) in layers.iter().enumerate() {
        validate_layer(l, i)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_dispatch_rejects_unknown_extensions() {
        let err = load_path(Path::new("model.tflite")).unwrap_err();
        assert!(matches!(err, IngestError::UnknownFormat(_)));
        assert!(err.to_string().contains(".onnx"));
    }

    #[test]
    fn path_detection() {
        assert!(looks_like_path("models/net.json"));
        assert!(looks_like_path("net.onnx"));
        assert!(looks_like_path("./a"));
        assert!(!looks_like_path("resnet18"));
        assert!(!looks_like_path("synth"));
    }

    #[test]
    fn missing_file_is_io_error_not_panic() {
        let err = load_path(Path::new("/nonexistent/net.json")).unwrap_err();
        assert!(matches!(err, IngestError::Io(_)));
    }
}
