//! Pragmatic ONNX-subset reader (no protobuf dependency).
//!
//! ONNX models are protobuf messages; the container has no protobuf
//! crate, so this module hand-rolls the ~6 message types the matmul view
//! needs from the wire format directly (varints + length-delimited
//! fields, skipping everything unknown — the format's own
//! forward-compatibility rule).
//!
//! Supported compute ops: `Conv` (incl. grouped/depthwise), `Gemm`,
//! `MatMul` (weight-stationary when the right operand is an initializer,
//! activation×activation → [`LayerKind::Dynamic`] otherwise — the
//! attention score/context pattern). Shape plumbing: pooling ops,
//! `Flatten`, `Reshape` (constant target), `Transpose`, and
//! shape-preserving elementwise/norm ops. Anything else drops its output
//! shapes; that only becomes an error if a later matmul op needs them.
//!
//! All failures are typed [`IngestError`]s; malformed bytes never panic.

use super::{validate_layers, IngestError};
use crate::workloads::{Layer, LayerKind, Workload};
use std::collections::HashMap;

fn err(msg: impl Into<String>) -> IngestError {
    IngestError::Onnx(msg.into())
}

// ---------------------------------------------------------------- wire

#[derive(Debug)]
enum Wire<'a> {
    Varint(u64),
    Fixed64,
    Len(&'a [u8]),
    Fixed32,
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn done(&self) -> bool {
        self.pos >= self.buf.len()
    }

    fn varint(&mut self) -> Result<u64, IngestError> {
        let mut out = 0u64;
        for shift in (0..64).step_by(7) {
            let b = *self
                .buf
                .get(self.pos)
                .ok_or_else(|| err("truncated varint"))?;
            self.pos += 1;
            out |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(out);
            }
        }
        Err(err("varint longer than 10 bytes"))
    }

    /// Next `(field_number, payload)` pair, skipping over fixed-width
    /// payloads (we never need them; they are consumed for framing).
    fn field(&mut self) -> Result<(u64, Wire<'a>), IngestError> {
        let key = self.varint()?;
        let field = key >> 3;
        match key & 7 {
            0 => Ok((field, Wire::Varint(self.varint()?))),
            1 => {
                self.take(8)?;
                Ok((field, Wire::Fixed64))
            }
            2 => {
                let len = self.varint()? as usize;
                Ok((field, Wire::Len(self.take(len)?)))
            }
            5 => {
                self.take(4)?;
                Ok((field, Wire::Fixed32))
            }
            w => Err(err(format!("unsupported wire type {w}"))),
        }
    }

    fn take(&mut self, len: usize) -> Result<&'a [u8], IngestError> {
        let end = self
            .pos
            .checked_add(len)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| err("truncated length-delimited field"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }
}

fn utf8(b: &[u8]) -> Result<String, IngestError> {
    String::from_utf8(b.to_vec()).map_err(|_| err("invalid utf-8 string"))
}

/// Repeated int64: packed (one LEN payload) or one unpacked varint.
fn push_i64s(out: &mut Vec<i64>, w: &Wire<'_>) -> Result<(), IngestError> {
    match w {
        Wire::Varint(v) => out.push(*v as i64),
        Wire::Len(b) => {
            let mut r = Reader::new(b);
            while !r.done() {
                out.push(r.varint()? as i64);
            }
        }
        _ => return Err(err("bad wire type for repeated int64")),
    }
    Ok(())
}

// ------------------------------------------------------------ messages

#[derive(Default)]
struct Attr {
    name: String,
    i: Option<i64>,
    ints: Vec<i64>,
}

fn parse_attr(b: &[u8]) -> Result<Attr, IngestError> {
    let mut r = Reader::new(b);
    let mut a = Attr::default();
    while !r.done() {
        match r.field()? {
            (1, Wire::Len(s)) => a.name = utf8(s)?,
            (3, Wire::Varint(v)) => a.i = Some(v as i64),
            (8, w) => push_i64s(&mut a.ints, &w)?,
            _ => {}
        }
    }
    Ok(a)
}

#[derive(Default)]
struct Node {
    inputs: Vec<String>,
    outputs: Vec<String>,
    name: String,
    op: String,
    attrs: Vec<Attr>,
}

impl Node {
    fn attr_i(&self, name: &str, default: i64) -> i64 {
        self.attrs
            .iter()
            .find(|a| a.name == name)
            .and_then(|a| a.i)
            .unwrap_or(default)
    }
    fn attr_ints(&self, name: &str) -> Option<&[i64]> {
        self.attrs
            .iter()
            .find(|a| a.name == name)
            .map(|a| a.ints.as_slice())
    }
    fn label(&self, idx: usize) -> String {
        if self.name.is_empty() {
            format!("{}_{idx}", self.op.to_lowercase())
        } else {
            self.name.clone()
        }
    }
}

fn parse_node(b: &[u8]) -> Result<Node, IngestError> {
    let mut r = Reader::new(b);
    let mut n = Node::default();
    while !r.done() {
        match r.field()? {
            (1, Wire::Len(s)) => n.inputs.push(utf8(s)?),
            (2, Wire::Len(s)) => n.outputs.push(utf8(s)?),
            (3, Wire::Len(s)) => n.name = utf8(s)?,
            (4, Wire::Len(s)) => n.op = utf8(s)?,
            (5, Wire::Len(s)) => n.attrs.push(parse_attr(s)?),
            _ => {}
        }
    }
    Ok(n)
}

struct Tensor {
    name: String,
    dims: Vec<i64>,
    data_type: i64,
    /// Constant int64 payload (only kept when small — Reshape targets).
    i64s: Vec<i64>,
}

fn parse_tensor(b: &[u8]) -> Result<Tensor, IngestError> {
    let mut r = Reader::new(b);
    let mut t = Tensor {
        name: String::new(),
        dims: Vec::new(),
        data_type: 0,
        i64s: Vec::new(),
    };
    let mut raw: &[u8] = &[];
    while !r.done() {
        match r.field()? {
            (1, w) => push_i64s(&mut t.dims, &w)?,
            (2, Wire::Varint(v)) => t.data_type = v as i64,
            (7, w) => push_i64s(&mut t.i64s, &w)?,
            (8, Wire::Len(s)) => t.name = utf8(s)?,
            (9, Wire::Len(s)) => raw = s,
            _ => {}
        }
    }
    // int64 constants may arrive as raw little-endian bytes instead
    if t.i64s.is_empty() && t.data_type == 7 && raw.len() % 8 == 0 && raw.len() <= 128 {
        for c in raw.chunks_exact(8) {
            t.i64s.push(i64::from_le_bytes(c.try_into().unwrap()));
        }
    }
    Ok(t)
}

/// ValueInfoProto → (name, dims); symbolic/zero dims read as 1 (batch).
fn parse_value_info(b: &[u8]) -> Result<Option<(String, Vec<u64>)>, IngestError> {
    let mut r = Reader::new(b);
    let mut name = String::new();
    let mut ty: &[u8] = &[];
    while !r.done() {
        match r.field()? {
            (1, Wire::Len(s)) => name = utf8(s)?,
            (2, Wire::Len(s)) => ty = s,
            _ => {}
        }
    }
    // TypeProto.tensor_type(1) -> Tensor.shape(2) -> TensorShapeProto.dim(1)
    let mut r = Reader::new(ty);
    let mut tensor: &[u8] = &[];
    while !r.done() {
        if let (1, Wire::Len(s)) = r.field()? {
            tensor = s;
        }
    }
    let mut r = Reader::new(tensor);
    let mut shape: &[u8] = &[];
    while !r.done() {
        if let (2, Wire::Len(s)) = r.field()? {
            shape = s;
        }
    }
    let mut dims = Vec::new();
    let mut r = Reader::new(shape);
    while !r.done() {
        if let (1, Wire::Len(dim)) = r.field()? {
            let mut dr = Reader::new(dim);
            let mut v = 1u64; // dim_param / absent → batch-like, read as 1
            while !dr.done() {
                if let (1, Wire::Varint(x)) = dr.field()? {
                    v = if x == 0 { 1 } else { x };
                }
            }
            dims.push(v);
        }
    }
    if dims.is_empty() {
        return Ok(None);
    }
    Ok(Some((name, dims)))
}

// ------------------------------------------------------------- mapping

fn mul(a: u64, b: u64) -> Result<u64, IngestError> {
    a.checked_mul(b).ok_or_else(|| err("dimension overflow"))
}

fn prod(dims: &[u64]) -> Result<u64, IngestError> {
    dims.iter().try_fold(1u64, |acc, &d| mul(acc, d))
}

fn udims(t: &Tensor) -> Result<Vec<u64>, IngestError> {
    t.dims
        .iter()
        .map(|&d| u64::try_from(d).map_err(|_| err(format!("negative dim in tensor '{}'", t.name))))
        .collect()
}

/// Conv/pool spatial output size, floor mode.
fn out_spatial(
    input: u64,
    kernel: u64,
    stride: u64,
    pad: u64,
    dil: u64,
) -> Result<u64, IngestError> {
    let eff = mul(kernel.saturating_sub(1), dil)? + 1;
    let padded = input + 2 * pad;
    let span = padded
        .checked_sub(eff)
        .ok_or_else(|| err("kernel larger than padded input"))?;
    Ok(span / stride.max(1) + 1)
}

struct Shapes {
    act: HashMap<String, Vec<u64>>,
}

impl Shapes {
    fn need(&self, name: &str, node: &str) -> Result<&Vec<u64>, IngestError> {
        self.act
            .get(name)
            .ok_or_else(|| err(format!("missing shape for input '{name}' of node '{node}'")))
    }
}

/// Decode an ONNX model into a [`Workload`] named `name`.
pub fn workload_from_onnx(bytes: &[u8], name: &str) -> Result<Workload, IngestError> {
    // ModelProto.graph = field 7
    let mut r = Reader::new(bytes);
    let mut graph: &[u8] = &[];
    while !r.done() {
        if let (7, Wire::Len(g)) = r.field()? {
            graph = g;
        }
    }
    if graph.is_empty() {
        return Err(err("no graph in model"));
    }

    // GraphProto: node=1, initializer=5, input=11
    let mut nodes = Vec::new();
    let mut inits: HashMap<String, Tensor> = HashMap::new();
    let mut shapes = Shapes {
        act: HashMap::new(),
    };
    let mut r = Reader::new(graph);
    while !r.done() {
        match r.field()? {
            (1, Wire::Len(b)) => nodes.push(parse_node(b)?),
            (5, Wire::Len(b)) => {
                let t = parse_tensor(b)?;
                if t.data_type == 8 {
                    return Err(err(format!(
                        "unsupported string tensor dtype in initializer '{}'",
                        t.name
                    )));
                }
                inits.insert(t.name.clone(), t);
            }
            (11, Wire::Len(b)) => {
                if let Some((n, dims)) = parse_value_info(b)? {
                    shapes.act.insert(n, dims);
                }
            }
            _ => {}
        }
    }
    // initializers shadow graph inputs (standard ONNX layout)
    for n in inits.keys() {
        shapes.act.remove(n);
    }

    let mut layers = Vec::new();
    for (idx, node) in nodes.iter().enumerate() {
        map_node(node, idx, &inits, &mut shapes, &mut layers)?;
    }
    if layers.is_empty() {
        return Err(err("no mappable Conv/Gemm/MatMul layers found"));
    }
    validate_layers(&layers)?;
    Ok(Workload::new(name, layers))
}

fn map_node(
    node: &Node,
    idx: usize,
    inits: &HashMap<String, Tensor>,
    shapes: &mut Shapes,
    layers: &mut Vec<Layer>,
) -> Result<(), IngestError> {
    let label = node.label(idx);
    match node.op.as_str() {
        "Conv" => {
            let x = shapes.need(node.inputs.first().map_or("", |s| s), &label)?.clone();
            let wname = node.inputs.get(1).ok_or_else(|| err(format!("{label}: Conv without weights")))?;
            let w = inits
                .get(wname)
                .ok_or_else(|| err(format!("{label}: weight '{wname}' is not an initializer")))?;
            let wd = udims(w)?;
            if wd.len() != 4 || x.len() < 3 {
                return Err(err(format!("{label}: expected 4-D weights and 3/4-D input")));
            }
            let (c, h, wi) = match x.len() {
                3 => (x[0], x[1], x[2]),
                _ => (x[1], x[2], x[3]),
            };
            let (cout, cin_g, kh, kw) = (wd[0], wd[1], wd[2], wd[3]);
            let group = u64::try_from(node.attr_i("group", 1)).map_err(|_| err("bad group"))?.max(1);
            let get2 = |name: &str, d: u64| -> (u64, u64) {
                match node.attr_ints(name) {
                    Some([a, b, ..]) => (*a as u64, *b as u64),
                    Some([a]) => (*a as u64, *a as u64),
                    _ => (d, d),
                }
            };
            let (sh, sw) = get2("strides", 1);
            let (dh, dw) = get2("dilations", 1);
            let (ph, pw) = match node.attr_ints("pads") {
                Some([a, b, _, _]) => (*a as u64, *b as u64),
                Some([a, b]) => (*a as u64, *b as u64),
                _ => (0, 0),
            };
            let oh = out_spatial(h, kh, sh, ph, dh)?;
            let ow = out_spatial(wi, kw, sw, pw, dw)?;
            let passes = mul(oh, ow)?;
            let depthwise = group == c && cout == c && cin_g == 1;
            let (kind, k, n) = if depthwise {
                (LayerKind::DepthwiseConv, mul(kh, kw)?, c)
            } else {
                (LayerKind::Conv, mul(mul(kh, kw)?, cin_g)?, cout)
            };
            layers.push(Layer {
                name: label,
                kind,
                k,
                n,
                passes,
                weights: mul(mul(cout, cin_g)?, mul(kh, kw)?)?,
                in_bytes: mul(c, mul(h, wi)?)?,
                out_bytes: mul(cout, passes)?,
            });
            if let Some(out) = node.outputs.first() {
                shapes.act.insert(out.clone(), vec![1, cout, oh, ow]);
            }
        }
        "Gemm" => {
            let x = shapes.need(node.inputs.first().map_or("", |s| s), &label)?.clone();
            let wname = node.inputs.get(1).ok_or_else(|| err(format!("{label}: Gemm without weights")))?;
            let w = inits
                .get(wname)
                .ok_or_else(|| err(format!("{label}: weight '{wname}' is not an initializer")))?;
            let wd = udims(w)?;
            if wd.len() != 2 {
                return Err(err(format!("{label}: Gemm weights must be 2-D")));
            }
            let (k, n) = if node.attr_i("transB", 0) != 0 {
                (wd[1], wd[0])
            } else {
                (wd[0], wd[1])
            };
            let m = prod(&x)? / k.max(1);
            let passes = m.max(1);
            layers.push(Layer {
                name: label,
                kind: LayerKind::Fc,
                k,
                n,
                passes,
                weights: mul(k, n)?,
                in_bytes: mul(passes, k)?,
                out_bytes: mul(passes, n)?,
            });
            if let Some(out) = node.outputs.first() {
                shapes.act.insert(out.clone(), vec![passes, n]);
            }
        }
        "MatMul" => {
            let a = shapes.need(node.inputs.first().map_or("", |s| s), &label)?.clone();
            let bname = node.inputs.get(1).ok_or_else(|| err(format!("{label}: MatMul needs 2 inputs")))?;
            if let Some(w) = inits.get(bname) {
                // weight-stationary: right operand is a constant matrix
                let wd = udims(w)?;
                if wd.len() < 2 {
                    return Err(err(format!("{label}: MatMul weights must be >= 2-D")));
                }
                let (k, n) = (wd[wd.len() - 2], wd[wd.len() - 1]);
                let passes = (prod(&a)? / k.max(1)).max(1);
                layers.push(Layer {
                    name: label,
                    kind: LayerKind::Fc,
                    k,
                    n,
                    passes,
                    weights: mul(k, n)?,
                    in_bytes: mul(passes, k)?,
                    out_bytes: mul(passes, n)?,
                });
                if let Some(out) = node.outputs.first() {
                    shapes.act.insert(out.clone(), vec![passes, n]);
                }
            } else {
                // activation×activation — the attention pattern
                let b = shapes.need(bname, &label)?.clone();
                if a.len() < 2 || b.len() < 2 {
                    return Err(err(format!("{label}: dynamic MatMul operands must be >= 2-D")));
                }
                let k = a[a.len() - 1];
                let n = b[b.len() - 1];
                if b[b.len() - 2] != k {
                    return Err(err(format!("{label}: inner dims disagree")));
                }
                let m_total = (prod(&a)? / k.max(1)).max(1);
                let in_bytes = prod(&a)? + prod(&b)?;
                let mut out_shape = a[..a.len() - 1].to_vec();
                out_shape.push(n);
                layers.push(Layer {
                    name: label,
                    kind: LayerKind::Dynamic,
                    k,
                    n,
                    passes: m_total,
                    weights: 0,
                    in_bytes,
                    out_bytes: mul(m_total, n)?,
                });
                if let Some(out) = node.outputs.first() {
                    shapes.act.insert(out.clone(), out_shape);
                }
            }
        }
        "MaxPool" | "AveragePool" => {
            let x = shapes.need(node.inputs.first().map_or("", |s| s), &label)?.clone();
            if x.len() == 4 {
                let ks = node.attr_ints("kernel_shape").unwrap_or(&[1, 1]);
                let (kh, kw) = (ks.first().copied().unwrap_or(1) as u64, ks.last().copied().unwrap_or(1) as u64);
                let (sh, sw) = match node.attr_ints("strides") {
                    Some([a, b, ..]) => (*a as u64, *b as u64),
                    _ => (kh, kw),
                };
                let (ph, pw) = match node.attr_ints("pads") {
                    Some([a, b, ..]) => (*a as u64, *b as u64),
                    _ => (0, 0),
                };
                let oh = out_spatial(x[2], kh, sh, ph, 1)?;
                let ow = out_spatial(x[3], kw, sw, pw, 1)?;
                if let Some(out) = node.outputs.first() {
                    shapes.act.insert(out.clone(), vec![x[0], x[1], oh, ow]);
                }
            }
        }
        "GlobalAveragePool" => {
            let x = shapes.need(node.inputs.first().map_or("", |s| s), &label)?.clone();
            if x.len() == 4 {
                if let Some(out) = node.outputs.first() {
                    shapes.act.insert(out.clone(), vec![x[0], x[1], 1, 1]);
                }
            }
        }
        "Flatten" => {
            let x = shapes.need(node.inputs.first().map_or("", |s| s), &label)?.clone();
            if let Some(out) = node.outputs.first() {
                shapes.act.insert(out.clone(), vec![1, prod(&x)?]);
            }
        }
        "Reshape" => {
            let x = shapes.need(node.inputs.first().map_or("", |s| s), &label)?.clone();
            let target = node
                .inputs
                .get(1)
                .and_then(|n| inits.get(n))
                .map(|t| t.i64s.clone())
                .unwrap_or_default();
            if !target.is_empty() {
                let total = prod(&x)?;
                let mut dims: Vec<u64> = Vec::new();
                let mut infer = None;
                for (i, &d) in target.iter().enumerate() {
                    match d {
                        -1 => {
                            infer = Some(i);
                            dims.push(1);
                        }
                        0 => dims.push(x.get(i).copied().unwrap_or(1)),
                        d if d > 0 => dims.push(d as u64),
                        _ => return Err(err(format!("{label}: bad reshape target"))),
                    }
                }
                if let Some(i) = infer {
                    let rest = prod(&dims)?;
                    dims[i] = total / rest.max(1);
                }
                if let Some(out) = node.outputs.first() {
                    shapes.act.insert(out.clone(), dims);
                }
            }
        }
        "Transpose" => {
            let x = shapes.need(node.inputs.first().map_or("", |s| s), &label)?.clone();
            let dims: Vec<u64> = match node.attr_ints("perm") {
                Some(perm) if perm.len() == x.len() => perm
                    .iter()
                    .map(|&p| x.get(p as usize).copied().unwrap_or(1))
                    .collect(),
                _ => x.iter().rev().copied().collect(),
            };
            if let Some(out) = node.outputs.first() {
                shapes.act.insert(out.clone(), dims);
            }
        }
        // shape-preserving ops: propagate the first input's shape
        "Relu" | "LeakyRelu" | "Sigmoid" | "Tanh" | "Softmax" | "Erf" | "Gelu" | "Clip"
        | "BatchNormalization" | "LayerNormalization" | "InstanceNormalization" | "Dropout"
        | "Identity" | "Add" | "Sub" | "Mul" | "Div" | "Pow" | "Sqrt" | "Cast" | "Pad" => {
            if let (Some(inp), Some(out)) = (node.inputs.first(), node.outputs.first()) {
                if let Some(s) = shapes.act.get(inp).cloned() {
                    shapes.act.insert(out.clone(), s);
                }
            }
        }
        // unknown op: its outputs become shape-unknown (only an error if
        // a downstream matmul needs them)
        _ => {
            for out in &node.outputs {
                shapes.act.remove(out);
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truncated_bytes_are_typed_errors_not_panics() {
        for bytes in [
            &[0x3a][..],             // key for field 7 LEN, then nothing
            &[0x3a, 0x05, 0x0a][..], // declared length exceeds buffer
            &[0xff; 16][..],         // overlong varint garbage
            &[][..],                 // empty model: no graph
        ] {
            let e = workload_from_onnx(bytes, "t").unwrap_err();
            assert!(matches!(e, IngestError::Onnx(_)), "{bytes:?} -> {e}");
        }
    }

    #[test]
    fn spatial_arithmetic_is_checked() {
        assert_eq!(out_spatial(224, 7, 2, 3, 1).unwrap(), 112);
        assert_eq!(out_spatial(7, 7, 1, 0, 1).unwrap(), 1);
        // kernel larger than padded input: error, not underflow panic
        assert!(out_spatial(3, 7, 1, 0, 1).is_err());
    }

    #[test]
    fn varint_roundtrip_and_bounds() {
        let mut r = Reader::new(&[0x96, 0x01]);
        assert_eq!(r.varint().unwrap(), 150);
        let mut r = Reader::new(&[0x80]);
        assert!(r.varint().is_err());
    }
}
