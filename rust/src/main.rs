//! `repro` — CLI for the joint hardware-workload co-optimization framework.
//!
//! ```text
//! repro exp <id|all> [--seed N] [--quick] [--native|--pjrt] [--out DIR]
//! repro search [--mem rram|sram] [--obj edap|edp|energy|latency|area|cost|acc]
//!              [--agg max|all|mean] [--workloads a,b,c] [--seed N]
//! repro eval --design R,C,M,T,G,B,Vstep,TC,GLB,TECH [--mem rram|sram]
//! repro workloads            # list workload statistics
//! repro space                # list search-space variants and sizes
//! repro artifacts            # verify AOT artifacts load and agree with native
//! ```

use anyhow::{bail, Context, Result};
use imcopt::coordinator::ExpContext;
use imcopt::experiments;
use imcopt::model::{MemoryTech, NativeEvaluator};
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::search::Optimizer;
use imcopt::space::SearchSpace;
use imcopt::util::cli::Args;
use imcopt::util::table::Table;
use imcopt::workloads::{WorkloadSet, ALL_NAMES};

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "exp" => cmd_exp(args),
        "search" => cmd_search(args),
        "eval" => cmd_eval(args),
        "workloads" => cmd_workloads(),
        "space" => cmd_space(),
        "artifacts" => cmd_artifacts(),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `repro help`)"),
    }
}

fn print_help() {
    println!(
        "repro — joint hardware-workload co-optimization for IMC accelerators\n\
         \n\
         commands:\n\
         \x20 exp <id|all>   regenerate a paper table/figure ({ids})\n\
         \x20 search         run one joint co-optimization\n\
         \x20 eval           evaluate a single design\n\
         \x20 workloads      list workload statistics\n\
         \x20 space          list search-space variants\n\
         \x20 artifacts      verify AOT artifacts vs the native evaluator\n\
         \n\
         common options: --seed N --quick --native --pjrt --out DIR\n\
         \x20 --threads N    worker threads for population evaluation\n\
         \x20                (default: IMCOPT_THREADS env var, else all cores;\n\
         \x20                scores are identical for any thread count)",
        ids = experiments::ALL_IDS.join(", ")
    );
}

fn parse_mem(args: &Args) -> Result<MemoryTech> {
    match args.opt_str("mem", "rram") {
        "rram" => Ok(MemoryTech::Rram),
        "sram" => Ok(MemoryTech::Sram),
        other => bail!("unknown --mem '{other}' (rram|sram)"),
    }
}

fn parse_objective(args: &Args) -> Result<Objective> {
    let kind = match args.opt_str("obj", "edap") {
        "edap" => ObjectiveKind::Edap,
        "edp" => ObjectiveKind::Edp,
        "energy" => ObjectiveKind::Energy,
        "latency" => ObjectiveKind::Latency,
        "area" => ObjectiveKind::Area,
        "cost" => ObjectiveKind::EdapCost,
        "acc" => ObjectiveKind::EdapAccuracy,
        other => bail!("unknown --obj '{other}'"),
    };
    let agg = match args.opt_str("agg", "max") {
        "max" => Aggregation::Max,
        "all" => Aggregation::All,
        "mean" => Aggregation::Mean,
        other => bail!("unknown --agg '{other}'"),
    };
    Ok(Objective::new(kind, agg))
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let ctx = ExpContext::from_args(args);
    if id == "all" {
        for id in experiments::ALL_IDS {
            println!("\n================ {id} ================");
            experiments::run(id, &ctx)?;
        }
        Ok(())
    } else {
        experiments::run(id, &ctx).map(|_| ())
    }
}

fn cmd_search(args: &Args) -> Result<()> {
    let ctx = ExpContext::from_args(args);
    let mem = parse_mem(args)?;
    let objective = parse_objective(args)?;
    let set = match args.opt("workloads") {
        Some(csv) => {
            let names: Vec<&str> = csv.split(',').collect();
            WorkloadSet::by_names(&names)?
        }
        None => WorkloadSet::cnn4(),
    };
    let space = match (mem, args.flag("tech")) {
        (MemoryTech::Rram, _) => SearchSpace::rram(),
        (MemoryTech::Sram, false) => SearchSpace::sram(),
        (MemoryTech::Sram, true) => SearchSpace::sram_tech(),
    };
    println!(
        "joint search: {} on {} ({} workloads: {:?}, space {} = {:.2e} points, backend {})",
        objective.name(),
        mem.name(),
        set.len(),
        set.names(),
        space.variant,
        space.size() as f64,
        if ctx.engine().is_some() { "pjrt" } else { "native" },
    );
    let problem = ctx.problem(&space, &set, mem, objective);
    let cfg = imcopt::experiments::common::four_phase(&ctx);
    let t0 = std::time::Instant::now();
    let r = imcopt::search::GeneticAlgorithm::new(cfg)
        .run(&problem, &mut imcopt::util::rng::Rng::seed_from(ctx.seed));
    println!(
        "best score {:.6} after {} evals in {} ({} distinct designs cached)",
        r.best_score,
        r.evals,
        imcopt::util::fmt_duration(t0.elapsed()),
        problem.cache_len(),
    );
    println!("best design: {}", space.describe(&r.best));
    let ev = problem.evaluate_design(&r.best);
    let mut t = Table::new(
        "per-workload metrics of the best design",
        &["workload", "energy mJ", "latency ms", "EDAP"],
    );
    for (w, m) in set.workloads.iter().zip(&ev.metrics) {
        t.row(vec![
            w.name.into(),
            format!("{:.4}", m.energy * 1e3),
            format!("{:.4}", m.latency * 1e3),
            format!("{:.4}", m.edap()),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let mem = parse_mem(args)?;
    let spec = args
        .opt("design")
        .context("--design R,C,M,T,G,B,V,TC,GLB,TECH required")?;
    let vals: Vec<f64> = spec
        .split(',')
        .map(|x| x.parse::<f64>().map_err(|e| anyhow::anyhow!("{e}: '{x}'")))
        .collect::<Result<_>>()?;
    anyhow::ensure!(vals.len() == 10, "--design needs 10 comma-separated values");
    let raw: [f64; 10] = vals.try_into().unwrap();
    let ev = NativeEvaluator::new(mem);
    let mut t = Table::new(
        &format!("native evaluation on {} (raw design {spec})", mem.name()),
        &["workload", "energy mJ", "latency ms", "area mm2", "feasible", "EDAP"],
    );
    for name in ALL_NAMES {
        let w = imcopt::workloads::by_name(name)?;
        let m = ev.evaluate(&raw, &w);
        t.row(vec![
            name.into(),
            format!("{:.4}", m.energy * 1e3),
            format!("{:.4}", m.latency * 1e3),
            format!("{:.2}", m.area),
            m.feasible.to_string(),
            format!("{:.4}", m.edap()),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn cmd_workloads() -> Result<()> {
    let mut t = Table::new(
        "workload models (matmul view; 8-bit weights/activations)",
        &["name", "mapped layers", "dynamic", "weights", "largest layer", "MACs"],
    );
    for name in ALL_NAMES {
        let w = imcopt::workloads::by_name(name)?;
        let dynamic = w.layers.iter().filter(|l| l.dynamic()).count();
        t.row(vec![
            name.into(),
            w.mapped_layers().to_string(),
            dynamic.to_string(),
            format!("{:.3e}", w.total_weights() as f64),
            format!("{:.3e}", w.max_layer_weights() as f64),
            format!("{:.3e}", w.total_macs() as f64),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn cmd_space() -> Result<()> {
    let mut t = Table::new(
        "search-space variants",
        &["variant", "size", "free params"],
    );
    for space in [
        SearchSpace::rram(),
        SearchSpace::sram(),
        SearchSpace::sram_tech(),
        SearchSpace::rram_reduced(),
    ] {
        t.row(vec![
            space.variant.into(),
            format!("{:.3e}", space.size() as f64),
            space.free_params().len().to_string(),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let engine = imcopt::runtime::Engine::load_default()?;
    println!(
        "artifacts loaded: fitness batches {:?}, accproxy {}",
        engine.fitness_batch_sizes(),
        engine.has_accproxy()
    );
    // quick agreement check against the native evaluator
    let space = SearchSpace::rram();
    let mut rng = imcopt::util::rng::Rng::seed_from(7);
    let raws: Vec<[f64; 10]> = (0..8)
        .map(|_| space.decode(&space.random(&mut rng)))
        .collect();
    let w = imcopt::workloads::resnet18();
    let native = NativeEvaluator::new(MemoryTech::Rram);
    let pjrt = engine.fitness(&raws, &w, MemoryTech::Rram)?;
    let mut worst: f64 = 0.0;
    for (raw, pm) in raws.iter().zip(&pjrt) {
        let nm = native.evaluate(raw, &w);
        for (a, b) in [
            (nm.energy, pm.energy),
            (nm.latency, pm.latency),
            (nm.area, pm.area),
        ] {
            worst = worst.max(((a - b) / a).abs());
        }
        anyhow::ensure!(
            nm.feasible == pm.feasible,
            "feasibility mismatch on {raw:?}"
        );
    }
    println!("native↔pjrt agreement: worst relative deviation {worst:.2e} (8 designs, resnet18)");
    anyhow::ensure!(worst < 5e-3, "deviation exceeds 0.5%");
    println!("artifacts OK");
    Ok(())
}
