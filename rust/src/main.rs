//! `imcopt` — CLI for the joint hardware-workload co-optimization
//! framework.
//!
//! ```text
//! imcopt run [ids...|--all] [--seed N] [--quick] [--out-dir DIR]
//!            [--resume] [--stable] [--topk K] [--hold-k K]
//!            [--portfolio IDS] [--moo-mode M] [--pareto-cap N]
//!            [--spec S] [--screen-frac F] [--native|--pjrt] [--workers N]
//! imcopt list [--markdown|--json]   # the experiment catalog
//! imcopt trace DIR           # analyze DIR/telemetry/ (hit rates, stage
//!                            # timings, convergence, worker utilization)
//! imcopt validate [--out-dir DIR [--require-all]] [--bench FILE] [--schema FILE]
//!                 [--trend FILE --baseline FILE [--tolerance PCT]]
//! imcopt search [--mem rram|sram] [--obj edap|edp|energy|latency|area|cost|acc]
//!               [--agg max|all|mean] [--workloads a,b,c] [--seed N]
//! imcopt eval --design R,C,M,T,G,B,Vstep,TC,GLB,TECH [--mem rram|sram]
//! imcopt workloads [--spec S] # list workload statistics (canonical nine,
//!                             # or an ingested/synthetic --spec family)
//! imcopt space               # list search-space variants and sizes
//! imcopt artifacts           # verify AOT artifacts load and agree with native
//! ```
//!
//! `run` drives the experiment registry with per-experiment checkpoints
//! under `--out-dir`; a run killed mid-flight resumes with `--resume`
//! without re-evaluating completed cells (`exp` is a legacy alias).
//! `--workers N` shards the sweep's cells across N supervised worker
//! processes with lease stealing, crash restarts and quarantine (see
//! `docs/orchestration.md`); experiments that keep failing exit the
//! process with code 3 instead of aborting the sweep.

use anyhow::{bail, Context, Result};
use imcopt::coordinator::ExpContext;
use imcopt::experiments;
use imcopt::model::{MemoryTech, NativeEvaluator};
use imcopt::objective::{Aggregation, Objective, ObjectiveKind};
use imcopt::scenarios::ScenarioSpec;
use imcopt::search::Optimizer;
use imcopt::space::SearchSpace;
use imcopt::util::cli::Args;
use imcopt::util::json;
use imcopt::util::schema;
use imcopt::util::table::Table;
use imcopt::workloads::{WorkloadSet, ALL_NAMES};
use std::path::Path;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "run" | "exp" => cmd_run(args),
        "list" => cmd_list(args),
        "validate" => cmd_validate(args),
        "trace" => cmd_trace(args),
        "search" => cmd_search(args),
        "eval" => cmd_eval(args),
        "workloads" => cmd_workloads(args),
        "space" => cmd_space(),
        "artifacts" => cmd_artifacts(),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `imcopt help`)"),
    }
}

fn print_help() {
    println!(
        "imcopt — joint hardware-workload co-optimization for IMC accelerators\n\
         \n\
         commands:\n\
         \x20 run [ids|--all] run registered experiments with checkpointing\n\
         \x20                 ({ids})\n\
         \x20 list           show the experiment registry (--markdown regenerates\n\
         \x20                docs/experiments.md, --json the validated listing)\n\
         \x20 trace DIR      analyze <DIR>/telemetry/ from a previous run: cache\n\
         \x20                hit rates, per-stage wall-clock, per-cell convergence\n\
         \x20                and worker utilization (see docs/telemetry.md;\n\
         \x20                disable collection with IMCOPT_TELEMETRY=0)\n\
         \x20 validate       check experiment/bench JSON artifacts against schemas;\n\
         \x20                --trend FILE --baseline FILE [--tolerance PCT] gates\n\
         \x20                bench throughput/speedup fields against a committed\n\
         \x20                baseline (the ci.sh regression gate; default 15%)\n\
         \x20 search         run one joint co-optimization\n\
         \x20 eval           evaluate a single design\n\
         \x20 workloads      list workload statistics (--spec S: an ingested or\n\
         \x20                synthetic family instead of the canonical nine)\n\
         \x20 space          list search-space variants\n\
         \x20 artifacts      verify AOT artifacts vs the native evaluator\n\
         \n\
         common options: --seed N --quick --native --pjrt --out-dir DIR\n\
         \x20 --resume       resume a killed run from its checkpoint journals\n\
         \x20 --stable       deterministic reports (wall-clock columns -> '-')\n\
         \x20 --topk K       best designs reported per genmatrix/portfolio cell\n\
         \x20 --hold-k K     genmatrix_k sweeps hold-k-out for k in 1..=K (default 2)\n\
         \x20 --portfolio P  restrict `transfer` to portfolio ids (comma-separated)\n\
         \x20 --moo-mode M   pareto objective mode: metric|workload (default: both)\n\
         \x20 --pareto-cap N pareto front-archive capacity (default 128)\n\
         \x20 --spec S       user scenario family for genmatrix_k / transfer /\n\
         \x20                population / pareto: w1+w2+...:rram|sram[:agg] with\n\
         \x20                canonical names or .json/.onnx file paths as workload\n\
         \x20                tokens, or a seeded synthetic population\n\
         \x20                synth:cnn|transformer|mixed:<n>:<seed>[:mem][:agg]\n\
         \x20                (default: paper sets; population: synth:mixed:200:seed)\n\
         \x20 --robust M     robust accuracy-aware objectives: aggregate each\n\
         \x20                design's score over a seeded device-variation\n\
         \x20                ensemble (worst|cvar<q>|mean, e.g. cvar0.25; off by\n\
         \x20                default — see docs/robustness.md)\n\
         \x20 --acc-floor A  minimum nominal accuracy (0,1) a design must reach\n\
         \x20                on every workload to enter a Pareto front\n\
         \x20                (constraint domination; pareto/robustness runs)\n\
         \x20 --screen-frac F surrogate pre-screening: fraction of each GA/NSGA-II\n\
         \x20                generation's offspring pool that reaches the exact\n\
         \x20                evaluator (clamped to [0.05, 1.0]; default 1.0 = exact\n\
         \x20                loop, bit-identical to builds without screening; see\n\
         \x20                docs/search.md)\n\
         \x20 --threads N    worker threads for population evaluation\n\
         \x20                (default: IMCOPT_THREADS env var, else all cores;\n\
         \x20                scores are identical for any thread count)\n\
         \x20 --workers N    shard `run` across N worker processes sharing one\n\
         \x20                --out-dir: file-locked cell claims with heartbeat\n\
         \x20                leases, stale-lease stealing, crash restarts and\n\
         \x20                quarantine (reports are byte-identical at any N;\n\
         \x20                see docs/orchestration.md)\n\
         \n\
         orchestrator environment (all optional; docs/orchestration.md):\n\
         \x20 IMCOPT_FAULT=<plan | seed:rate>  deterministic fault injection,\n\
         \x20                e.g. 'w1:exit@cell=2' or '7:0.01' (crash-matrix tests)\n\
         \x20 IMCOPT_LEASE_MS=30000 lease staleness timeout before stealing\n\
         \x20 IMCOPT_CELL_RETRIES=2 extra attempts per failing experiment\n\
         \x20 IMCOPT_RETRY_MS=100   retry backoff base (doubles, capped 5s)\n\
         \x20 IMCOPT_MAX_RESTARTS=2 restarts per crashed worker before abandoning",
        ids = experiments::ALL_IDS.join(", ")
    );
}

fn cmd_run(args: &Args) -> Result<()> {
    // the tiny parser cannot know `--resume fig3` means "flag, then
    // positional" — it would swallow the id as the flag's value and this
    // command would silently sweep every registered experiment. Reject
    // boolean flags carrying unexpected values instead.
    for flag in ["all", "quick", "stable", "resume", "native", "pjrt"] {
        if let Some(v) = args.opt(flag) {
            anyhow::ensure!(
                v == "true" || v == "false",
                "--{flag} is a boolean flag but got value '{v}'; put experiment \
                 ids before the flags (e.g. `imcopt run {v} --{flag}`)"
            );
        }
    }
    let ctx = ExpContext::from_args(args);
    // an explicitly requested backend that cannot load is a CLI error,
    // not a mid-sweep panic
    ctx.require_backend()?;
    // likewise a malformed --robust mode (worst|cvar<q>|mean)
    ctx.robust_config()?;
    let positional_all =
        args.positionals.is_empty() || args.positionals.iter().any(|s| s == "all");
    let ids: Vec<&str> = if args.flag("all") || positional_all {
        experiments::ALL_IDS.to_vec()
    } else {
        args.positionals.iter().map(|s| s.as_str()).collect()
    };
    if ctx.worker_id.is_some() {
        // orchestrator worker process: coordinate through cell claims,
        // write the status file, exit 0 or EXIT_QUARANTINED
        return imcopt::orchestrator::worker_main(&ids, &ctx);
    }
    let summary = if ctx.workers > 1 {
        imcopt::orchestrator::supervisor::supervise(&ids, &ctx)?
    } else {
        experiments::run_selected(&ids, &ctx)?
    };
    println!("\n{}", summary.to_line());
    if !summary.quarantined.is_empty() {
        for q in &summary.quarantined {
            eprintln!("quarantined: {} — {}", q.experiment, q.reason);
        }
        // graceful degradation is still a degradation: every healthy
        // experiment completed, but the exit code must say "not clean"
        std::process::exit(imcopt::orchestrator::EXIT_QUARANTINED);
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    // self-describing registry: --markdown regenerates the checked-in
    // catalog (docs/experiments.md, drift-tested), --json the
    // machine-readable listing (schemas/registry.schema.json)
    if args.flag("markdown") {
        print!("{}", experiments::catalog_markdown());
        return Ok(());
    }
    if args.flag("json") {
        println!("{}", experiments::catalog_json());
        return Ok(());
    }
    let mut t = Table::new(
        "experiment registry (imcopt run <id>)",
        &["id", "cost", "resume", "description"],
    );
    for exp in experiments::REGISTRY {
        t.row(vec![
            exp.id().into(),
            exp.cost().name().into(),
            exp.granularity().name().into(),
            exp.description().into(),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

/// `imcopt trace <out-dir>` — the telemetry analyzer: renders cache
/// hit-rate, per-stage wall-clock, per-cell convergence and worker
/// utilization tables from the out-of-band `<out-dir>/telemetry/` files
/// a run leaves behind (counters snapshots + append-only trace JSONL).
/// Every counters snapshot and every trace line is schema-validated on
/// the way in, so the ci.sh telemetry leg doubles as a format gate.
fn cmd_trace(args: &Args) -> Result<()> {
    let dir_arg = args
        .positionals
        .first()
        .map(|s| s.as_str())
        .unwrap_or_else(|| args.opt_str("out-dir", "out"));
    let out_dir = Path::new(dir_arg);
    let tdir = out_dir.join("telemetry");
    anyhow::ensure!(
        tdir.is_dir(),
        "no telemetry directory under {} — run `imcopt run` against this \
         out-dir first (telemetry is on by default; IMCOPT_TELEMETRY=0 \
         disables it)",
        out_dir.display()
    );
    let counters_schema = Path::new(args.opt_str(
        "counters-schema",
        "schemas/telemetry_counters.schema.json",
    ));
    let trace_schema_path =
        Path::new(args.opt_str("trace-schema", "schemas/telemetry_trace.schema.json"));
    let trace_schema_doc = {
        let text = std::fs::read_to_string(trace_schema_path)
            .with_context(|| format!("reading {}", trace_schema_path.display()))?;
        json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", trace_schema_path.display()))?
    };

    // ---- counters snapshots (in-process + per-worker) ---------------------
    let mut snapshot_paths: Vec<std::path::PathBuf> = std::fs::read_dir(&tdir)
        .with_context(|| format!("reading {}", tdir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("counters") && n.ends_with(".json"))
        })
        .collect();
    snapshot_paths.sort();
    let mut counter_sums: std::collections::BTreeMap<String, f64> = Default::default();
    let mut span_sums: std::collections::BTreeMap<String, (f64, Option<f64>)> =
        Default::default();
    let mut notice_counts: std::collections::BTreeMap<String, f64> = Default::default();
    // per-worker (or in-process, worker "-") utilization rows
    let mut worker_rows: Vec<(String, std::collections::BTreeMap<String, f64>)> =
        Vec::new();
    for path in &snapshot_paths {
        let doc = validate_file(path, counters_schema)?;
        let mut row: std::collections::BTreeMap<String, f64> = Default::default();
        if let Some(json::Json::Obj(counters)) = doc.get("counters") {
            for (k, v) in counters {
                if let Some(x) = v.as_f64() {
                    *counter_sums.entry(k.clone()).or_insert(0.0) += x;
                    row.insert(k.clone(), x);
                }
            }
        }
        if let Some(json::Json::Obj(spans)) = doc.get("spans") {
            for (name, span) in spans {
                let count = span.get("count").and_then(|c| c.as_f64()).unwrap_or(0.0);
                let ms = span.get("total_ms").and_then(|m| m.as_f64());
                let entry = span_sums.entry(name.clone()).or_insert((0.0, None));
                entry.0 += count;
                if let Some(ms) = ms {
                    entry.1 = Some(entry.1.unwrap_or(0.0) + ms);
                }
            }
        }
        if let Some(json::Json::Obj(notices)) = doc.get("notices") {
            for (k, v) in notices {
                if let Some(x) = v.as_f64() {
                    *notice_counts.entry(k.clone()).or_insert(0.0) += x;
                }
            }
        }
        let worker = match doc.get("worker").and_then(|w| w.as_usize()) {
            Some(w) => w.to_string(),
            None => "-".to_string(),
        };
        worker_rows.push((worker, row));
    }

    let pct = |num: f64, den: f64| -> String {
        if den > 0.0 {
            format!("{:.1}%", 100.0 * num / den)
        } else {
            "-".into()
        }
    };
    let n0 = |k: &str| counter_sums.get(k).copied().unwrap_or(0.0);

    if !snapshot_paths.is_empty() {
        let mut t = Table::new(
            &format!(
                "cache & screen hit rates ({} snapshot{})",
                snapshot_paths.len(),
                if snapshot_paths.len() == 1 { "" } else { "s" }
            ),
            &["path", "hits/kept", "misses/dropped", "lookups", "rate"],
        );
        let (eh, em) = (n0("eval_memo_hits"), n0("eval_memo_misses"));
        t.row(vec![
            "eval memo".into(),
            format!("{eh:.0}"),
            format!("{em:.0}"),
            format!("{:.0}", eh + em),
            pct(eh, eh + em),
        ]);
        let (ac, am) = (n0("acc_memo_calls"), n0("acc_memo_misses"));
        t.row(vec![
            "accuracy memo".into(),
            format!("{:.0}", ac - am),
            format!("{am:.0}"),
            format!("{ac:.0}"),
            pct(ac - am, ac),
        ]);
        let (sa, so) = (n0("screen_accepted"), n0("screened_out"));
        t.row(vec![
            "surrogate screen".into(),
            format!("{sa:.0}"),
            format!("{so:.0}"),
            format!("{:.0}", sa + so),
            pct(sa, sa + so),
        ]);
        print!("{}", t.to_text());

        let mut c = Table::new(
            "work & durability counters",
            &["counter", "count"],
        );
        for key in [
            "exact_evals",
            "offgrid_fallbacks",
            "journal_appends",
            "journal_syncs",
            "lease_claims",
            "lease_steals",
            "lease_heartbeats",
            "cells_computed",
            "cells_reused",
            "cell_retries",
            "cells_quarantined",
            "artifact_writes",
        ] {
            c.row(vec![key.into(), format!("{:.0}", n0(key))]);
        }
        print!("{}", c.to_text());

        let mut st = Table::new(
            "per-stage wall clock (nesting by indent; '-' = --stable run)",
            &["stage", "calls", "total ms", "mean ms"],
        );
        for (name, depth) in imcopt::telemetry::STAGES {
            let (count, ms) = span_sums.get(name).copied().unwrap_or((0.0, None));
            let (total, mean) = match ms {
                Some(ms) if count > 0.0 => {
                    (format!("{ms:.1}"), format!("{:.3}", ms / count))
                }
                Some(ms) => (format!("{ms:.1}"), "-".into()),
                None => ("-".into(), "-".into()),
            };
            st.row(vec![
                format!("{}{name}", "  ".repeat(depth)),
                format!("{count:.0}"),
                total,
                mean,
            ]);
        }
        print!("{}", st.to_text());
    }

    // ---- trace JSONL: per-cell convergence --------------------------------
    let mut trace_paths: Vec<std::path::PathBuf> = std::fs::read_dir(&tdir)
        .with_context(|| format!("reading {}", tdir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("trace") && n.ends_with(".jsonl"))
        })
        .collect();
    trace_paths.sort();
    // (experiment, cell, seed) -> per-event-kind accumulators
    #[derive(Default)]
    struct CellTrace {
        gens: usize,
        first_best: Option<f64>,
        last_best: Option<f64>,
        last_median: Option<f64>,
        last_accept: Option<f64>,
        last_violation: Option<f64>,
        fronts: usize,
        last_front_size: Option<f64>,
        last_hv: Option<f64>,
        last_evals: Option<f64>,
    }
    let mut cells: std::collections::BTreeMap<(String, String, u64), CellTrace> =
        Default::default();
    let mut lines_total = 0usize;
    let mut torn = 0usize;
    for path in &trace_paths {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        for line in text.lines() {
            let Ok(doc) = json::parse(line) else {
                // at most the torn tail of a killed run; anything parseable
                // must still conform to the schema below
                torn += 1;
                continue;
            };
            let errs = schema::validate(&trace_schema_doc, &doc);
            if !errs.is_empty() {
                bail!(
                    "{}: trace line violates {}:\n  {}",
                    path.display(),
                    trace_schema_path.display(),
                    errs.join("\n  ")
                );
            }
            lines_total += 1;
            let key = (
                doc.get("experiment").and_then(|e| e.as_str()).unwrap_or("").to_string(),
                doc.get("cell").and_then(|c| c.as_str()).unwrap_or("").to_string(),
                doc.get("seed").and_then(|s| s.as_usize()).unwrap_or(0) as u64,
            );
            let ct = cells.entry(key).or_default();
            ct.last_evals = doc.get("evals").and_then(|v| v.as_f64()).or(ct.last_evals);
            match doc.get("event").and_then(|e| e.as_str()) {
                Some("generation") => {
                    ct.gens += 1;
                    let best = doc.get("best").and_then(|b| b.as_f64_lenient());
                    if ct.first_best.is_none() {
                        ct.first_best = best;
                    }
                    ct.last_best = best.or(ct.last_best);
                    ct.last_median = doc
                        .get("median")
                        .and_then(|m| m.as_f64_lenient())
                        .or(ct.last_median);
                    ct.last_accept = doc
                        .get("screen_accept_rate")
                        .and_then(|a| a.as_f64())
                        .or(ct.last_accept);
                    ct.last_violation = doc
                        .get("violation_rate")
                        .and_then(|v| v.as_f64())
                        .or(ct.last_violation);
                }
                Some("front") => {
                    ct.fronts += 1;
                    ct.last_front_size =
                        doc.get("front_size").and_then(|f| f.as_f64()).or(ct.last_front_size);
                    ct.last_hv = doc
                        .get("hypervolume")
                        .and_then(|h| h.as_f64_lenient())
                        .or(ct.last_hv);
                }
                _ => {}
            }
        }
    }
    let s = |x: Option<f64>| x.map(imcopt::experiments::common::s).unwrap_or_else(|| "-".into());
    if cells.values().any(|c| c.gens > 0) {
        let mut t = Table::new(
            &format!("convergence per search cell ({lines_total} trace events)"),
            &["experiment", "cell", "seed", "gens", "evals", "best g0", "best end",
              "median end", "viol", "screen"],
        );
        for ((exp, cell, seed), ct) in &cells {
            if ct.gens == 0 {
                continue;
            }
            t.row(vec![
                exp.clone(),
                cell.clone(),
                seed.to_string(),
                ct.gens.to_string(),
                s(ct.last_evals),
                s(ct.first_best),
                s(ct.last_best),
                s(ct.last_median),
                s(ct.last_violation),
                ct.last_accept
                    .map(|a| pct(a, 1.0))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        print!("{}", t.to_text());
    }
    if cells.values().any(|c| c.fronts > 0) {
        let mut t = Table::new(
            "Pareto front evolution per cell",
            &["experiment", "cell", "seed", "gens", "evals", "front size", "hypervolume"],
        );
        for ((exp, cell, seed), ct) in &cells {
            if ct.fronts == 0 {
                continue;
            }
            t.row(vec![
                exp.clone(),
                cell.clone(),
                seed.to_string(),
                ct.fronts.to_string(),
                s(ct.last_evals),
                s(ct.last_front_size),
                s(ct.last_hv),
            ]);
        }
        print!("{}", t.to_text());
    }

    // ---- worker utilization ----------------------------------------------
    if worker_rows.iter().any(|(w, _)| w != "-") {
        // heartbeat ages come from the supervisor's aggregation, when it ran
        let status_doc = std::fs::read_to_string(out_dir.join("orchestrator_status.json"))
            .ok()
            .and_then(|text| json::parse(&text).ok());
        let age_of = |w: &str| -> String {
            status_doc
                .as_ref()
                .and_then(|d| d.get("worker_status"))
                .and_then(|ws| ws.as_arr())
                .and_then(|ws| {
                    ws.iter().find(|e| {
                        e.get("worker").and_then(|x| x.as_usize())
                            == w.parse::<usize>().ok()
                    })
                })
                .and_then(|e| e.get("heartbeat_age_ms"))
                .and_then(|a| a.as_f64())
                .map(|a| format!("{a:.0}"))
                .unwrap_or_else(|| "-".into())
        };
        let mut t = Table::new(
            "worker utilization (counters-w<i>.json + orchestrator status)",
            &["worker", "computed", "reused", "exact evals", "claims", "steals",
              "heartbeats", "hb age ms"],
        );
        for (w, row) in &worker_rows {
            let g = |k: &str| row.get(k).copied().unwrap_or(0.0);
            t.row(vec![
                w.clone(),
                format!("{:.0}", g("cells_computed")),
                format!("{:.0}", g("cells_reused")),
                format!("{:.0}", g("exact_evals")),
                format!("{:.0}", g("lease_claims")),
                format!("{:.0}", g("lease_steals")),
                format!("{:.0}", g("lease_heartbeats")),
                age_of(w),
            ]);
        }
        print!("{}", t.to_text());
    }

    if !notice_counts.is_empty() {
        let mut t = Table::new("degradation notices", &["notice", "count"]);
        for (k, v) in &notice_counts {
            t.row(vec![k.clone(), format!("{v:.0}")]);
        }
        print!("{}", t.to_text());
    }

    anyhow::ensure!(
        !snapshot_paths.is_empty() || lines_total > 0,
        "telemetry directory {} holds no counters snapshots or trace events",
        tdir.display()
    );
    if torn > 0 {
        eprintln!("[trace] skipped {torn} unparseable line(s) (torn tail of a killed run)");
    }
    println!(
        "trace ok: {} snapshot(s), {} trace event(s), {} search cell(s) under {}",
        snapshot_paths.len(),
        lines_total,
        cells.len(),
        tdir.display()
    );
    Ok(())
}

/// Validate a single JSON file against a schema file, returning the
/// parsed document for any further checks.
fn validate_file(doc_path: &Path, schema_path: &Path) -> Result<json::Json> {
    let doc_text = std::fs::read_to_string(doc_path)
        .with_context(|| format!("reading {}", doc_path.display()))?;
    let doc = json::parse(&doc_text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", doc_path.display()))?;
    let schema_text = std::fs::read_to_string(schema_path)
        .with_context(|| format!("reading {}", schema_path.display()))?;
    let schema_doc = json::parse(&schema_text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", schema_path.display()))?;
    let errs = schema::validate(&schema_doc, &doc);
    if !errs.is_empty() {
        bail!(
            "{} violates {}:\n  {}",
            doc_path.display(),
            schema_path.display(),
            errs.join("\n  ")
        );
    }
    Ok(doc)
}

/// The bench-trend gate (`validate --trend FILE --baseline FILE`):
/// compare a fresh bench report against a committed baseline and fail on
/// throughput/speedup regressions beyond the tolerance. Only rate-like
/// fields participate — names ending in `_per_sec` or containing
/// `speedup`; identity and config fields are the schema validator's
/// job. A trend field present in the baseline but missing from the
/// current report is an error (a silently dropped metric must not pass
/// the gate). Re-bless an intentional change by copying the fresh
/// report over the baseline (README.md, "Bench-trend gate").
fn trend_check(bench_path: &Path, baseline_path: &Path, tolerance_pct: f64) -> Result<()> {
    let load = |p: &Path| -> Result<json::Json> {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading {}", p.display()))?;
        json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", p.display()))
    };
    let current = load(bench_path)?;
    let baseline = load(baseline_path)?;
    let json::Json::Obj(base_fields) = &baseline else {
        bail!("{}: baseline must be a JSON object", baseline_path.display());
    };
    let floor_factor = 1.0 - tolerance_pct / 100.0;
    let mut t = Table::new(
        &format!(
            "bench trend: {} vs {} (tolerance {tolerance_pct:.0}%)",
            bench_path.display(),
            baseline_path.display()
        ),
        &["field", "baseline", "current", "floor", "status"],
    );
    let mut gated = 0usize;
    let mut regressions: Vec<String> = Vec::new();
    for (key, value) in base_fields {
        if !(key.ends_with("_per_sec") || key.contains("speedup")) {
            continue;
        }
        let Some(base) = value.as_f64_lenient() else {
            continue;
        };
        let cur = current
            .get(key)
            .and_then(|v| v.as_f64_lenient())
            .with_context(|| {
                format!(
                    "{}: trend field '{key}' from the baseline is missing",
                    bench_path.display()
                )
            })?;
        let floor = base * floor_factor;
        let ok = cur >= floor;
        gated += 1;
        if !ok {
            regressions.push(format!(
                "{key}: {cur:.3} < floor {floor:.3} (baseline {base:.3})"
            ));
        }
        t.row(vec![
            key.clone(),
            format!("{base:.3}"),
            format!("{cur:.3}"),
            format!("{floor:.3}"),
            String::from(if ok { "ok" } else { "REGRESSED" }),
        ]);
    }
    print!("{}", t.to_text());
    anyhow::ensure!(
        gated > 0,
        "{}: no trend fields (*_per_sec / *speedup*) to gate on",
        baseline_path.display()
    );
    if !regressions.is_empty() {
        bail!(
            "bench trend regression in {} ({} of {gated} fields beyond \
             {tolerance_pct:.0}%):\n  {}",
            bench_path.display(),
            regressions.len(),
            regressions.join("\n  ")
        );
    }
    println!(
        "ok: {} holds the {} baseline ({gated} fields within {tolerance_pct:.0}%)",
        bench_path.display(),
        baseline_path.display()
    );
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let mut checked = false;
    if let Some(bench) = args.opt("bench") {
        let schema = args.opt_str("schema", "schemas/bench_eval.schema.json");
        validate_file(Path::new(bench), Path::new(schema))?;
        println!("ok: {bench} conforms to {schema}");
        checked = true;
    }
    if let Some(bench) = args.opt("trend") {
        let baseline = args
            .opt("baseline")
            .context("--trend requires --baseline FILE (the committed floor)")?;
        trend_check(
            Path::new(bench),
            Path::new(baseline),
            args.opt_f64("tolerance", 15.0),
        )?;
        checked = true;
    }
    if let Some(dir) = args.opt("out-dir") {
        let dir = Path::new(dir);
        let schema = Path::new(args.opt_str(
            "report-schema",
            "schemas/experiment_report.schema.json",
        ));
        // by default a partial out-dir (from `imcopt run fig3 ...`) is
        // fine — absent artifacts are reported, present ones must
        // conform. `--require-all` (the ci.sh smoke) demands every
        // registered experiment.
        let require_all = args.flag("require-all");
        let mut t = Table::new("experiment artifacts", &["id", "artifact", "status"]);
        let mut present = 0usize;
        let mut genmatrix_present = false;
        let mut pareto_present = false;
        let mut robustness_present = false;
        let mut cell_dirs: Vec<(&str, &str)> = Vec::new();
        for exp in experiments::REGISTRY {
            let path = dir.join(format!("{}.json", exp.id()));
            if !path.exists() {
                anyhow::ensure!(
                    !require_all,
                    "{}: missing artifact for registered experiment '{}'",
                    path.display(),
                    exp.id()
                );
                t.row(vec![
                    exp.id().into(),
                    path.display().to_string(),
                    "absent".into(),
                ]);
                continue;
            }
            let doc = validate_file(&path, schema)?;
            // the artifact must belong to the experiment it is named after
            anyhow::ensure!(
                doc.get("id").and_then(|v| v.as_str()) == Some(exp.id()),
                "{}: id mismatch",
                path.display()
            );
            present += 1;
            genmatrix_present |= exp.id() == "genmatrix";
            match exp.id() {
                "genmatrix_k" => cell_dirs.push(("genmatrix_k", "genmatrix_k_cells")),
                "transfer" => cell_dirs.push(("transfer", "transfer_cells")),
                "population" => cell_dirs.push(("population", "population_cells")),
                "pareto" => pareto_present = true,
                "robustness" => robustness_present = true,
                _ => {}
            }
            t.row(vec![
                exp.id().into(),
                path.display().to_string(),
                "ok".into(),
            ]);
        }
        anyhow::ensure!(
            present > 0,
            "no experiment artifacts found under {}",
            dir.display()
        );
        // a genmatrix run additionally emits one standalone JSON cell per
        // held-out workload of each set
        if genmatrix_present {
            let mut cells = 0usize;
            for (set_name, set) in
                [("cnn4", WorkloadSet::cnn4()), ("all9", WorkloadSet::all9())]
            {
                for w in &set.workloads {
                    let path = dir
                        .join("genmatrix_cells")
                        .join(format!("{set_name}-{}.json", w.name));
                    let text = std::fs::read_to_string(&path).with_context(|| {
                        format!("missing genmatrix cell {}", path.display())
                    })?;
                    let doc = json::parse(&text)
                        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
                    for key in ["held_out", "gap", "joint", "separate_bound", "top"] {
                        anyhow::ensure!(
                            doc.get(key).is_some(),
                            "{}: missing '{key}'",
                            path.display()
                        );
                    }
                    cells += 1;
                }
            }
            t.row(vec![
                "genmatrix cells".into(),
                dir.join("genmatrix_cells").display().to_string(),
                format!("ok ({cells} cells)"),
            ]);
        }
        // portfolio experiments (genmatrix_k / transfer) emit one JSON
        // cell per portfolio, shape-pinned by the portfolio-cell schema
        if !cell_dirs.is_empty() {
            let cell_schema_path =
                Path::new(args.opt_str("cell-schema", "schemas/portfolio_cell.schema.json"));
            for (id, sub) in cell_dirs {
                let cells_dir = dir.join(sub);
                let mut cells = 0usize;
                let entries = std::fs::read_dir(&cells_dir)
                    .with_context(|| format!("missing cell dir {}", cells_dir.display()))?;
                let mut paths: Vec<_> = entries
                    .filter_map(|e| e.ok().map(|e| e.path()))
                    .filter(|p| p.extension().is_some_and(|x| x == "json"))
                    .collect();
                paths.sort();
                for path in paths {
                    let doc = validate_file(&path, cell_schema_path)?;
                    anyhow::ensure!(
                        doc.get("experiment").and_then(|v| v.as_str()) == Some(id),
                        "{}: experiment mismatch",
                        path.display()
                    );
                    cells += 1;
                }
                anyhow::ensure!(
                    cells > 0,
                    "no portfolio cells under {}",
                    cells_dir.display()
                );
                t.row(vec![
                    format!("{id} cells"),
                    cells_dir.display().to_string(),
                    format!("ok ({cells} cells)"),
                ]);
            }
        }
        // a pareto run emits one front artifact per (set, mode), pinned by
        // the pareto-front schema
        if pareto_present {
            let front_schema_path = Path::new(
                args.opt_str("pareto-schema", "schemas/pareto_front.schema.json"),
            );
            let fronts_dir = dir.join("pareto_fronts");
            let entries = std::fs::read_dir(&fronts_dir)
                .with_context(|| format!("missing front dir {}", fronts_dir.display()))?;
            let mut paths: Vec<_> = entries
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.extension().is_some_and(|x| x == "json"))
                .collect();
            paths.sort();
            let mut fronts = 0usize;
            for path in paths {
                let doc = validate_file(&path, front_schema_path)?;
                anyhow::ensure!(
                    doc.get("experiment").and_then(|v| v.as_str()) == Some("pareto"),
                    "{}: experiment mismatch",
                    path.display()
                );
                fronts += 1;
            }
            anyhow::ensure!(
                fronts > 0,
                "no pareto fronts under {}",
                fronts_dir.display()
            );
            t.row(vec![
                "pareto fronts".into(),
                fronts_dir.display().to_string(),
                format!("ok ({fronts} fronts)"),
            ]);
        }
        // a robustness run emits a nominal-vs-robust gap cell plus one
        // floor-cost curve per memory technology
        if robustness_present {
            let cell_schema_path = Path::new(args.opt_str(
                "robustness-schema",
                "schemas/robustness_cell.schema.json",
            ));
            let cells_dir = dir.join("robustness_cells");
            let entries = std::fs::read_dir(&cells_dir)
                .with_context(|| format!("missing cell dir {}", cells_dir.display()))?;
            let mut paths: Vec<_> = entries
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.extension().is_some_and(|x| x == "json"))
                .collect();
            paths.sort();
            let mut cells = 0usize;
            let mut kinds: Vec<String> = Vec::new();
            for path in paths {
                let doc = validate_file(&path, cell_schema_path)?;
                anyhow::ensure!(
                    doc.get("experiment").and_then(|v| v.as_str()) == Some("robustness"),
                    "{}: experiment mismatch",
                    path.display()
                );
                if let Some(k) = doc.get("kind").and_then(|v| v.as_str()) {
                    kinds.push(k.to_string());
                }
                cells += 1;
            }
            anyhow::ensure!(
                cells > 0,
                "no robustness cells under {}",
                cells_dir.display()
            );
            anyhow::ensure!(
                kinds.iter().any(|k| k == "gap") && kinds.iter().any(|k| k == "floor_curve"),
                "robustness cells must include a 'gap' and a 'floor_curve' kind, got {kinds:?}"
            );
            t.row(vec![
                "robustness cells".into(),
                cells_dir.display().to_string(),
                format!("ok ({cells} cells)"),
            ]);
        }
        print!("{}", t.to_text());
        checked = true;
    }
    if !checked {
        bail!(
            "nothing to validate: pass --out-dir DIR, --bench FILE and/or \
             --trend FILE --baseline FILE"
        );
    }
    Ok(())
}

fn parse_mem(args: &Args) -> Result<MemoryTech> {
    match args.opt_str("mem", "rram") {
        "rram" => Ok(MemoryTech::Rram),
        "sram" => Ok(MemoryTech::Sram),
        other => bail!("unknown --mem '{other}' (rram|sram)"),
    }
}

fn parse_objective(args: &Args) -> Result<Objective> {
    let kind = match args.opt_str("obj", "edap") {
        "edap" => ObjectiveKind::Edap,
        "edp" => ObjectiveKind::Edp,
        "energy" => ObjectiveKind::Energy,
        "latency" => ObjectiveKind::Latency,
        "area" => ObjectiveKind::Area,
        "cost" => ObjectiveKind::EdapCost,
        "acc" => ObjectiveKind::EdapAccuracy,
        other => bail!("unknown --obj '{other}'"),
    };
    let agg = match args.opt_str("agg", "max") {
        "max" => Aggregation::Max,
        "all" => Aggregation::All,
        "mean" => Aggregation::Mean,
        other => bail!("unknown --agg '{other}'"),
    };
    Ok(Objective::new(kind, agg))
}

fn cmd_search(args: &Args) -> Result<()> {
    let ctx = ExpContext::from_args(args);
    ctx.require_backend()?;
    let mem = parse_mem(args)?;
    let objective = parse_objective(args)?;
    let set = match args.opt("workloads") {
        Some(csv) => {
            let names: Vec<&str> = csv.split(',').collect();
            WorkloadSet::by_names(&names)?
        }
        None => WorkloadSet::cnn4(),
    };
    let space = match (mem, args.flag("tech")) {
        (MemoryTech::Rram, _) => SearchSpace::rram(),
        (MemoryTech::Sram, false) => SearchSpace::sram(),
        (MemoryTech::Sram, true) => SearchSpace::sram_tech(),
    };
    println!(
        "joint search: {} on {} ({} workloads: {:?}, space {} = {:.2e} points, backend {})",
        objective.name(),
        mem.name(),
        set.len(),
        set.names(),
        space.variant,
        space.size() as f64,
        if ctx.engine().is_some() { "pjrt" } else { "native" },
    );
    let problem = ctx.problem(&space, &set, mem, objective);
    let cfg = imcopt::experiments::common::four_phase(&ctx);
    let t0 = std::time::Instant::now();
    let r = imcopt::search::GeneticAlgorithm::new(cfg)
        .run(&problem, &mut imcopt::util::rng::Rng::seed_from(ctx.seed));
    println!(
        "best score {:.6} after {} evals in {} ({} distinct designs cached)",
        r.best_score,
        r.evals,
        imcopt::util::fmt_duration(t0.elapsed()),
        problem.cache_len(),
    );
    println!("best design: {}", space.describe(&r.best));
    let ev = problem.evaluate_design(&r.best);
    let mut t = Table::new(
        "per-workload metrics of the best design",
        &["workload", "energy mJ", "latency ms", "EDAP"],
    );
    for (w, m) in set.workloads.iter().zip(&ev.metrics) {
        t.row(vec![
            w.name.clone(),
            format!("{:.4}", m.energy * 1e3),
            format!("{:.4}", m.latency * 1e3),
            format!("{:.4}", m.edap()),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let mem = parse_mem(args)?;
    let spec = args
        .opt("design")
        .context("--design R,C,M,T,G,B,V,TC,GLB,TECH required")?;
    let vals: Vec<f64> = spec
        .split(',')
        .map(|x| x.parse::<f64>().map_err(|e| anyhow::anyhow!("{e}: '{x}'")))
        .collect::<Result<_>>()?;
    anyhow::ensure!(vals.len() == 10, "--design needs 10 comma-separated values");
    let raw: [f64; 10] = vals.try_into().unwrap();
    let ev = NativeEvaluator::new(mem);
    let mut t = Table::new(
        &format!("native evaluation on {} (raw design {spec})", mem.name()),
        &["workload", "energy mJ", "latency ms", "area mm2", "feasible", "EDAP"],
    );
    for name in ALL_NAMES {
        let w = imcopt::workloads::by_name(name)?;
        let m = ev.evaluate(&raw, &w);
        t.row(vec![
            name.into(),
            format!("{:.4}", m.energy * 1e3),
            format!("{:.4}", m.latency * 1e3),
            format!("{:.2}", m.area),
            m.feasible.to_string(),
            format!("{:.4}", m.edap()),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn cmd_workloads(args: &Args) -> Result<()> {
    // `--spec` lists an ingested/synthetic family instead of the
    // canonical nine (also the CI corpus-parsing entry point)
    let workloads: Vec<imcopt::workloads::Workload> = match args.opt("spec") {
        Some(s) => ScenarioSpec::parse(s)?.set.workloads,
        None => ALL_NAMES
            .iter()
            .map(|n| imcopt::workloads::by_name(n))
            .collect::<Result<_>>()?,
    };
    let mut t = Table::new(
        "workload models (matmul view; 8-bit weights/activations)",
        &["name", "mapped layers", "dynamic", "weights", "largest layer", "MACs"],
    );
    for w in &workloads {
        let dynamic = w.layers.iter().filter(|l| l.dynamic()).count();
        t.row(vec![
            w.name.clone(),
            w.mapped_layers().to_string(),
            dynamic.to_string(),
            format!("{:.3e}", w.total_weights() as f64),
            format!("{:.3e}", w.max_layer_weights() as f64),
            format!("{:.3e}", w.total_macs() as f64),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn cmd_space() -> Result<()> {
    let mut t = Table::new(
        "search-space variants",
        &["variant", "size", "free params"],
    );
    for space in [
        SearchSpace::rram(),
        SearchSpace::sram(),
        SearchSpace::sram_tech(),
        SearchSpace::rram_reduced(),
    ] {
        t.row(vec![
            space.variant.into(),
            format!("{:.3e}", space.size() as f64),
            space.free_params().len().to_string(),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let engine = imcopt::runtime::Engine::load_default()?;
    println!(
        "artifacts loaded: fitness batches {:?}, accproxy {}",
        engine.fitness_batch_sizes(),
        engine.has_accproxy()
    );
    // quick agreement check against the native evaluator
    let space = SearchSpace::rram();
    let mut rng = imcopt::util::rng::Rng::seed_from(7);
    let raws: Vec<[f64; 10]> = (0..8)
        .map(|_| space.decode(&space.random(&mut rng)))
        .collect();
    let w = imcopt::workloads::resnet18();
    let native = NativeEvaluator::new(MemoryTech::Rram);
    let pjrt = engine.fitness(&raws, &w, MemoryTech::Rram)?;
    let mut worst: f64 = 0.0;
    for (raw, pm) in raws.iter().zip(&pjrt) {
        let nm = native.evaluate(raw, &w);
        for (a, b) in [
            (nm.energy, pm.energy),
            (nm.latency, pm.latency),
            (nm.area, pm.area),
        ] {
            worst = worst.max(((a - b) / a).abs());
        }
        anyhow::ensure!(
            nm.feasible == pm.feasible,
            "feasibility mismatch on {raw:?}"
        );
    }
    println!("native↔pjrt agreement: worst relative deviation {worst:.2e} (8 designs, resnet18)");
    anyhow::ensure!(worst < 5e-3, "deviation exceeds 0.5%");
    println!("artifacts OK");
    Ok(())
}
