//! RRAM non-ideality model and accuracy estimation (paper §IV-H).
//!
//! The paper maps QAT-trained 8-bit models onto analog tiles with AIHWKIT,
//! modeling (i) conductance-dependent Gaussian programming noise with a
//! 4th-order-polynomial σ(g) fitted to Wan et al. 2022 measurements,
//! (ii) IR-drop, (iii) 8-bit DAC/ADC quantization and (iv) 1 % additive
//! output noise, then averages accuracy over 30 noisy evaluations.
//!
//! We reproduce the same pipeline with a **proxy**: the L1 Pallas noisy
//! crossbar kernel (`python/compile/kernels/crossbar.py`) measures the
//! relative MVM output error ε of a design's (R × C, bits/cell) tile
//! configuration over proxy matrices (executed from Rust through the AOT
//! `accproxy` artifact, 30 iterations like the paper), and a calibrated
//! monotone map converts ε into estimated task accuracy anchored at the
//! paper's 8-bit baselines. The native [`analytical_eps`] fallback is the
//! closed-form expectation of the same kernel — the two agree within test
//! tolerance and preserve the paper's ranking signals: more bits/cell and
//! larger arrays hurt accuracy; cycle-to-cycle noise dominates IR-drop.

use crate::model::MemoryTech;
use crate::space::idx;

/// σ(g)/g_max polynomial coefficients (4th order, evaluated on normalized
/// conductance g ∈ [0,1]); fit shape follows Wan et al. 2022 / AIHWKIT:
/// noise is largest mid-range and smaller at the conductance extremes.
/// Mirrored in `python/compile/hwspec.py`.
pub const SIGMA_POLY: [f64; 5] = [0.010, 0.080, -0.160, 0.120, -0.030];

/// Evaluate the conductance-noise polynomial at normalized conductance.
pub fn sigma_of_g(g_norm: f64) -> f64 {
    let g = g_norm.clamp(0.0, 1.0);
    let mut acc = 0.0;
    let mut p = 1.0;
    for c in SIGMA_POLY {
        acc += c * p;
        p *= g;
    }
    acc.max(0.0)
}

/// IR-drop severity coefficient per (rows × cols) relative to a 512×512
/// array at nominal wire resistance.
pub const IR_COEFF: f64 = 0.035;
/// Additive output-referred noise (1 % of full scale, paper §IV-H).
pub const OUT_NOISE: f64 = 0.01;
/// DAC/ADC quantization: 8-bit uniform.
pub const QUANT_BITS: f64 = 8.0;

/// Noise specification derived from a design point; feeds both the AOT
/// accuracy-proxy artifact and the analytical fallback.
#[derive(Clone, Copy, Debug)]
pub struct NoiseSpec {
    /// Mean conductance-noise std (σ̄ over uniform g).
    pub sigma_mean: f64,
    /// Multi-level amplification: an 8-bit weight sliced into `8/B` cells
    /// of `B` bits concentrates more significance per device.
    pub level_factor: f64,
    /// Relative IR-drop attenuation across the array.
    pub ir_drop: f64,
}

impl NoiseSpec {
    /// Derive from a decoded design vector. SRAM designs are digital and
    /// carry no programming noise or IR-drop (only quantization).
    pub fn from_design(raw: &[f64; 10], mem: MemoryTech) -> NoiseSpec {
        match mem {
            MemoryTech::Sram => NoiseSpec {
                sigma_mean: 0.0,
                level_factor: 0.0,
                ir_drop: 0.0,
            },
            MemoryTech::Rram => {
                let bits = raw[idx::BITS_CELL];
                let rows = raw[idx::ROWS];
                let cols = raw[idx::COLS];
                // average σ(g) over g ∈ [0,1] (trapezoid, 32 points;
                // mirrored in hwspec.py)
                let n = 32;
                let mut s = 0.0;
                for i in 0..=n {
                    let g = i as f64 / n as f64;
                    let w = if i == 0 || i == n { 0.5 } else { 1.0 };
                    s += w * sigma_of_g(g);
                }
                let sigma_mean = s / n as f64;
                NoiseSpec {
                    sigma_mean,
                    level_factor: (bits).sqrt(),
                    ir_drop: IR_COEFF * (rows / 512.0) * (cols / 512.0),
                }
            }
        }
    }

    /// Effective per-weight relative noise std.
    pub fn weight_sigma(&self) -> f64 {
        self.sigma_mean * self.level_factor
    }
}

/// Closed-form expectation of the noisy-crossbar relative MVM error for a
/// network of `depth` mapped layers: independent error sources add in
/// quadrature per layer and error compounds ~√depth across layers.
pub fn analytical_eps(spec: &NoiseSpec, depth: usize) -> f64 {
    let e_noise = spec.weight_sigma();
    let e_ir = spec.ir_drop;
    let e_quant = 1.0 / ((2f64).powf(QUANT_BITS) * (12f64).sqrt());
    let e_out = OUT_NOISE;
    let per_layer =
        (e_noise * e_noise + e_ir * e_ir + e_quant * e_quant + e_out * e_out).sqrt();
    per_layer * (depth as f64).sqrt()
}

/// The paper's 8-bit QAT baselines (§IV-H): (workload, dataset, accuracy,
/// chance level).
pub const BASELINES: [(&str, &str, f64, f64); 4] = [
    ("resnet18", "CIFAR-10", 0.9488, 0.10),
    ("vgg16", "SVHN", 0.9789, 0.10),
    ("alexnet", "Fashion-MNIST", 0.9350, 0.10),
    ("mobilenetv3", "CIFAR-100", 0.7003, 0.01),
];

/// Calibration scale: relative error at which accuracy has decayed by 1/e
/// of its above-chance margin.
pub const EPS_SCALE: f64 = 0.25;

/// Map a measured/predicted relative output error onto estimated task
/// accuracy: exponential decay from the 8-bit baseline to chance level.
/// Monotone in ε — exactly the ranking property the Fig. 8 objective needs.
pub fn accuracy_from_eps(eps: f64, base_acc: f64, chance: f64) -> f64 {
    chance + (base_acc - chance) * (-(eps / EPS_SCALE) * (eps / EPS_SCALE)).exp()
}

/// Whether a workload has a Fig. 8 accuracy baseline — accuracy-aware
/// objectives (and the robustness corner specs built on them) are only
/// defined over baseline-covered workloads.
pub fn has_baseline(workload: &str) -> bool {
    BASELINES.iter().any(|(n, _, _, _)| *n == workload)
}

/// Baseline lookup by workload name (panics on workloads without a Fig. 8
/// baseline — the experiment only uses the CNN-4 set).
pub fn baseline(workload: &str) -> (f64, f64) {
    BASELINES
        .iter()
        .find(|(n, _, _, _)| *n == workload)
        .map(|&(_, _, b, c)| (b, c))
        .unwrap_or_else(|| panic!("no accuracy baseline for workload '{workload}'"))
}

/// Full native accuracy estimate for one design on one workload.
pub fn estimate_native(raw: &[f64; 10], mem: MemoryTech, workload: &crate::workloads::Workload) -> f64 {
    let spec = NoiseSpec::from_design(raw, mem);
    let eps = analytical_eps(&spec, workload.mapped_layers());
    let (base, chance) = baseline(&workload.name);
    accuracy_from_eps(eps, base, chance)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::resnet18;

    #[test]
    fn sigma_poly_shape() {
        // non-negative over the domain, peaked mid-range
        for i in 0..=20 {
            let g = i as f64 / 20.0;
            assert!(sigma_of_g(g) >= 0.0);
        }
        assert!(sigma_of_g(0.5) > sigma_of_g(0.0));
        assert!(sigma_of_g(0.5) > sigma_of_g(1.0));
    }

    #[test]
    fn more_bits_more_noise() {
        let mut raw1 = [512.0, 256.0, 16.0, 8.0, 24.0, 1.0, 0.85, 2.0, 4096.0, 32.0];
        let acc1 = estimate_native(&raw1, MemoryTech::Rram, &resnet18());
        raw1[crate::space::idx::BITS_CELL] = 4.0;
        let acc4 = estimate_native(&raw1, MemoryTech::Rram, &resnet18());
        assert!(acc4 < acc1, "acc(4b)={acc4} !< acc(1b)={acc1}");
    }

    #[test]
    fn bigger_arrays_more_ir_drop() {
        let small = NoiseSpec::from_design(
            &[64.0, 64.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0],
            MemoryTech::Rram,
        );
        let big = NoiseSpec::from_design(
            &[512.0, 512.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0],
            MemoryTech::Rram,
        );
        assert!(big.ir_drop > small.ir_drop);
    }

    #[test]
    fn noise_dominates_ir_drop() {
        // Paper §IV-H: cycle-to-cycle variation impacts accuracy more than
        // IR-drop. Check at the mid design point.
        let spec = NoiseSpec::from_design(
            &[256.0, 256.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0],
            MemoryTech::Rram,
        );
        assert!(spec.weight_sigma() > spec.ir_drop, "{spec:?}");
    }

    #[test]
    fn accuracy_bounds() {
        let (base, chance) = baseline("resnet18");
        assert!((accuracy_from_eps(0.0, base, chance) - base).abs() < 1e-12);
        let deep = accuracy_from_eps(10.0, base, chance);
        assert!((deep - chance).abs() < 1e-6);
        // monotone
        assert!(accuracy_from_eps(0.1, base, chance) > accuracy_from_eps(0.2, base, chance));
    }

    #[test]
    fn sram_designs_are_noise_free() {
        let spec = NoiseSpec::from_design(
            &[256.0, 256.0, 16.0, 8.0, 24.0, 1.0, 0.85, 2.0, 4096.0, 32.0],
            MemoryTech::Sram,
        );
        assert_eq!(spec.weight_sigma(), 0.0);
        assert_eq!(spec.ir_drop, 0.0);
        // quantization + output noise still bound accuracy below baseline
        let eps = analytical_eps(&spec, 20);
        assert!(eps > 0.0 && eps < 0.1);
    }

    #[test]
    #[should_panic(expected = "no accuracy baseline")]
    fn unknown_baseline_panics() {
        baseline("gpt2-medium");
    }

    #[test]
    fn has_baseline_matches_table() {
        for (name, _, _, _) in BASELINES {
            assert!(has_baseline(name));
        }
        assert!(!has_baseline("gpt2-medium"));
        assert!(!has_baseline(""));
    }
}
