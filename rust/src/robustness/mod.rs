//! Device-variation robustness: deterministic non-ideality injection.
//!
//! The paper's §IV-H accuracy model evaluates RRAM non-idealities at a
//! single nominal operating point. Real deployments see device-level
//! spread around that point: σ(g)-corner scaling across wafers,
//! conductance drift over retention time, stuck-at-G_min/G_max cells,
//! and IR-drop corners from wire-resistance variation. This module makes
//! that spread a first-class, *deterministic* model — mirroring how
//! `util::fault` made process-level faults deterministic and testable,
//! but at the device level:
//!
//! * [`Perturbation`] — one operating point, expressed as a transform on
//!   a design's [`accuracy::NoiseSpec`]. Stuck-at and drift errors fold
//!   into the conductance-noise term in quadrature (they are independent
//!   error sources on the same weights), so every knob is monotone: more
//!   drift, more stuck cells, a higher σ corner or a worse IR corner can
//!   only increase the per-layer error ε. SRAM designs (digital, no
//!   programming noise, no IR-drop) are invariants of every perturbation.
//! * [`Corner`] — the three named operating corners (low/nominal/high).
//! * [`PerturbationEnsemble`] — corners × K Monte-Carlo draws, generated
//!   from the seed alone (no per-thread or per-worker state), so ensemble
//!   members are bit-identical across `--threads`, `--workers`, and
//!   kill/`--resume` by construction.
//! * [`RobustMode`] — how a robust objective aggregates per-member
//!   scores: worst-case, CVaR(q) (mean of the worst q-tail), or mean.
//!
//! The coordinator wires ensembles into [`crate::coordinator::JointProblem`]
//! via perturbation-id-extended accuracy-memo keys (id 0 is the unperturbed
//! nominal path, ids 1..=N index ensemble members); see `docs/robustness.md`.
//!
//! [`accuracy::NoiseSpec`]: crate::accuracy::NoiseSpec

use crate::accuracy::NoiseSpec;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};

/// Relative error contributed by a fully stuck cell population of
/// fraction 1 (stuck-at-G_min/G_max is a gross weight error; the
/// expected contribution of a fraction `f` scales as √f in quadrature).
pub const STUCK_ERR: f64 = 0.5;

/// Conductance-drift coefficient: relative error per unit of normalized
/// retention-time drift (drift = 1 ≈ the paper's 1-year retention corner).
pub const DRIFT_COEFF: f64 = 0.05;

/// One device-variation operating point, as a transform on a design's
/// noise specification. All knobs are non-negative; the nominal point is
/// `sigma_scale = ir_scale = 1`, `drift = stuck_frac = 0`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Perturbation {
    /// Multiplier on the mean conductance-noise std (σ(g) corner).
    pub sigma_scale: f64,
    /// Normalized retention-time drift (0 = fresh, 1 = retention corner).
    pub drift: f64,
    /// Fraction of cells stuck at G_min/G_max.
    pub stuck_frac: f64,
    /// Multiplier on the IR-drop attenuation (wire-resistance corner).
    pub ir_scale: f64,
}

impl Perturbation {
    /// The identity transform (nominal operating point).
    pub fn nominal() -> Perturbation {
        Perturbation {
            sigma_scale: 1.0,
            drift: 0.0,
            stuck_frac: 0.0,
            ir_scale: 1.0,
        }
    }

    /// Transform a design's noise spec to this operating point.
    ///
    /// Stuck-at and drift errors enter the conductance-noise term in
    /// quadrature (independent error sources on the same weights), so ε
    /// is monotone in every knob. `level_factor` is untouched — and
    /// because `weight_sigma = sigma_mean × level_factor`, SRAM designs
    /// (`level_factor = 0`, `ir_drop = 0`) see no effect from any
    /// perturbation: device variation is an analog phenomenon.
    pub fn apply(&self, spec: &NoiseSpec) -> NoiseSpec {
        let scaled = spec.sigma_mean * self.sigma_scale.max(0.0);
        let stuck = STUCK_ERR * self.stuck_frac.max(0.0).sqrt();
        let drift = DRIFT_COEFF * self.drift.max(0.0);
        NoiseSpec {
            sigma_mean: (scaled * scaled + stuck * stuck + drift * drift).sqrt(),
            level_factor: spec.level_factor,
            ir_drop: spec.ir_drop * self.ir_scale.max(0.0),
        }
    }
}

/// Named device-variation corners (the endpoints of the measured σ(g)
/// spread plus the retention/stuck-at worst case).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Corner {
    /// Best-case wafer: 0.8× σ(g), 0.8× IR-drop, no drift or stuck cells.
    Low,
    /// The paper's nominal operating point (identity transform).
    Nominal,
    /// Worst-case wafer: 1.25× σ(g), 1.25× IR-drop, half-retention drift
    /// and 0.2 % stuck cells.
    High,
}

impl Corner {
    pub const ALL: [Corner; 3] = [Corner::Low, Corner::Nominal, Corner::High];

    /// Parse a corner token (as used in `--spec` scenario strings).
    pub fn parse(s: &str) -> Option<Corner> {
        match s {
            "low" => Some(Corner::Low),
            "nominal" => Some(Corner::Nominal),
            "high" => Some(Corner::High),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Corner::Low => "low",
            Corner::Nominal => "nominal",
            Corner::High => "high",
        }
    }

    /// The corner's operating point.
    pub fn perturbation(&self) -> Perturbation {
        match self {
            Corner::Low => Perturbation {
                sigma_scale: 0.8,
                drift: 0.0,
                stuck_frac: 0.0,
                ir_scale: 0.8,
            },
            Corner::Nominal => Perturbation::nominal(),
            Corner::High => Perturbation {
                sigma_scale: 1.25,
                drift: 0.5,
                stuck_frac: 0.002,
                ir_scale: 1.25,
            },
        }
    }
}

/// A deterministic set of perturbations a robust objective scores over.
///
/// Construction is a pure function of the flags (seed, draw count or
/// corner name) — no wall-clock, thread, or worker state enters — so the
/// member list is bit-identical for any `--threads`/`--workers` count
/// and across kill/`--resume`. Member *i* of the ensemble is addressed
/// as perturbation id `i + 1` in the coordinator's accuracy memo (id 0
/// is reserved for the unperturbed nominal path).
#[derive(Clone, Debug)]
pub struct PerturbationEnsemble {
    pub members: Vec<Perturbation>,
    descriptor: String,
}

impl PerturbationEnsemble {
    /// The three corners plus `draws_per_corner` Monte-Carlo draws
    /// jittered around each corner. Each draw gets its own RNG seeded
    /// from `(seed, corner, draw)` alone, so members are independent of
    /// generation order and of each other.
    pub fn corners_and_draws(seed: u64, draws_per_corner: usize) -> PerturbationEnsemble {
        let mut members = Vec::with_capacity(3 * (1 + draws_per_corner));
        for c in Corner::ALL {
            members.push(c.perturbation());
        }
        for (ci, c) in Corner::ALL.iter().enumerate() {
            let base = c.perturbation();
            for di in 0..draws_per_corner {
                let stream = (ci * draws_per_corner + di + 1) as u64;
                let mut rng =
                    Rng::seed_from(seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                members.push(Perturbation {
                    sigma_scale: (base.sigma_scale * (1.0 + 0.08 * rng.normal())).max(0.25),
                    drift: (base.drift * (1.0 + 0.20 * rng.normal())).max(0.0),
                    stuck_frac: (base.stuck_frac * (1.0 + 0.25 * rng.normal())).max(0.0),
                    ir_scale: (base.ir_scale * (1.0 + 0.05 * rng.normal())).max(0.25),
                });
            }
        }
        PerturbationEnsemble {
            members,
            descriptor: format!("ens-s{seed}-k{draws_per_corner}"),
        }
    }

    /// A one-member ensemble pinned to a named corner (the `--spec`
    /// noise-sweep family: score every design at exactly this corner).
    pub fn single_corner(c: Corner) -> PerturbationEnsemble {
        PerturbationEnsemble {
            members: vec![c.perturbation()],
            descriptor: format!("corner-{}", c.name()),
        }
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// A short string identifying the ensemble's construction — joined
    /// into `JointProblem::config_key`/`acc_scope` and the checkpoint
    /// config fingerprint so persisted memos never mix across ensembles.
    pub fn descriptor(&self) -> &str {
        &self.descriptor
    }
}

/// How a robust objective aggregates per-member scores (scores are
/// costs: lower is better, `+∞` is infeasible).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RobustMode {
    /// Worst case over the ensemble (max cost).
    Worst,
    /// Conditional value-at-risk: mean of the worst `⌈q·N⌉` costs.
    Cvar(f64),
    /// Plain ensemble mean.
    Mean,
}

impl RobustMode {
    /// Parse a `--robust` flag value: `worst`, `mean`, or `cvar<q>` with
    /// `q ∈ (0, 1]` (e.g. `cvar0.25`).
    pub fn parse(s: &str) -> Result<RobustMode> {
        match s {
            "worst" => Ok(RobustMode::Worst),
            "mean" => Ok(RobustMode::Mean),
            _ => {
                if let Some(qs) = s.strip_prefix("cvar") {
                    let q: f64 = qs
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad cvar quantile '{qs}'"))?;
                    ensure!(
                        q > 0.0 && q <= 1.0,
                        "cvar quantile must be in (0, 1], got {q}"
                    );
                    Ok(RobustMode::Cvar(q))
                } else {
                    bail!("unknown robust mode '{s}' (expected worst|cvar<q>|mean)")
                }
            }
        }
    }

    /// Canonical flag spelling (round-trips through [`RobustMode::parse`]).
    pub fn descriptor(&self) -> String {
        match self {
            RobustMode::Worst => "worst".to_string(),
            RobustMode::Mean => "mean".to_string(),
            RobustMode::Cvar(q) => format!("cvar{q}"),
        }
    }

    /// Aggregate per-member costs. Sorts `scores` in place (CVaR);
    /// non-finite member costs propagate (an ensemble with any
    /// infeasible member is worst-case infeasible).
    pub fn aggregate(&self, scores: &mut [f64]) -> f64 {
        assert!(!scores.is_empty(), "robust aggregate over empty ensemble");
        match self {
            RobustMode::Worst => scores.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            RobustMode::Mean => scores.iter().sum::<f64>() / scores.len() as f64,
            RobustMode::Cvar(q) => {
                scores.sort_by(|a, b| {
                    b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal)
                });
                let n = ((q * scores.len() as f64).ceil() as usize).clamp(1, scores.len());
                scores[..n].iter().sum::<f64>() / n as f64
            }
        }
    }
}

/// A fully-resolved robust-objective configuration: the aggregation mode
/// plus the ensemble it aggregates over.
#[derive(Clone, Debug)]
pub struct RobustConfig {
    pub mode: RobustMode,
    pub ensemble: PerturbationEnsemble,
}

impl RobustConfig {
    /// Build from the `--robust` flag value plus the run seed and draw
    /// count (the standard corners-and-draws ensemble).
    pub fn from_flag(mode: &str, seed: u64, draws_per_corner: usize) -> Result<RobustConfig> {
        Ok(RobustConfig {
            mode: RobustMode::parse(mode)?,
            ensemble: PerturbationEnsemble::corners_and_draws(seed, draws_per_corner),
        })
    }

    /// One-corner config (used by `--spec … :<corner>` scenario strings);
    /// the mode is irrelevant for a single member.
    pub fn at_corner(c: Corner) -> RobustConfig {
        RobustConfig {
            mode: RobustMode::Worst,
            ensemble: PerturbationEnsemble::single_corner(c),
        }
    }

    /// Joined into config keys / fingerprints; identifies mode + ensemble.
    pub fn descriptor(&self) -> String {
        format!("{}@{}", self.mode.descriptor(), self.ensemble.descriptor())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::{analytical_eps, NoiseSpec};
    use crate::model::MemoryTech;

    fn rram_spec() -> NoiseSpec {
        NoiseSpec::from_design(
            &[256.0, 256.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0],
            MemoryTech::Rram,
        )
    }

    fn sram_spec() -> NoiseSpec {
        NoiseSpec::from_design(
            &[256.0, 256.0, 16.0, 8.0, 24.0, 1.0, 0.85, 2.0, 4096.0, 32.0],
            MemoryTech::Sram,
        )
    }

    #[test]
    fn nominal_perturbation_is_identity() {
        let spec = rram_spec();
        let p = Perturbation::nominal().apply(&spec);
        assert!((p.sigma_mean - spec.sigma_mean).abs() < 1e-15);
        assert!((p.ir_drop - spec.ir_drop).abs() < 1e-15);
        assert_eq!(p.level_factor, spec.level_factor);
    }

    #[test]
    fn corners_order_eps() {
        let spec = rram_spec();
        let eps = |c: Corner| analytical_eps(&c.perturbation().apply(&spec), 4);
        assert!(eps(Corner::Low) < eps(Corner::Nominal));
        assert!(eps(Corner::Nominal) < eps(Corner::High));
    }

    #[test]
    fn eps_monotone_in_every_knob() {
        // property sweep: increasing any single knob never decreases ε
        let spec = rram_spec();
        let grid = [0.0, 0.001, 0.01, 0.1, 0.5, 1.0, 2.0];
        let eps_at = |p: Perturbation| analytical_eps(&p.apply(&spec), 4);
        for w in grid.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let mut a = Perturbation::nominal();
            let mut b = Perturbation::nominal();
            a.stuck_frac = lo;
            b.stuck_frac = hi;
            assert!(eps_at(a) <= eps_at(b), "stuck_frac {lo} vs {hi}");
            let mut a = Perturbation::nominal();
            let mut b = Perturbation::nominal();
            a.drift = lo;
            b.drift = hi;
            assert!(eps_at(a) <= eps_at(b), "drift {lo} vs {hi}");
            let mut a = Perturbation::nominal();
            let mut b = Perturbation::nominal();
            a.sigma_scale = lo;
            b.sigma_scale = hi;
            assert!(eps_at(a) <= eps_at(b), "sigma_scale {lo} vs {hi}");
            let mut a = Perturbation::nominal();
            let mut b = Perturbation::nominal();
            a.ir_scale = lo;
            b.ir_scale = hi;
            assert!(eps_at(a) <= eps_at(b), "ir_scale {lo} vs {hi}");
        }
    }

    #[test]
    fn sram_specs_are_perturbation_invariant() {
        let spec = sram_spec();
        let worst = Corner::High.perturbation().apply(&spec);
        // level_factor = 0 nulls the (perturbed) conductance term and
        // ir_drop = 0 scales to 0: digital designs see no device variation
        assert_eq!(worst.weight_sigma(), 0.0);
        assert_eq!(worst.ir_drop, 0.0);
        assert_eq!(
            analytical_eps(&worst, 8).to_bits(),
            analytical_eps(&spec, 8).to_bits()
        );
    }

    #[test]
    fn ensemble_is_deterministic_in_seed() {
        let a = PerturbationEnsemble::corners_and_draws(42, 4);
        let b = PerturbationEnsemble::corners_and_draws(42, 4);
        assert_eq!(a.len(), 3 + 3 * 4);
        assert_eq!(a.members, b.members);
        assert_eq!(a.descriptor(), b.descriptor());
        let c = PerturbationEnsemble::corners_and_draws(43, 4);
        assert_ne!(a.members, c.members);
        // first three members are the exact corners, in order
        assert_eq!(a.members[0], Corner::Low.perturbation());
        assert_eq!(a.members[1], Corner::Nominal.perturbation());
        assert_eq!(a.members[2], Corner::High.perturbation());
    }

    #[test]
    fn single_corner_ensemble() {
        let e = PerturbationEnsemble::single_corner(Corner::High);
        assert_eq!(e.len(), 1);
        assert_eq!(e.members[0], Corner::High.perturbation());
        assert_eq!(e.descriptor(), "corner-high");
    }

    #[test]
    fn robust_mode_parses_and_round_trips() {
        assert_eq!(RobustMode::parse("worst").unwrap(), RobustMode::Worst);
        assert_eq!(RobustMode::parse("mean").unwrap(), RobustMode::Mean);
        assert_eq!(
            RobustMode::parse("cvar0.25").unwrap(),
            RobustMode::Cvar(0.25)
        );
        for mode in ["worst", "mean", "cvar0.25"] {
            let parsed = RobustMode::parse(mode).unwrap();
            assert_eq!(parsed.descriptor(), mode);
        }
        assert!(RobustMode::parse("median").is_err());
        assert!(RobustMode::parse("cvar0").is_err());
        assert!(RobustMode::parse("cvar1.5").is_err());
        assert!(RobustMode::parse("cvarx").is_err());
    }

    #[test]
    fn aggregate_semantics() {
        let mut s = [3.0, 1.0, 4.0, 2.0];
        assert_eq!(RobustMode::Worst.aggregate(&mut s), 4.0);
        let mut s = [3.0, 1.0, 4.0, 2.0];
        assert_eq!(RobustMode::Mean.aggregate(&mut s), 2.5);
        // cvar0.5 over 4 = mean of the worst 2 = (4 + 3) / 2
        let mut s = [3.0, 1.0, 4.0, 2.0];
        assert_eq!(RobustMode::Cvar(0.5).aggregate(&mut s), 3.5);
        // cvar1.0 == mean; tiny q clamps to the single worst member
        let mut s = [3.0, 1.0, 4.0, 2.0];
        assert_eq!(RobustMode::Cvar(1.0).aggregate(&mut s), 2.5);
        let mut s = [3.0, 1.0, 4.0, 2.0];
        assert_eq!(RobustMode::Cvar(1e-9).aggregate(&mut s), 4.0);
        // an infeasible member dominates worst-case and poisons the mean
        let mut s = [1.0, f64::INFINITY];
        assert_eq!(RobustMode::Worst.aggregate(&mut s), f64::INFINITY);
        let mut s = [1.0, f64::INFINITY];
        assert_eq!(RobustMode::Mean.aggregate(&mut s), f64::INFINITY);
    }

    #[test]
    fn config_descriptors() {
        let rc = RobustConfig::from_flag("cvar0.25", 7, 2).unwrap();
        assert_eq!(rc.descriptor(), "cvar0.25@ens-s7-k2");
        assert_eq!(rc.ensemble.len(), 9);
        assert!(RobustConfig::from_flag("nope", 7, 2).is_err());
        let one = RobustConfig::at_corner(Corner::Low);
        assert_eq!(one.descriptor(), "worst@corner-low");
        assert_eq!(one.ensemble.len(), 1);
    }
}
