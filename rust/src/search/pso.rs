//! Particle swarm optimization (Kennedy & Eberhart 1995) over the
//! index-coded design space — one of the Table 3 baselines. Particles move
//! in continuous index space and are snapped to the grid for evaluation.
//! The paper observes PSO converging to *local* minima on this problem,
//! which the discrete snapping readily explains.

use super::{BestTracker, OptResult, Optimizer, Problem, SearchBudget};
use crate::space::Design;
use crate::util::rng::Rng;
use std::time::Instant;

pub struct Pso {
    pub budget: SearchBudget,
    /// Inertia weight.
    pub w: f64,
    /// Cognitive coefficient.
    pub c1: f64,
    /// Social coefficient.
    pub c2: f64,
}

impl Pso {
    pub fn new(budget: SearchBudget) -> Pso {
        Pso {
            budget,
            w: 0.72,
            c1: 1.49,
            c2: 1.49,
        }
    }
}

impl Optimizer for Pso {
    fn name(&self) -> String {
        "PSO".into()
    }

    fn run(&self, problem: &dyn Problem, rng: &mut Rng) -> OptResult {
        let t0 = Instant::now();
        let space = problem.space();
        let n = space.params.len();
        let pop = self.budget.pop;
        let mut tracker = BestTracker::default();
        let mut evals = 0usize;

        // positions/velocities in continuous index space
        let mut xs: Vec<Vec<f64>> = (0..pop)
            .map(|_| {
                let d = problem.random_candidate(rng);
                d.0.iter().map(|&v| v as f64).collect()
            })
            .collect();
        let mut vs: Vec<Vec<f64>> = (0..pop)
            .map(|_| {
                (0..n)
                    .map(|i| {
                        let hi = space.params[i].cardinality() as f64 - 1.0;
                        rng.range_f64(-hi * 0.25, hi * 0.25)
                    })
                    .collect()
            })
            .collect();

        let designs: Vec<Design> = xs.iter().map(|x| space.clamp_round(x)).collect();
        let scores = problem.score_batch(&designs);
        evals += pop;
        tracker.observe(&designs, &scores);
        tracker.end_generation();

        let mut pbest = xs.clone();
        let mut pbest_score = scores.clone();
        let gbest_idx = (0..pop)
            .min_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap())
            .unwrap();
        let mut gbest = xs[gbest_idx].clone();
        let mut gbest_score = scores[gbest_idx];

        for _gen in 1..self.budget.gens {
            for p in 0..pop {
                for i in 0..n {
                    let r1 = rng.f64();
                    let r2 = rng.f64();
                    vs[p][i] = self.w * vs[p][i]
                        + self.c1 * r1 * (pbest[p][i] - xs[p][i])
                        + self.c2 * r2 * (gbest[i] - xs[p][i]);
                    xs[p][i] += vs[p][i];
                    // reflect at bounds
                    let hi = space.params[i].cardinality() as f64 - 1.0;
                    if xs[p][i] < 0.0 {
                        xs[p][i] = -xs[p][i];
                        vs[p][i] = -vs[p][i];
                    }
                    if xs[p][i] > hi {
                        xs[p][i] = (2.0 * hi - xs[p][i]).max(0.0);
                        vs[p][i] = -vs[p][i];
                    }
                }
            }
            let designs: Vec<Design> = xs.iter().map(|x| space.clamp_round(x)).collect();
            let scores = problem.score_batch(&designs);
            evals += pop;
            tracker.observe(&designs, &scores);
            tracker.end_generation();
            for p in 0..pop {
                if scores[p] < pbest_score[p] {
                    pbest_score[p] = scores[p];
                    pbest[p] = xs[p].clone();
                }
                if scores[p] < gbest_score {
                    gbest_score = scores[p];
                    gbest = xs[p].clone();
                }
            }
        }
        tracker.into_result(self.name(), evals, t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::testutil::Sphere;
    use crate::space::SearchSpace;

    #[test]
    fn pso_improves_over_random() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let pso = Pso::new(SearchBudget { pop: 20, gens: 15 });
        let r = pso.run(&p, &mut Rng::seed_from(1));
        assert!(r.best_score < 6.0, "{}", r.best_score);
        assert_eq!(r.history.len(), 15);
        // improvement over the first generation
        assert!(r.history.last().unwrap() <= &r.history[0]);
    }

    #[test]
    fn positions_stay_in_bounds() {
        // Indirectly verified by score: out-of-bounds rounding would panic
        // in decode; run a longer swarm on the full space.
        let p = Sphere::centered(SearchSpace::sram_tech());
        let pso = Pso::new(SearchBudget { pop: 12, gens: 20 });
        let r = pso.run(&p, &mut Rng::seed_from(2));
        assert!(r.best_score.is_finite());
    }
}
