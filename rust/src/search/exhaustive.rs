//! Exhaustive enumeration — ground truth for the reduced-space algorithm
//! comparison (paper §III-C1, Table 3). Only usable on spaces small enough
//! to enumerate; provides the global minimum that the stochastic
//! algorithms are judged against.

use super::{OptResult, Optimizer, Problem};
use crate::space::Design;
use crate::util::rng::Rng;
use std::time::Instant;

pub struct Exhaustive {
    /// Evaluate in chunks of this many designs (batches through PJRT).
    pub chunk: usize,
}

impl Default for Exhaustive {
    fn default() -> Self {
        Exhaustive { chunk: 256 }
    }
}

impl Exhaustive {
    /// Enumerate and score the whole space, returning every (design,
    /// score) pair — used by Table 3 to find local/global minima and by
    /// Fig. 9 to draw the complete EDAP-cost cloud on small spaces.
    pub fn score_all(&self, problem: &dyn Problem) -> Vec<(Design, f64)> {
        let all = problem.space().enumerate();
        let mut out = Vec::with_capacity(all.len());
        for chunk in all.chunks(self.chunk) {
            let scores = problem.score_batch(chunk);
            out.extend(chunk.iter().cloned().zip(scores));
        }
        out
    }

    /// The set of *local minima* under single-parameter moves: designs no
    /// 1-Hamming neighbor improves on. Includes the global minimum.
    pub fn local_minima(
        &self,
        problem: &dyn Problem,
        scored: &[(Design, f64)],
    ) -> Vec<usize> {
        let space = problem.space();
        // dense lookup by linear index
        let mut score_by_idx = vec![f64::INFINITY; space.size() as usize];
        for (d, s) in scored {
            score_by_idx[space.linear_index(d) as usize] = *s;
        }
        let mut minima = Vec::new();
        'outer: for (i, (d, s)) in scored.iter().enumerate() {
            if !s.is_finite() {
                continue;
            }
            for pi in space.free_params() {
                for v in 0..space.params[pi].cardinality() as u16 {
                    if v == d.0[pi] {
                        continue;
                    }
                    let mut nd = d.clone();
                    nd.0[pi] = v;
                    if score_by_idx[space.linear_index(&nd) as usize] < *s {
                        continue 'outer;
                    }
                }
            }
            minima.push(i);
        }
        minima
    }
}

impl Optimizer for Exhaustive {
    fn name(&self) -> String {
        "Exhaustive".into()
    }

    fn run(&self, problem: &dyn Problem, _rng: &mut Rng) -> OptResult {
        let t0 = Instant::now();
        let scored = self.score_all(problem);
        let evals = scored.len();
        let mut tracker = super::BestTracker::default();
        for chunk in scored.chunks(4096) {
            let (ds, ss): (Vec<Design>, Vec<f64>) = chunk.iter().cloned().unzip();
            tracker.observe(&ds, &ss);
        }
        tracker.end_generation();
        tracker.into_result(self.name(), evals, t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::testutil::Sphere;
    use crate::space::SearchSpace;

    #[test]
    fn finds_exact_global_minimum() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let ex = Exhaustive::default();
        let r = ex.run(&p, &mut Rng::seed_from(0));
        assert_eq!(r.evals, 768);
        // brute-force check
        let scored = ex.score_all(&p);
        let min = scored
            .iter()
            .map(|(_, s)| *s)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(r.best_score, min);
    }

    #[test]
    fn local_minima_contains_global() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let ex = Exhaustive::default();
        let scored = ex.score_all(&p);
        let minima = ex.local_minima(&p, &scored);
        assert!(!minima.is_empty());
        let global = scored
            .iter()
            .enumerate()
            .min_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
            .unwrap()
            .0;
        assert!(minima.contains(&global));
        // a convex sphere has exactly one basin... but even-cardinality
        // parameters tie at two center indices; allow a small set
        assert!(minima.len() <= 8, "{}", minima.len());
    }
}
