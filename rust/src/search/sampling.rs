//! Hamming-distance-based diversity sampling (paper Algorithm 1, §III-C2).
//!
//! Three steps: (1) sample `P_H` random candidates (pre-filtered for
//! capacity by `Problem::random_candidate` in the weight-stationary case),
//! (2) greedily select the `P_E` most mutually distant candidates under
//! Hamming distance (max-min farthest-point traversal), (3) evaluate the
//! diverse set and keep the best `P_GA` as the GA's initial population.

use super::Problem;
use crate::space::Design;
use crate::util::rng::Rng;

/// Paper defaults: `P_H = 1000`, `P_E = 500`.
pub const P_H: usize = 1000;
pub const P_E: usize = 500;

/// Step 1: random candidate pool of size `p_h`.
pub fn random_pool(problem: &dyn Problem, p_h: usize, rng: &mut Rng) -> Vec<Design> {
    (0..p_h).map(|_| problem.random_candidate(rng)).collect()
}

/// Step 2: greedy max-min Hamming selection of `p_e` designs from `pool`.
///
/// `C₂` starts with the pool's first candidate; each iteration adds the
/// candidate maximizing its minimum Hamming distance to `C₂` (Eq. 1–2).
/// O(|pool| · p_e) with an incrementally maintained d_min array.
pub fn select_diverse(pool: &[Design], p_e: usize) -> Vec<Design> {
    assert!(!pool.is_empty());
    let p_e = p_e.min(pool.len());
    let mut selected: Vec<usize> = vec![0];
    // d_min[i] = min Hamming distance from pool[i] to the selected set
    let mut d_min: Vec<usize> = pool.iter().map(|d| d.hamming(&pool[0])).collect();
    while selected.len() < p_e {
        // farthest point from the selected set
        let (next, _) = d_min
            .iter()
            .enumerate()
            .filter(|(i, _)| !selected.contains(i))
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .unwrap();
        selected.push(next);
        for (i, dm) in d_min.iter_mut().enumerate() {
            *dm = (*dm).min(pool[i].hamming(&pool[next]));
        }
    }
    selected.into_iter().map(|i| pool[i].clone()).collect()
}

/// Full pipeline: sample `p_h`, diversify to `p_e`, evaluate, keep the
/// `p_ga` lowest-scoring designs as the initial population. Also returns
/// the number of evaluations spent (the ~30 % sampling overhead of
/// Table 6).
pub fn hamming_init(
    problem: &dyn Problem,
    p_h: usize,
    p_e: usize,
    p_ga: usize,
    rng: &mut Rng,
) -> (Vec<Design>, usize) {
    let pool = random_pool(problem, p_h, rng);
    let diverse = select_diverse(&pool, p_e);
    let scores = problem.score_batch(&diverse);
    let mut scored: Vec<(Design, f64)> = diverse.into_iter().zip(scores).collect();
    scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let evals = scored.len();
    let mut init: Vec<Design> = scored.into_iter().take(p_ga).map(|(d, _)| d).collect();
    // backfill with randoms if fewer than p_ga survived dedup/feasibility
    while init.len() < p_ga {
        init.push(problem.random_candidate(rng));
    }
    (init, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::testutil::Sphere;
    use crate::space::SearchSpace;
    use crate::util::proptest::check;

    #[test]
    fn diverse_selection_spreads() {
        let space = SearchSpace::rram();
        let mut rng = Rng::seed_from(1);
        let pool: Vec<Design> = (0..200).map(|_| space.random(&mut rng)).collect();
        let sel = select_diverse(&pool, 50);
        assert_eq!(sel.len(), 50);
        // min pairwise distance of selected set should beat that of a
        // random 50-subset (the point of the exercise)
        let min_pair = |xs: &[Design]| {
            let mut m = usize::MAX;
            for i in 0..xs.len() {
                for j in (i + 1)..xs.len() {
                    m = m.min(xs[i].hamming(&xs[j]));
                }
            }
            m
        };
        let random_subset: Vec<Design> = pool[..50].to_vec();
        assert!(min_pair(&sel) >= min_pair(&random_subset));
    }

    #[test]
    fn selection_is_deterministic() {
        let space = SearchSpace::rram();
        let mut rng = Rng::seed_from(2);
        let pool: Vec<Design> = (0..100).map(|_| space.random(&mut rng)).collect();
        assert_eq!(select_diverse(&pool, 30), select_diverse(&pool, 30));
    }

    #[test]
    fn hamming_init_returns_sorted_best() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let mut rng = Rng::seed_from(3);
        let (init, evals) = hamming_init(&p, 200, 100, 20, &mut rng);
        assert_eq!(init.len(), 20);
        assert_eq!(evals, 100);
        // the best of init must be close to the sphere optimum compared to
        // a random draw
        let s_init = p.score_batch(&init);
        let best_init = s_init.iter().cloned().fold(f64::INFINITY, f64::min);
        let randoms: Vec<Design> = (0..20).map(|_| p.space.random(&mut rng)).collect();
        let s_rand = p.score_batch(&randoms);
        let best_rand = s_rand.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(best_init <= best_rand, "{best_init} vs {best_rand}");
    }

    #[test]
    fn select_diverse_handles_small_pools() {
        let pool = vec![Design(vec![0; 10]), Design(vec![1; 10])];
        assert_eq!(select_diverse(&pool, 10).len(), 2);
    }

    #[test]
    fn property_selected_are_from_pool() {
        check("diverse ⊆ pool", 20, |rng| {
            let space = SearchSpace::sram();
            let pool: Vec<Design> =
                (0..(10 + rng.below(60))).map(|_| space.random(rng)).collect();
            let k = 1 + rng.below(pool.len());
            let sel = select_diverse(&pool, k);
            if sel.iter().all(|d| pool.contains(d)) && sel.len() == k {
                Ok(())
            } else {
                Err(format!("k={k} pool={} sel={}", pool.len(), sel.len()))
            }
        });
    }
}
