//! CMA-ES (Hansen) — Table 3 baseline.
//!
//! A standard rank-μ covariance-matrix-adaptation ES operating in
//! continuous index space. The paper reports CMA-ES failing to converge on
//! this problem class: the discretization plateau (many continuous points
//! snap to the same grid cell) starves the covariance update of gradient
//! signal and the +∞ scores of infeasible designs break its assumption of
//! smooth ranking. We keep the implementation faithful rather than
//! patching it, so Table 3 reproduces for the *right reason*.

use super::{BestTracker, OptResult, Optimizer, Problem, SearchBudget};
use crate::space::Design;
use crate::util::rng::Rng;
use std::time::Instant;

pub struct CmaEs {
    pub budget: SearchBudget,
    pub sigma0: f64,
}

impl CmaEs {
    pub fn new(budget: SearchBudget) -> CmaEs {
        CmaEs {
            budget,
            sigma0: 1.5,
        }
    }
}

/// Symmetric matrix–vector multiply.
fn matvec(m: &[Vec<f64>], v: &[f64]) -> Vec<f64> {
    m.iter().map(|row| row.iter().zip(v).map(|(a, b)| a * b).sum()).collect()
}

/// Cholesky factorization (lower triangular); falls back to a diagonal
/// jitter when the matrix loses positive definiteness.
fn cholesky(a: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let n = a.len();
    let mut l = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i][j];
            for k in 0..j {
                sum -= l[i][k] * l[j][k];
            }
            if i == j {
                l[i][j] = sum.max(1e-10).sqrt();
            } else {
                l[i][j] = sum / l[j][j];
            }
        }
    }
    l
}

impl Optimizer for CmaEs {
    fn name(&self) -> String {
        "CMA-ES".into()
    }

    fn run(&self, problem: &dyn Problem, rng: &mut Rng) -> OptResult {
        let t0 = Instant::now();
        let space = problem.space();
        let n = space.params.len();
        let lambda = self.budget.pop.max(4);
        let mu = lambda / 2;
        // log-linear recombination weights
        let mut w: Vec<f64> = (0..mu)
            .map(|i| ((mu as f64 + 0.5).ln() - ((i + 1) as f64).ln()).max(0.0))
            .collect();
        let wsum: f64 = w.iter().sum();
        for wi in &mut w {
            *wi /= wsum;
        }
        let mu_eff = 1.0 / w.iter().map(|x| x * x).sum::<f64>();
        let cc = 4.0 / (n as f64 + 4.0);
        let cs = (mu_eff + 2.0) / (n as f64 + mu_eff + 5.0);
        let c1 = 2.0 / ((n as f64 + 1.3).powi(2) + mu_eff);
        let cmu = (1.0 - c1)
            .min(2.0 * (mu_eff - 2.0 + 1.0 / mu_eff) / ((n as f64 + 2.0).powi(2) + mu_eff));
        let damps = 1.0 + cs + 2.0 * ((mu_eff - 1.0) / (n as f64 + 1.0)).sqrt().max(0.0);
        let chi_n = (n as f64).sqrt() * (1.0 - 1.0 / (4.0 * n as f64));

        // state
        let seed = problem.random_candidate(rng);
        let mut mean: Vec<f64> = seed.0.iter().map(|&v| v as f64).collect();
        let mut sigma = self.sigma0;
        let mut cov: Vec<Vec<f64>> = (0..n)
            .map(|i| (0..n).map(|j| if i == j { 1.0 } else { 0.0 }).collect())
            .collect();
        let mut ps = vec![0.0; n];
        let mut pc = vec![0.0; n];

        let mut tracker = BestTracker::default();
        let mut evals = 0usize;
        let gens = self.budget.gens;

        for gen in 0..gens {
            let bd = cholesky(&cov);
            // sample λ offspring: x = mean + σ·B·z
            let mut zs: Vec<Vec<f64>> = Vec::with_capacity(lambda);
            let mut xs: Vec<Vec<f64>> = Vec::with_capacity(lambda);
            for _ in 0..lambda {
                let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let bz = matvec(&bd, &z);
                let x: Vec<f64> = mean
                    .iter()
                    .zip(&bz)
                    .enumerate()
                    .map(|(i, (&m, &d))| {
                        let hi = space.params[i].cardinality() as f64 - 1.0;
                        (m + sigma * d).clamp(0.0, hi)
                    })
                    .collect();
                zs.push(z);
                xs.push(x);
            }
            let designs: Vec<Design> = xs.iter().map(|x| space.clamp_round(x)).collect();
            let scores = problem.score_batch(&designs);
            evals += lambda;
            tracker.observe(&designs, &scores);
            tracker.end_generation();

            // rank by score
            let mut order: Vec<usize> = (0..lambda).collect();
            order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());

            // recombine mean
            let old_mean = mean.clone();
            for i in 0..n {
                mean[i] = (0..mu).map(|r| w[r] * xs[order[r]][i]).sum();
            }

            // evolution paths
            let y: Vec<f64> = (0..n)
                .map(|i| (mean[i] - old_mean[i]) / sigma.max(1e-12))
                .collect();
            for i in 0..n {
                ps[i] = (1.0 - cs) * ps[i] + (cs * (2.0 - cs) * mu_eff).sqrt() * y[i];
            }
            let ps_norm: f64 = ps.iter().map(|x| x * x).sum::<f64>().sqrt();
            let hsig = ps_norm
                / (1.0 - (1.0 - cs).powi(2 * (gen as i32 + 1))).sqrt()
                / chi_n
                < 1.4 + 2.0 / (n as f64 + 1.0);
            for i in 0..n {
                pc[i] = (1.0 - cc) * pc[i]
                    + if hsig {
                        (cc * (2.0 - cc) * mu_eff).sqrt() * y[i]
                    } else {
                        0.0
                    };
            }

            // covariance update (rank-1 + rank-μ)
            for i in 0..n {
                for j in 0..n {
                    let rank_mu: f64 = (0..mu)
                        .map(|r| {
                            let xi = (xs[order[r]][i] - old_mean[i]) / sigma.max(1e-12);
                            let xj = (xs[order[r]][j] - old_mean[j]) / sigma.max(1e-12);
                            w[r] * xi * xj
                        })
                        .sum();
                    cov[i][j] = (1.0 - c1 - cmu) * cov[i][j]
                        + c1 * pc[i] * pc[j]
                        + cmu * rank_mu;
                }
            }

            // step-size control
            sigma *= ((cs / damps) * (ps_norm / chi_n - 1.0)).exp();
            sigma = sigma.clamp(1e-4, 8.0);
        }
        tracker.into_result(self.name(), evals, t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::testutil::Sphere;
    use crate::space::SearchSpace;

    #[test]
    fn runs_and_returns_finite_on_sphere() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let cma = CmaEs::new(SearchBudget { pop: 16, gens: 15 });
        let r = cma.run(&p, &mut Rng::seed_from(2));
        assert!(r.best_score.is_finite());
        assert_eq!(r.history.len(), 15);
    }

    #[test]
    fn cholesky_of_identity() {
        let eye = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let l = cholesky(&eye);
        assert!((l[0][0] - 1.0).abs() < 1e-12);
        assert!((l[1][1] - 1.0).abs() < 1e-12);
        assert_eq!(l[0][1], 0.0);
    }

    #[test]
    fn cholesky_recovers_spd_factor() {
        // A = L Lᵀ with L = [[2,0],[1,1]] -> A = [[4,2],[2,2]]
        let a = vec![vec![4.0, 2.0], vec![2.0, 2.0]];
        let l = cholesky(&a);
        assert!((l[0][0] - 2.0).abs() < 1e-9);
        assert!((l[1][0] - 1.0).abs() < 1e-9);
        assert!((l[1][1] - 1.0).abs() < 1e-9);
    }
}
