//! Surrogate hardware-metric predictor (paper §V-D: "hardware metric
//! prediction models could be incorporated by training dedicated
//! predictors in place of explicit hardware estimation for each sampled
//! design").
//!
//! A ridge regression on log-score over engineered design features
//! (log-transformed geometry, voltage, interactions). It is deliberately
//! *not* used to replace evaluation inside the GA — the paper warns that
//! hardware-metric prediction "requires substantially higher accuracy" —
//! but to **prescreen** candidates so only the promising fraction reaches
//! the exact evaluator:
//!
//! * [`surrogate_init`] prescreens the diversity-sampled initial pool
//!   (evaluate a subset, fit, rank the remainder, evaluate the top half);
//! * [`ScreenState`] is the multi-fidelity hot-loop variant
//!   (`--screen-frac`): the GA/NSGA-II generation loops variate a
//!   `1/frac`-times larger offspring pool, the online-fitted model ranks
//!   it, only the top λ candidates are evaluated exactly, and the rejects
//!   are recycled into the next variation round — see `docs/search.md`.
//!
//! The `surrogate` registry experiment quantifies the equal-wall-clock
//! quality trade-off; `imcopt run ablations` covers the init-time variant.

use super::{sampling, Problem};
use crate::space::{idx, Design, SearchSpace};
use crate::util::rng::Rng;
use std::collections::HashSet;

/// Number of engineered features (excluding the bias).
pub const N_FEATURES: usize = 14;

/// Featurize a decoded design for the ridge model: log geometry terms
/// capture the multiplicative structure of the analytical cost model.
pub fn features(raw: &[f64; 10]) -> [f64; N_FEATURES] {
    let rows = raw[idx::ROWS];
    let cols = raw[idx::COLS];
    let m = raw[idx::C_PER_TILE];
    let t = raw[idx::T_PER_ROUTER];
    let g = raw[idx::G_PER_CHIP];
    let bits = raw[idx::BITS_CELL].max(1.0);
    let v = raw[idx::V_STEP];
    let tc = raw[idx::T_CYCLE_NS];
    let glb = raw[idx::GLB_KB];
    let tech = raw[idx::TECH_NM];
    let macros = m * t * g;
    [
        rows.ln(),
        cols.ln(),
        macros.ln(),
        g.ln(),
        bits.ln(),
        v.ln(),
        tc.ln(),
        glb.ln(),
        tech.ln(),
        (rows * cols).ln(),          // array size
        (macros * rows * cols).ln(), // total device count
        v * v,                       // dynamic-energy scale
        (cols / 4.0).ln(),           // ADC sweep length
        macros.ln() * tc.ln(),       // parallelism x clock interaction
    ]
}

/// Ridge regression model over [`features`] + bias.
#[derive(Clone, Debug)]
pub struct RidgeModel {
    /// Weights, last entry is the bias.
    pub w: Vec<f64>,
    /// L2 regularization strength.
    pub lambda: f64,
}

impl RidgeModel {
    /// Fit on (features, log-score) pairs via the normal equations
    /// (the design dimension is tiny, Gaussian elimination suffices).
    pub fn fit(xs: &[[f64; N_FEATURES]], ys: &[f64], lambda: f64) -> Option<RidgeModel> {
        let n = xs.len();
        if n < N_FEATURES + 1 {
            return None;
        }
        let d = N_FEATURES + 1; // + bias
        // A = XᵀX + λI, b = Xᵀy
        let mut a = vec![vec![0.0f64; d]; d];
        let mut b = vec![0.0f64; d];
        for (x, &y) in xs.iter().zip(ys) {
            let mut row = [0.0f64; N_FEATURES + 1];
            row[..N_FEATURES].copy_from_slice(x);
            row[N_FEATURES] = 1.0;
            for i in 0..d {
                b[i] += row[i] * y;
                for j in 0..d {
                    a[i][j] += row[i] * row[j];
                }
            }
        }
        for (i, row) in a.iter_mut().enumerate() {
            row[i] += lambda * n as f64;
        }
        let w = solve(a, b)?;
        Some(RidgeModel { w, lambda })
    }

    /// Predicted log-score.
    pub fn predict(&self, x: &[f64; N_FEATURES]) -> f64 {
        let mut acc = self.w[N_FEATURES];
        for i in 0..N_FEATURES {
            acc += self.w[i] * x[i];
        }
        acc
    }

    /// Coefficient of determination on a held-out set.
    pub fn r2(&self, xs: &[[f64; N_FEATURES]], ys: &[f64]) -> f64 {
        let mean = crate::util::stats::mean(ys);
        let ss_tot: f64 = ys.iter().map(|y| (y - mean) * (y - mean)).sum();
        let ss_res: f64 = xs
            .iter()
            .zip(ys)
            .map(|(x, &y)| {
                let e = y - self.predict(x);
                e * e
            })
            .sum();
        if ss_tot <= 0.0 {
            return 0.0;
        }
        1.0 - ss_res / ss_tot
    }
}

/// Gaussian elimination with partial pivoting.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let piv = (col..n).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in (col + 1)..n {
            let f = a[row][col] / a[col][col];
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for k in (col + 1)..n {
            acc -= a[col][k] * x[k];
        }
        x[col] = acc / a[col][col];
    }
    Some(x)
}

/// Surrogate-assisted initial sampling: like
/// [`sampling::hamming_init`] but only `train_n` of the `p_e` diverse
/// candidates are evaluated; a ridge model ranks the rest and the top
/// predicted fraction is evaluated to fill the population. Returns the
/// initial population and the number of true evaluations spent.
pub fn surrogate_init(
    problem: &dyn Problem,
    p_h: usize,
    p_e: usize,
    p_ga: usize,
    train_n: usize,
    rng: &mut Rng,
) -> (Vec<Design>, usize) {
    let pool = sampling::random_pool(problem, p_h, rng);
    let diverse = sampling::select_diverse(&pool, p_e);
    let train_n = train_n.clamp(N_FEATURES + 2, diverse.len());

    // evaluate a training subset
    let train = &diverse[..train_n];
    let train_scores = problem.score_batch(train);
    let mut evals = train_n;

    let space = problem.space();
    let finite: Vec<(usize, f64)> = train_scores
        .iter()
        .enumerate()
        .filter(|(_, s)| s.is_finite())
        .map(|(i, s)| (i, *s))
        .collect();
    let xs: Vec<[f64; N_FEATURES]> = finite
        .iter()
        .map(|&(i, _)| features(&space.decode(&train[i])))
        .collect();
    let ys: Vec<f64> = finite.iter().map(|&(_, s)| s.ln()).collect();

    let rest = &diverse[train_n..];
    let shortlisted: Vec<Design> = match RidgeModel::fit(&xs, &ys, 1e-3) {
        Some(model) => {
            // rank the unevaluated remainder by predicted score
            let mut ranked: Vec<(usize, f64)> = rest
                .iter()
                .enumerate()
                .map(|(i, d)| (i, model.predict(&features(&space.decode(d)))))
                .collect();
            ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            // evaluate only the most promising half of the remainder
            ranked
                .iter()
                .take(rest.len() / 2)
                .map(|&(i, _)| rest[i].clone())
                .collect()
        }
        None => rest.to_vec(), // degenerate training set: evaluate all
    };
    let short_scores = problem.score_batch(&shortlisted);
    evals += shortlisted.len();

    // final population: best of everything actually evaluated
    let mut scored: Vec<(Design, f64)> = train
        .iter()
        .cloned()
        .zip(train_scores)
        .chain(shortlisted.into_iter().zip(short_scores))
        .collect();
    scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut init: Vec<Design> = scored.into_iter().take(p_ga).map(|(d, _)| d).collect();
    while init.len() < p_ga {
        init.push(problem.random_candidate(rng));
    }
    (init, evals)
}

/// Ridge regularization used by the online hot-loop model (matches the
/// init-time prescreen in [`surrogate_init`]).
const SCREEN_LAMBDA: f64 = 1e-3;

/// Online surrogate screening state for the GA/NSGA-II generation loops
/// (`--screen-frac`, ROADMAP direction 4).
///
/// Every exact evaluation the loop performs is [`ScreenState::observe`]d
/// in population order; at offspring time the loop variates a pool of
/// [`ScreenState::pool_target`] candidates (recycled rejects first, then
/// fresh variation) and [`ScreenState::select`] keeps the λ with the best
/// predicted log-score for exact evaluation, carrying the rejects into
/// the next round. The exact evaluator is still called on exactly λ
/// candidates per generation, so a screened run costs the same wall-clock
/// as the exact loop (plus the fit/rank overhead pinned by
/// `BENCH_surrogate.json`) — the win is a `1/frac`-times larger candidate
/// pool per generation.
///
/// Determinism: training pairs accumulate in evaluation order (which is
/// thread-count-independent — `score_batch` is bit-identical at any
/// `--threads`), duplicates are dropped by design identity preserving
/// first-seen order, and ranking ties break by pool index via
/// `total_cmp`, so a screened run is a pure function of
/// (problem, config, seed).
#[derive(Clone, Debug)]
pub struct ScreenState {
    frac: f64,
    xs: Vec<[f64; N_FEATURES]>,
    ys: Vec<f64>,
    seen: HashSet<Design>,
    carry: Vec<Design>,
}

impl ScreenState {
    /// Screening state for an evaluated fraction `frac` ∈ (0, 1), or
    /// `None` when `frac >= 1.0` (or is not finite) — the caller must
    /// then run the exact, unscreened loop so default runs stay
    /// bit-identical.
    pub fn new(frac: f64) -> Option<ScreenState> {
        if !frac.is_finite() || frac >= 1.0 {
            return None;
        }
        Some(ScreenState {
            frac: frac.max(0.05),
            xs: Vec::new(),
            ys: Vec::new(),
            seen: HashSet::new(),
            carry: Vec::new(),
        })
    }

    /// Record exact scalar scores (one observation per first-seen design;
    /// non-finite / non-positive scores are skipped — the model predicts
    /// log-score).
    pub fn observe(&mut self, space: &SearchSpace, designs: &[Design], scores: &[f64]) {
        for (d, &s) in designs.iter().zip(scores) {
            if !s.is_finite() || s <= 0.0 {
                continue;
            }
            if self.seen.insert(d.clone()) {
                self.xs.push(features(&space.decode(d)));
                self.ys.push(s.ln());
            }
        }
    }

    /// Record exact objective *vectors* (the NSGA-II loop): the training
    /// target is the mean of the per-axis logs — the log geometric mean,
    /// a scalar proxy that ranks "generally strong" vectors first.
    /// Vectors with any non-finite or non-positive axis are skipped.
    pub fn observe_vec(&mut self, space: &SearchSpace, designs: &[Design], objs: &[Vec<f64>]) {
        for (d, o) in designs.iter().zip(objs) {
            if o.is_empty() || o.iter().any(|x| !x.is_finite() || *x <= 0.0) {
                continue;
            }
            if self.seen.insert(d.clone()) {
                self.xs.push(features(&space.decode(d)));
                self.ys.push(o.iter().map(|x| x.ln()).sum::<f64>() / o.len() as f64);
            }
        }
    }

    /// Offspring-pool size for `lambda` evaluation slots:
    /// `ceil(lambda / frac)`, never below `lambda`.
    pub fn pool_target(&self, lambda: usize) -> usize {
        ((lambda as f64 / self.frac).ceil() as usize).max(lambda)
    }

    /// Rejects carried from the previous [`ScreenState::select`] — seed
    /// the next offspring pool with these before fresh variation.
    pub fn take_carry(&mut self) -> Vec<Design> {
        std::mem::take(&mut self.carry)
    }

    /// Keep the `keep` pool members with the best (lowest) predicted
    /// log-score for exact evaluation; the rest become the next round's
    /// carry. Until the model has enough training data to fit, the first
    /// `keep` pool members pass through unranked (plain truncation keeps
    /// the cold start deterministic).
    pub fn select(&mut self, space: &SearchSpace, pool: Vec<Design>, keep: usize) -> Vec<Design> {
        if pool.len() <= keep {
            self.carry.clear();
            crate::telemetry::screen_selected(pool.len(), 0);
            return pool;
        }
        crate::telemetry::screen_selected(keep, pool.len() - keep);
        let mut chosen = vec![false; pool.len()];
        let fitted = {
            let _span = crate::telemetry::span(crate::telemetry::Stage::SurrogateFit);
            RidgeModel::fit(&self.xs, &self.ys, SCREEN_LAMBDA)
        };
        match fitted {
            Some(model) => {
                let _span = crate::telemetry::span(crate::telemetry::Stage::SurrogateRank);
                let mut ranked: Vec<(f64, usize)> = pool
                    .iter()
                    .enumerate()
                    .map(|(i, d)| (model.predict(&features(&space.decode(d))), i))
                    .collect();
                ranked.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                for &(_, i) in ranked.iter().take(keep) {
                    chosen[i] = true;
                }
            }
            None => {
                for c in chosen.iter_mut().take(keep) {
                    *c = true;
                }
            }
        }
        let mut selected = Vec::with_capacity(keep);
        let mut rejected = Vec::with_capacity(pool.len() - keep);
        for (i, d) in pool.into_iter().enumerate() {
            if chosen[i] {
                selected.push(d);
            } else {
                rejected.push(d);
            }
        }
        self.carry = rejected;
        selected
    }

    /// Training observations accumulated so far (distinct designs).
    pub fn observations(&self) -> usize {
        self.xs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EvalBackend, JointProblem};
    use crate::model::MemoryTech;
    use crate::objective::Objective;
    use crate::space::SearchSpace;
    use crate::workloads::WorkloadSet;

    #[test]
    fn solve_linear_system() {
        let a = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        let b = vec![5.0, 10.0];
        let x = solve(a, b).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-9);
        assert!((x[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ridge_recovers_linear_target() {
        let mut rng = Rng::seed_from(1);
        let space = SearchSpace::rram();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..200 {
            let d = space.random(&mut rng);
            let f = features(&space.decode(&d));
            xs.push(f);
            // synthetic linear target over the features
            ys.push(2.0 * f[0] - 0.5 * f[6] + 3.0);
        }
        let m = RidgeModel::fit(&xs, &ys, 1e-6).unwrap();
        assert!(m.r2(&xs, &ys) > 0.999, "r2={}", m.r2(&xs, &ys));
    }

    #[test]
    fn surrogate_predicts_real_scores_reasonably() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        );
        let mut rng = Rng::seed_from(2);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        // train on feasibility-prefiltered candidates
        while ys.len() < 300 {
            let d = p.random_candidate(&mut rng);
            let s = crate::search::Problem::score_batch(&p, std::slice::from_ref(&d))[0];
            if s.is_finite() {
                xs.push(features(&space.decode(&d)));
                ys.push(s.ln());
            }
        }
        let (train_x, test_x) = xs.split_at(200);
        let (train_y, test_y) = ys.split_at(200);
        let m = RidgeModel::fit(train_x, train_y, 1e-3).unwrap();
        let r2 = m.r2(test_x, test_y);
        assert!(
            r2 > 0.5,
            "surrogate should explain most of the log-EDAP variance, r2={r2}"
        );
    }

    #[test]
    fn surrogate_init_spends_fewer_evals_than_full_sampling() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        );
        let mut rng = Rng::seed_from(3);
        let (init, evals) = surrogate_init(&p, 300, 150, 20, 50, &mut rng);
        assert_eq!(init.len(), 20);
        // 50 train + 50 shortlisted = 100 < 150 full sampling
        assert!(evals < 150, "evals={evals}");
        // the population should contain feasible designs
        let scores = crate::search::Problem::score_batch(&p, &init);
        assert!(scores.iter().any(|s| s.is_finite()));
    }

    #[test]
    fn screen_state_is_off_at_frac_one() {
        assert!(ScreenState::new(1.0).is_none());
        assert!(ScreenState::new(2.0).is_none());
        assert!(ScreenState::new(f64::NAN).is_none());
        assert!(ScreenState::new(0.5).is_some());
    }

    #[test]
    fn screen_pool_target_rounds_up_and_floors_at_lambda() {
        let s = ScreenState::new(0.25).unwrap();
        assert_eq!(s.pool_target(40), 160);
        assert_eq!(s.pool_target(10), 40);
        assert_eq!(s.pool_target(0), 0);
        let s = ScreenState::new(0.3).unwrap();
        assert_eq!(s.pool_target(10), 34); // ceil(10 / 0.3)
        // the constructor clamps absurdly small fractions
        let s = ScreenState::new(1e-9).unwrap();
        assert_eq!(s.pool_target(10), 200); // frac clamped to 0.05
    }

    #[test]
    fn screen_cold_start_truncates_and_carries_rejects() {
        let space = SearchSpace::rram();
        let mut rng = Rng::seed_from(11);
        let mut s = ScreenState::new(0.5).unwrap();
        let pool: Vec<Design> = (0..8).map(|_| space.random(&mut rng)).collect();
        let selected = s.select(&space, pool.clone(), 4);
        // no training data yet: plain truncation, order preserved
        assert_eq!(selected, pool[..4].to_vec());
        assert_eq!(s.take_carry(), pool[4..].to_vec());
        assert!(s.take_carry().is_empty(), "carry is consumed once");
        // a pool no larger than keep passes through whole
        let small: Vec<Design> = pool[..3].to_vec();
        assert_eq!(s.select(&space, small.clone(), 4), small);
        assert!(s.take_carry().is_empty());
    }

    #[test]
    fn screen_observe_dedups_and_skips_non_finite() {
        let space = SearchSpace::rram();
        let mut rng = Rng::seed_from(12);
        let mut s = ScreenState::new(0.5).unwrap();
        let d: Vec<Design> = (0..3).map(|_| space.random(&mut rng)).collect();
        s.observe(&space, &d, &[2.0, f64::INFINITY, 3.0]);
        assert_eq!(s.observations(), 2, "non-finite score skipped");
        s.observe(&space, &d, &[2.0, 4.0, 3.0]);
        assert_eq!(s.observations(), 3, "duplicates ignored, new finite added");
        s.observe_vec(&space, &d[..1], &[vec![1.0, 2.0]]);
        assert_eq!(s.observations(), 3, "observe_vec dedups against observe");
    }

    #[test]
    fn screen_select_ranks_with_fitted_model_deterministically() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        );
        let mut rng = Rng::seed_from(13);
        let mut s = ScreenState::new(0.25).unwrap();
        // train past the fit threshold on real scores
        let train: Vec<Design> = (0..80).map(|_| p.random_candidate(&mut rng)).collect();
        let scores = crate::search::Problem::score_batch(&p, &train);
        s.observe(&space, &train, &scores);
        assert!(s.observations() > N_FEATURES + 1);

        let pool: Vec<Design> = (0..40).map(|_| p.random_candidate(&mut rng)).collect();
        let a = s.clone().select(&space, pool.clone(), 10);
        let b = s.clone().select(&space, pool.clone(), 10);
        assert_eq!(a, b, "ranking must be deterministic");
        assert_eq!(a.len(), 10);
        // selection + carry partition the pool, preserving pool order
        let mut sc = s.clone();
        let sel = sc.select(&space, pool.clone(), 10);
        let carry = sc.take_carry();
        assert_eq!(carry.len(), 30);
        let (mut i, mut j) = (0, 0);
        for d in &pool {
            if i < sel.len() && &sel[i] == d {
                i += 1;
            } else {
                assert_eq!(&carry[j], d, "partition must preserve pool order");
                j += 1;
            }
        }
        assert_eq!((i, j), (sel.len(), carry.len()));
        // and the model genuinely reorders: selection is generally not the
        // plain prefix once fitted (sanity, not a strict guarantee — the
        // seeded pool makes this stable)
        assert_ne!(sel, pool[..10].to_vec(), "fitted model should rank, not truncate");
    }
}
