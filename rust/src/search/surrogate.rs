//! Surrogate hardware-metric predictor (paper §V-D: "hardware metric
//! prediction models could be incorporated by training dedicated
//! predictors in place of explicit hardware estimation for each sampled
//! design").
//!
//! A ridge regression on log-score over engineered design features
//! (log-transformed geometry, voltage, interactions). It is deliberately
//! *not* used to replace evaluation inside the GA — the paper warns that
//! hardware-metric prediction "requires substantially higher accuracy" —
//! but to **prescreen** the diversity-sampled pool: evaluate a subset,
//! fit, rank the remainder by prediction, and spend the remaining
//! evaluation budget on the most promising candidates. The ablation
//! experiment (`imcopt run ablations`) quantifies the evals-vs-quality
//! trade-off.

use super::{sampling, Problem};
use crate::space::{idx, Design};
use crate::util::rng::Rng;

/// Number of engineered features (excluding the bias).
pub const N_FEATURES: usize = 14;

/// Featurize a decoded design for the ridge model: log geometry terms
/// capture the multiplicative structure of the analytical cost model.
pub fn features(raw: &[f64; 10]) -> [f64; N_FEATURES] {
    let rows = raw[idx::ROWS];
    let cols = raw[idx::COLS];
    let m = raw[idx::C_PER_TILE];
    let t = raw[idx::T_PER_ROUTER];
    let g = raw[idx::G_PER_CHIP];
    let bits = raw[idx::BITS_CELL].max(1.0);
    let v = raw[idx::V_STEP];
    let tc = raw[idx::T_CYCLE_NS];
    let glb = raw[idx::GLB_KB];
    let tech = raw[idx::TECH_NM];
    let macros = m * t * g;
    [
        rows.ln(),
        cols.ln(),
        macros.ln(),
        g.ln(),
        bits.ln(),
        v.ln(),
        tc.ln(),
        glb.ln(),
        tech.ln(),
        (rows * cols).ln(),          // array size
        (macros * rows * cols).ln(), // total device count
        v * v,                       // dynamic-energy scale
        (cols / 4.0).ln(),           // ADC sweep length
        macros.ln() * tc.ln(),       // parallelism x clock interaction
    ]
}

/// Ridge regression model over [`features`] + bias.
#[derive(Clone, Debug)]
pub struct RidgeModel {
    /// Weights, last entry is the bias.
    pub w: Vec<f64>,
    /// L2 regularization strength.
    pub lambda: f64,
}

impl RidgeModel {
    /// Fit on (features, log-score) pairs via the normal equations
    /// (the design dimension is tiny, Gaussian elimination suffices).
    pub fn fit(xs: &[[f64; N_FEATURES]], ys: &[f64], lambda: f64) -> Option<RidgeModel> {
        let n = xs.len();
        if n < N_FEATURES + 1 {
            return None;
        }
        let d = N_FEATURES + 1; // + bias
        // A = XᵀX + λI, b = Xᵀy
        let mut a = vec![vec![0.0f64; d]; d];
        let mut b = vec![0.0f64; d];
        for (x, &y) in xs.iter().zip(ys) {
            let mut row = [0.0f64; N_FEATURES + 1];
            row[..N_FEATURES].copy_from_slice(x);
            row[N_FEATURES] = 1.0;
            for i in 0..d {
                b[i] += row[i] * y;
                for j in 0..d {
                    a[i][j] += row[i] * row[j];
                }
            }
        }
        for (i, row) in a.iter_mut().enumerate() {
            row[i] += lambda * n as f64;
        }
        let w = solve(a, b)?;
        Some(RidgeModel { w, lambda })
    }

    /// Predicted log-score.
    pub fn predict(&self, x: &[f64; N_FEATURES]) -> f64 {
        let mut acc = self.w[N_FEATURES];
        for i in 0..N_FEATURES {
            acc += self.w[i] * x[i];
        }
        acc
    }

    /// Coefficient of determination on a held-out set.
    pub fn r2(&self, xs: &[[f64; N_FEATURES]], ys: &[f64]) -> f64 {
        let mean = crate::util::stats::mean(ys);
        let ss_tot: f64 = ys.iter().map(|y| (y - mean) * (y - mean)).sum();
        let ss_res: f64 = xs
            .iter()
            .zip(ys)
            .map(|(x, &y)| {
                let e = y - self.predict(x);
                e * e
            })
            .sum();
        if ss_tot <= 0.0 {
            return 0.0;
        }
        1.0 - ss_res / ss_tot
    }
}

/// Gaussian elimination with partial pivoting.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let piv = (col..n).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in (col + 1)..n {
            let f = a[row][col] / a[col][col];
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for k in (col + 1)..n {
            acc -= a[col][k] * x[k];
        }
        x[col] = acc / a[col][col];
    }
    Some(x)
}

/// Surrogate-assisted initial sampling: like
/// [`sampling::hamming_init`] but only `train_n` of the `p_e` diverse
/// candidates are evaluated; a ridge model ranks the rest and the top
/// predicted fraction is evaluated to fill the population. Returns the
/// initial population and the number of true evaluations spent.
pub fn surrogate_init(
    problem: &dyn Problem,
    p_h: usize,
    p_e: usize,
    p_ga: usize,
    train_n: usize,
    rng: &mut Rng,
) -> (Vec<Design>, usize) {
    let pool = sampling::random_pool(problem, p_h, rng);
    let diverse = sampling::select_diverse(&pool, p_e);
    let train_n = train_n.clamp(N_FEATURES + 2, diverse.len());

    // evaluate a training subset
    let train = &diverse[..train_n];
    let train_scores = problem.score_batch(train);
    let mut evals = train_n;

    let space = problem.space();
    let finite: Vec<(usize, f64)> = train_scores
        .iter()
        .enumerate()
        .filter(|(_, s)| s.is_finite())
        .map(|(i, s)| (i, *s))
        .collect();
    let xs: Vec<[f64; N_FEATURES]> = finite
        .iter()
        .map(|&(i, _)| features(&space.decode(&train[i])))
        .collect();
    let ys: Vec<f64> = finite.iter().map(|&(_, s)| s.ln()).collect();

    let rest = &diverse[train_n..];
    let shortlisted: Vec<Design> = match RidgeModel::fit(&xs, &ys, 1e-3) {
        Some(model) => {
            // rank the unevaluated remainder by predicted score
            let mut ranked: Vec<(usize, f64)> = rest
                .iter()
                .enumerate()
                .map(|(i, d)| (i, model.predict(&features(&space.decode(d)))))
                .collect();
            ranked.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            // evaluate only the most promising half of the remainder
            ranked
                .iter()
                .take(rest.len() / 2)
                .map(|&(i, _)| rest[i].clone())
                .collect()
        }
        None => rest.to_vec(), // degenerate training set: evaluate all
    };
    let short_scores = problem.score_batch(&shortlisted);
    evals += shortlisted.len();

    // final population: best of everything actually evaluated
    let mut scored: Vec<(Design, f64)> = train
        .iter()
        .cloned()
        .zip(train_scores)
        .chain(shortlisted.into_iter().zip(short_scores))
        .collect();
    scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    let mut init: Vec<Design> = scored.into_iter().take(p_ga).map(|(d, _)| d).collect();
    while init.len() < p_ga {
        init.push(problem.random_candidate(rng));
    }
    (init, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{EvalBackend, JointProblem};
    use crate::model::MemoryTech;
    use crate::objective::Objective;
    use crate::space::SearchSpace;
    use crate::workloads::WorkloadSet;

    #[test]
    fn solve_linear_system() {
        let a = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        let b = vec![5.0, 10.0];
        let x = solve(a, b).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-9);
        assert!((x[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ridge_recovers_linear_target() {
        let mut rng = Rng::seed_from(1);
        let space = SearchSpace::rram();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..200 {
            let d = space.random(&mut rng);
            let f = features(&space.decode(&d));
            xs.push(f);
            // synthetic linear target over the features
            ys.push(2.0 * f[0] - 0.5 * f[6] + 3.0);
        }
        let m = RidgeModel::fit(&xs, &ys, 1e-6).unwrap();
        assert!(m.r2(&xs, &ys) > 0.999, "r2={}", m.r2(&xs, &ys));
    }

    #[test]
    fn surrogate_predicts_real_scores_reasonably() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        );
        let mut rng = Rng::seed_from(2);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        // train on feasibility-prefiltered candidates
        while ys.len() < 300 {
            let d = p.random_candidate(&mut rng);
            let s = crate::search::Problem::score_batch(&p, std::slice::from_ref(&d))[0];
            if s.is_finite() {
                xs.push(features(&space.decode(&d)));
                ys.push(s.ln());
            }
        }
        let (train_x, test_x) = xs.split_at(200);
        let (train_y, test_y) = ys.split_at(200);
        let m = RidgeModel::fit(train_x, train_y, 1e-3).unwrap();
        let r2 = m.r2(test_x, test_y);
        assert!(
            r2 > 0.5,
            "surrogate should explain most of the log-EDAP variance, r2={r2}"
        );
    }

    #[test]
    fn surrogate_init_spends_fewer_evals_than_full_sampling() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::edap(),
        );
        let mut rng = Rng::seed_from(3);
        let (init, evals) = surrogate_init(&p, 300, 150, 20, 50, &mut rng);
        assert_eq!(init.len(), 20);
        // 50 train + 50 shortlisted = 100 < 150 full sampling
        assert!(evals < 150, "evals={evals}");
        // the population should contain feasible designs
        let scores = crate::search::Problem::score_batch(&p, &init);
        assert!(scores.iter().any(|s| s.is_finite()));
    }
}
