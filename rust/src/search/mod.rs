//! Optimization algorithms (paper §III-C).
//!
//! [`Problem`] abstracts "score a batch of designs jointly across the
//! workload set" (implemented by `coordinator::JointProblem`, which routes
//! evaluation through the PJRT artifact or the native evaluator, with
//! memoization). [`Optimizer`] is implemented by:
//!
//! * [`GeneticAlgorithm`] — generic phased GA engine with SBX crossover +
//!   polynomial mutation; covers the paper's *non-modified GA* baseline
//!   \[44\], the *non-modified GA + enhanced sampling* baseline, and the
//!   proposed **four-phase GA** ([`FourPhaseGa`], Table 4) with
//!   Hamming-distance diversity sampling ([`sampling`]).
//! * Table 3 baselines: [`pso::Pso`], [`es::EvolutionStrategy`] (ES and
//!   stochastic-ranking ERES), [`cmaes::CmaEs`], [`g3pcx::G3Pcx`], and
//!   [`exhaustive::Exhaustive`] ground truth.
//!
//! With `--screen-frac < 1.0` the GA (and `pareto::nsga2`) generation
//! loops run **two-stage**: an online ridge surrogate
//! ([`surrogate::ScreenState`]) ranks a `1/frac`-times larger offspring
//! pool and only the predicted-best λ reach the exact evaluator, with
//! rejects recycled into the next variation round — see `docs/search.md`.

pub mod cmaes;
pub mod es;
pub mod exhaustive;
pub mod g3pcx;
pub mod ga;
pub mod pso;
pub mod sampling;
pub mod surrogate;

pub use cmaes::CmaEs;
pub use es::EvolutionStrategy;
pub use exhaustive::Exhaustive;
pub use g3pcx::G3Pcx;
pub use ga::{EarlyStop, FourPhaseGa, GaConfig, GeneticAlgorithm, InitStrategy, PhaseParams};
pub use pso::Pso;
pub use surrogate::ScreenState;

use crate::space::{Design, SearchSpace};
use crate::util::rng::Rng;
use std::time::Duration;

/// A joint hardware-workload optimization problem: lower score is better,
/// `+∞` marks infeasible designs.
pub trait Problem: Sync {
    fn space(&self) -> &SearchSpace;

    /// Joint scores for a batch of designs (order-preserving).
    fn score_batch(&self, designs: &[Design]) -> Vec<f64>;

    /// Sample a random *initial* candidate. Implementations may apply the
    /// paper's feasibility pre-filter (RRAM weight-stationary designs must
    /// hold the largest workload, Algorithm 1).
    fn random_candidate(&self, rng: &mut Rng) -> Design {
        self.space().random(rng)
    }

    /// Graded constraint violation for stochastic ranking (ERES) and the
    /// NSGA-II constraint-domination tournament: 0 for feasible designs,
    /// positive magnitude otherwise. The default cannot grade, so any
    /// non-finite score — `+∞` *and* `NaN` alike — reports a unit
    /// violation: a NaN score is neither finite nor gradable, so it is
    /// explicitly infeasible rather than silently feasible.
    fn violation(&self, design: &Design) -> f64 {
        let score = self.score_batch(std::slice::from_ref(design))[0];
        if score.is_finite() {
            0.0
        } else {
            // covers +inf (constraint breach) and NaN (unscorable) alike
            1.0
        }
    }

    /// Number of evaluator invocations so far (for runtime accounting).
    fn evals(&self) -> usize {
        0
    }
}

/// Search effort shared across algorithms so comparisons are budgeted
/// fairly ("equivalent population size and number of generations", §IV-E).
#[derive(Clone, Copy, Debug)]
pub struct SearchBudget {
    /// Population / swarm size per generation.
    pub pop: usize,
    /// Total generations (a 4-phase GA splits these across phases).
    pub gens: usize,
}

impl SearchBudget {
    /// The paper's default: `P_GA = 40`, `G = 10` per phase × 4 phases.
    pub fn paper() -> SearchBudget {
        SearchBudget { pop: 40, gens: 40 }
    }
}

/// Result of one optimization run.
#[derive(Clone, Debug)]
pub struct OptResult {
    pub algorithm: String,
    /// Best design found.
    pub best: Design,
    pub best_score: f64,
    /// Best-so-far score after each generation (convergence curve).
    pub history: Vec<f64>,
    /// Top-k (design, score) pairs, best first (Fig. 5 plots top-5).
    pub top: Vec<(Design, f64)>,
    /// Evaluator invocations consumed by this run.
    pub evals: usize,
    pub wall: Duration,
}

impl OptResult {
    /// Collect the best `k` distinct designs from a scored population.
    /// NaN-safe: `total_cmp` (as in `BestTracker`) orders NaNs last
    /// instead of panicking mid-run. Deduplication is global, not
    /// adjacent-only — duplicate designs with tied scores (e.g. several
    /// `+∞`-scored infeasibles) cannot reappear in the top-k.
    pub fn top_k(mut scored: Vec<(Design, f64)>, k: usize) -> Vec<(Design, f64)> {
        scored.sort_by(|a, b| a.1.total_cmp(&b.1));
        let mut seen = std::collections::HashSet::new();
        scored.retain(|(d, _)| seen.insert(d.clone()));
        scored.truncate(k);
        scored
    }

    /// Relative spread of the reported top-k: how much worse the k-th
    /// best design scores than the best (`worst/best − 1`, so `0.05` =
    /// the alternatives are within 5%). `0.0` when the top list has
    /// fewer than two entries or the best score is not a positive finite
    /// number. The portfolio experiments report it as a proxy for how
    /// interchangeable the near-optimal designs are.
    pub fn spread(&self) -> f64 {
        match (self.top.first(), self.top.last()) {
            (Some((_, best)), Some((_, worst)))
                if self.top.len() > 1 && *best > 0.0 && best.is_finite() =>
            {
                worst / best - 1.0
            }
            _ => 0.0,
        }
    }
}

/// A search algorithm.
pub trait Optimizer {
    fn name(&self) -> String;
    fn run(&self, problem: &dyn Problem, rng: &mut Rng) -> OptResult;
}

/// Default bounded capacity of [`BestTracker`]: large enough for top-5
/// reporting plus elite bookkeeping. Callers that report a deeper top-k
/// (e.g. `genmatrix` via `GaConfig::top_k`) construct the tracker with
/// [`BestTracker::with_cap`].
pub(crate) const TRACK_CAP: usize = 64;

/// Tracks the best-so-far set during a run; shared by all optimizers.
///
/// A bounded top-k structure over *distinct* designs with configurable
/// capacity. The worst live entry sits on top of a
/// max-[`std::collections::BinaryHeap`]
/// (score, then insertion order), so admission checks and evictions are
/// O(log k) instead of the previous sorted-vec linear scans; a `live` map
/// keyed by design deduplicates and marks superseded heap entries stale
/// (lazy deletion). Candidates that cannot enter the top-k are rejected
/// without cloning — the common case once a run warms up.
#[derive(Clone, Debug)]
pub(crate) struct BestTracker {
    cap: usize,
    /// Live (design → (score, insertion seq)); at most `cap` entries.
    live: std::collections::HashMap<Design, (f64, u64)>,
    /// Max-heap of (score, seq, design); an entry is live iff `live`
    /// still maps its design to the same seq.
    heap: std::collections::BinaryHeap<WorstEntry>,
    seq: u64,
    /// First-seen minimum, tracked separately so `best_score` is O(1).
    best: Option<(Design, f64)>,
    pub history: Vec<f64>,
}

/// Heap entry ordered worst-first: higher score is greater; among equal
/// scores the later insertion is greater, so evictions drop the
/// latest-seen duplicate score and ties keep first-seen order.
#[derive(Clone, Debug)]
struct WorstEntry {
    score: f64,
    seq: u64,
    design: Design,
}

impl PartialEq for WorstEntry {
    fn eq(&self, other: &Self) -> bool {
        self.score.to_bits() == other.score.to_bits() && self.seq == other.seq
    }
}
impl Eq for WorstEntry {}
impl PartialOrd for WorstEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for WorstEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.score
            .total_cmp(&other.score)
            .then(self.seq.cmp(&other.seq))
    }
}

impl Default for BestTracker {
    fn default() -> Self {
        BestTracker::with_cap(TRACK_CAP)
    }
}

impl BestTracker {
    /// A tracker holding at most `cap` distinct designs.
    pub fn with_cap(cap: usize) -> BestTracker {
        BestTracker {
            cap: cap.max(1),
            live: std::collections::HashMap::new(),
            heap: std::collections::BinaryHeap::new(),
            seq: 0,
            best: None,
            history: Vec::new(),
        }
    }

    pub fn observe(&mut self, designs: &[Design], scores: &[f64]) {
        for (d, &s) in designs.iter().zip(scores) {
            if s.is_finite() {
                self.insert(d, s);
            }
        }
    }

    /// Distinct designs currently tracked (test diagnostics).
    #[cfg(test)]
    pub fn len(&self) -> usize {
        self.live.len()
    }

    /// Drop stale heap entries (superseded by a better score for the same
    /// design) so `peek` is the worst *live* entry.
    fn prune_top(&mut self) {
        loop {
            // decide from `peek` in its own statement so the borrow ends
            // before the `pop`
            let stale = match self.heap.peek() {
                Some(top) => !matches!(
                    self.live.get(&top.design),
                    Some(&(_, seq)) if seq == top.seq
                ),
                None => return,
            };
            if !stale {
                return;
            }
            self.heap.pop();
        }
    }

    fn push_live(&mut self, d: &Design, s: f64) {
        self.seq += 1;
        self.live.insert(d.clone(), (s, self.seq));
        self.heap.push(WorstEntry {
            score: s,
            seq: self.seq,
            design: d.clone(),
        });
    }

    fn insert(&mut self, d: &Design, s: f64) {
        if let Some(&(old, _)) = self.live.get(d) {
            // scores are deterministic per design, so this re-observation
            // path normally rejects; tolerate a changed score by keeping
            // the better one (the old heap entry goes stale)
            if s >= old {
                return;
            }
            self.push_live(d, s);
        } else {
            if self.live.len() >= self.cap {
                self.prune_top();
                // cheap rejection: not better than the current worst
                // (equal scores keep the earlier-seen entry)
                let worst = self.heap.peek().map(|e| e.score).unwrap_or(f64::INFINITY);
                if s >= worst {
                    return;
                }
                if let Some(evicted) = self.heap.pop() {
                    self.live.remove(&evicted.design);
                }
            }
            self.push_live(d, s);
        }
        match &self.best {
            Some((_, bs)) if s >= *bs => {}
            _ => self.best = Some((d.clone(), s)),
        }
    }

    pub fn end_generation(&mut self) {
        self.history.push(self.best_score());
    }

    pub fn best_score(&self) -> f64 {
        self.best.as_ref().map(|(_, s)| *s).unwrap_or(f64::INFINITY)
    }

    /// Finish the run, reporting the best `k` distinct designs
    /// (ascending score; ties in first-seen order).
    pub fn into_result_k(
        self,
        algorithm: String,
        evals: usize,
        wall: Duration,
        k: usize,
    ) -> OptResult {
        let mut entries: Vec<(Design, f64, u64)> = self
            .live
            .into_iter()
            .map(|(d, (s, seq))| (d, s, seq))
            .collect();
        entries.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.2.cmp(&b.2)));
        let top: Vec<(Design, f64)> = entries
            .into_iter()
            .take(k.max(1))
            .map(|(d, s, _)| (d, s))
            .collect();
        let (best, best_score) = top
            .first()
            .cloned()
            .unwrap_or_else(|| (Design(vec![0; crate::space::NUM_PARAMS]), f64::INFINITY));
        OptResult {
            algorithm,
            best,
            best_score,
            history: self.history,
            top,
            evals,
            wall,
        }
    }

    /// Finish with the default top-5 reporting depth.
    pub fn into_result(
        self,
        algorithm: String,
        evals: usize,
        wall: Duration,
    ) -> OptResult {
        self.into_result_k(algorithm, evals, wall, 5)
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// A cheap synthetic problem over any space: score is the squared
    /// distance of the index vector from a target point, so the global
    /// minimum is known exactly. Infeasibility can be injected on a
    /// sub-region to exercise constraint handling.
    pub struct Sphere {
        pub space: SearchSpace,
        pub target: Vec<f64>,
        pub infeasible_band: Option<(usize, u16)>,
        pub count: AtomicUsize,
    }

    impl Sphere {
        pub fn centered(space: SearchSpace) -> Sphere {
            let target = space
                .params
                .iter()
                .map(|p| (p.cardinality() as f64 - 1.0) / 2.0)
                .collect();
            Sphere {
                space,
                target,
                infeasible_band: None,
                count: AtomicUsize::new(0),
            }
        }
    }

    impl Problem for Sphere {
        fn space(&self) -> &SearchSpace {
            &self.space
        }
        fn score_batch(&self, designs: &[Design]) -> Vec<f64> {
            self.count.fetch_add(designs.len(), Ordering::Relaxed);
            designs
                .iter()
                .map(|d| {
                    if let Some((pi, v)) = self.infeasible_band {
                        if d.0[pi] == v {
                            return f64::INFINITY;
                        }
                    }
                    d.0.iter()
                        .zip(&self.target)
                        .map(|(&x, &t)| {
                            let dx = x as f64 - t;
                            dx * dx
                        })
                        .sum::<f64>()
                        + 1.0
                })
                .collect()
        }
        fn evals(&self) -> usize {
            self.count.load(Ordering::Relaxed)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::Sphere;
    use super::*;

    #[test]
    fn best_tracker_orders_and_dedups() {
        let mut t = BestTracker::default();
        let d1 = Design(vec![0; 10]);
        let d2 = Design(vec![1; 10]);
        t.observe(&[d1.clone(), d2.clone(), d1.clone()], &[3.0, 1.0, 3.0]);
        t.end_generation();
        let r = t.into_result("x".into(), 3, Duration::ZERO);
        assert_eq!(r.best, d2);
        assert_eq!(r.best_score, 1.0);
        assert_eq!(r.top.len(), 2);
        assert_eq!(r.history, vec![1.0]);
    }

    #[test]
    fn best_tracker_is_bounded_and_keeps_global_best() {
        let mut t = BestTracker::default();
        // stream far more distinct designs than the cap, best arriving
        // mid-stream; scores descend then ascend so admission hits both
        // the accept and reject paths
        for i in 0..1000u16 {
            let d = Design(vec![i; 10]);
            let s = (i as f64 - 500.0).abs() + 1.0;
            t.observe(std::slice::from_ref(&d), &[s]);
        }
        assert!(t.len() <= TRACK_CAP);
        assert_eq!(t.best_score(), 1.0);
        let r = t.into_result_k("x".into(), 1000, Duration::ZERO, TRACK_CAP);
        assert_eq!(r.best, Design(vec![500; 10]));
        assert_eq!(r.top.len(), TRACK_CAP);
        assert_eq!(r.top[0].1, 1.0);
        // sorted ascending, all distinct
        for w in r.top.windows(2) {
            assert!(w[0].1 <= w[1].1);
            assert_ne!(w[0].0, w[1].0);
        }
    }

    #[test]
    fn best_tracker_rejects_duplicates_without_growth() {
        let mut t = BestTracker::default();
        let d = Design(vec![7; 10]);
        for _ in 0..100 {
            t.observe(std::slice::from_ref(&d), &[5.0]);
        }
        assert_eq!(t.len(), 1);
        // infinite scores never enter
        t.observe(&[Design(vec![9; 10])], &[f64::INFINITY]);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn best_tracker_configurable_cap_and_tie_order() {
        // cap 3; four distinct designs, two sharing the middle score —
        // the later-seen equal score is the one evicted
        let mut t = BestTracker::with_cap(3);
        let mk = |i: u16| Design(vec![i; 10]);
        t.observe(&[mk(0), mk(1), mk(2), mk(3)], &[2.0, 1.0, 2.0, 3.0]);
        assert_eq!(t.len(), 3);
        let r = t.into_result_k("x".into(), 4, Duration::ZERO, 3);
        assert_eq!(r.top.len(), 3);
        assert_eq!(r.best, mk(1));
        // ties keep first-seen order: design 0 (score 2.0) precedes 2
        assert_eq!(r.top[1].0, mk(0));
        assert_eq!(r.top[2].0, mk(2));
    }

    #[test]
    fn best_tracker_eviction_never_drops_the_minimum() {
        let mut t = BestTracker::with_cap(1);
        t.observe(&[Design(vec![1; 10])], &[5.0]);
        t.observe(&[Design(vec![2; 10])], &[3.0]);
        t.observe(&[Design(vec![3; 10])], &[9.0]); // rejected
        assert_eq!(t.len(), 1);
        assert_eq!(t.best_score(), 3.0);
        let r = t.into_result("x".into(), 3, Duration::ZERO);
        assert_eq!(r.best, Design(vec![2; 10]));
        assert_eq!(r.top.len(), 1);
    }

    #[test]
    fn top_k_is_nan_safe_and_orders_ascending() {
        let mk = |i: u16| Design(vec![i; 10]);
        let scored = vec![
            (mk(0), f64::NAN),
            (mk(1), 2.0),
            (mk(2), 1.0),
            (mk(2), 1.0),
            (mk(3), f64::INFINITY),
        ];
        let top = OptResult::top_k(scored, 3);
        assert_eq!(top.len(), 3);
        assert_eq!(top[0].0, mk(2));
        assert_eq!(top[0].1, 1.0);
        assert_eq!(top[1].1, 2.0);
        assert!(top[2].1.is_infinite());
    }

    #[test]
    fn top_k_dedups_non_adjacent_score_ties() {
        // stable sort keeps A, B, A adjacent-distinct on tied scores;
        // dedup must still be global
        let mk = |i: u16| Design(vec![i; 10]);
        let scored = vec![
            (mk(0), f64::INFINITY),
            (mk(1), f64::INFINITY),
            (mk(0), f64::INFINITY),
            (mk(2), 1.0),
        ];
        let top = OptResult::top_k(scored, 4);
        assert_eq!(top.len(), 3);
        assert_eq!(top[0].0, mk(2));
        assert_eq!(top[1].0, mk(0));
        assert_eq!(top[2].0, mk(1));
    }

    #[test]
    fn default_violation_treats_nan_as_infeasible() {
        /// Scores: finite for index-0 == 0, +inf for 1, NaN otherwise.
        struct NanScores(SearchSpace);
        impl Problem for NanScores {
            fn space(&self) -> &SearchSpace {
                &self.0
            }
            fn score_batch(&self, designs: &[Design]) -> Vec<f64> {
                designs
                    .iter()
                    .map(|d| match d.0[0] {
                        0 => 1.0,
                        1 => f64::INFINITY,
                        _ => f64::NAN,
                    })
                    .collect()
            }
        }
        let p = NanScores(SearchSpace::rram_reduced());
        let mut ok = Design(vec![0; 10]);
        assert_eq!(p.violation(&ok), 0.0);
        ok.0[0] = 1;
        assert_eq!(p.violation(&ok), 1.0, "+inf is infeasible");
        ok.0[0] = 2;
        assert_eq!(p.violation(&ok), 1.0, "NaN must grade as infeasible too");
    }

    #[test]
    fn sphere_minimum_is_target() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let best = Design(
            p.target
                .iter()
                .map(|t| t.round() as u16)
                .collect::<Vec<_>>(),
        );
        let s = p.score_batch(&[best])[0];
        // reduced space cardinalities: 5,5,4,... -> target .5 offsets
        assert!(s < 2.5, "{s}");
    }
}
