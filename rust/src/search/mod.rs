//! Optimization algorithms (paper §III-C).
//!
//! [`Problem`] abstracts "score a batch of designs jointly across the
//! workload set" (implemented by `coordinator::JointProblem`, which routes
//! evaluation through the PJRT artifact or the native evaluator, with
//! memoization). [`Optimizer`] is implemented by:
//!
//! * [`GeneticAlgorithm`] — generic phased GA engine with SBX crossover +
//!   polynomial mutation; covers the paper's *non-modified GA* baseline
//!   \[44\], the *non-modified GA + enhanced sampling* baseline, and the
//!   proposed **four-phase GA** ([`FourPhaseGa`], Table 4) with
//!   Hamming-distance diversity sampling ([`sampling`]).
//! * Table 3 baselines: [`pso::Pso`], [`es::EvolutionStrategy`] (ES and
//!   stochastic-ranking ERES), [`cmaes::CmaEs`], [`g3pcx::G3Pcx`], and
//!   [`exhaustive::Exhaustive`] ground truth.

pub mod cmaes;
pub mod es;
pub mod exhaustive;
pub mod g3pcx;
pub mod ga;
pub mod pso;
pub mod sampling;
pub mod surrogate;

pub use cmaes::CmaEs;
pub use es::EvolutionStrategy;
pub use exhaustive::Exhaustive;
pub use g3pcx::G3Pcx;
pub use ga::{EarlyStop, FourPhaseGa, GaConfig, GeneticAlgorithm, InitStrategy, PhaseParams};
pub use pso::Pso;

use crate::space::{Design, SearchSpace};
use crate::util::rng::Rng;
use std::time::Duration;

/// A joint hardware-workload optimization problem: lower score is better,
/// `+∞` marks infeasible designs.
pub trait Problem: Sync {
    fn space(&self) -> &SearchSpace;

    /// Joint scores for a batch of designs (order-preserving).
    fn score_batch(&self, designs: &[Design]) -> Vec<f64>;

    /// Sample a random *initial* candidate. Implementations may apply the
    /// paper's feasibility pre-filter (RRAM weight-stationary designs must
    /// hold the largest workload, Algorithm 1).
    fn random_candidate(&self, rng: &mut Rng) -> Design {
        self.space().random(rng)
    }

    /// Graded constraint violation for stochastic ranking (ERES): 0 for
    /// feasible designs, positive magnitude otherwise. The default cannot
    /// grade, so it reports 1.0 for infeasible scores.
    fn violation(&self, design: &Design) -> f64 {
        if self.score_batch(std::slice::from_ref(design))[0].is_finite() {
            0.0
        } else {
            1.0
        }
    }

    /// Number of evaluator invocations so far (for runtime accounting).
    fn evals(&self) -> usize {
        0
    }
}

/// Search effort shared across algorithms so comparisons are budgeted
/// fairly ("equivalent population size and number of generations", §IV-E).
#[derive(Clone, Copy, Debug)]
pub struct SearchBudget {
    /// Population / swarm size per generation.
    pub pop: usize,
    /// Total generations (a 4-phase GA splits these across phases).
    pub gens: usize,
}

impl SearchBudget {
    /// The paper's default: `P_GA = 40`, `G = 10` per phase × 4 phases.
    pub fn paper() -> SearchBudget {
        SearchBudget { pop: 40, gens: 40 }
    }
}

/// Result of one optimization run.
#[derive(Clone, Debug)]
pub struct OptResult {
    pub algorithm: String,
    /// Best design found.
    pub best: Design,
    pub best_score: f64,
    /// Best-so-far score after each generation (convergence curve).
    pub history: Vec<f64>,
    /// Top-k (design, score) pairs, best first (Fig. 5 plots top-5).
    pub top: Vec<(Design, f64)>,
    /// Evaluator invocations consumed by this run.
    pub evals: usize,
    pub wall: Duration,
}

impl OptResult {
    /// Collect the best `k` distinct designs from a scored population.
    pub fn top_k(mut scored: Vec<(Design, f64)>, k: usize) -> Vec<(Design, f64)> {
        scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        scored.dedup_by(|a, b| a.0 == b.0);
        scored.truncate(k);
        scored
    }
}

/// A search algorithm.
pub trait Optimizer {
    fn name(&self) -> String;
    fn run(&self, problem: &dyn Problem, rng: &mut Rng) -> OptResult;
}

/// Bounded top-k capacity of [`BestTracker`]: large enough for the top-5
/// reporting plus elite bookkeeping, small enough that membership checks
/// are a short linear scan.
const TRACK_CAP: usize = 64;

/// Tracks the best-so-far set during a run; shared by all optimizers.
///
/// A bounded top-k structure: `seen` holds at most [`TRACK_CAP`] *distinct*
/// designs, sorted ascending by score. Candidates that cannot enter the
/// top-k are rejected without cloning (the common case once a run warms
/// up), replacing the old unbounded push + periodic 4096-element
/// sort/dedup/truncate which cloned every finite design it ever observed.
#[derive(Clone, Debug, Default)]
pub(crate) struct BestTracker {
    /// Distinct (design, score), sorted ascending by score; ties keep
    /// first-seen order (stable insertion).
    seen: Vec<(Design, f64)>,
    pub history: Vec<f64>,
}

impl BestTracker {
    pub fn observe(&mut self, designs: &[Design], scores: &[f64]) {
        for (d, &s) in designs.iter().zip(scores) {
            if s.is_finite() {
                self.insert(d, s);
            }
        }
    }

    fn insert(&mut self, d: &Design, s: f64) {
        // cheap rejection first: no clone, no scan
        if self.seen.len() == TRACK_CAP
            && s >= self.seen.last().map(|(_, w)| *w).unwrap_or(f64::INFINITY)
        {
            return;
        }
        // dedup: scores are deterministic per design, but tolerate a
        // changed score by keeping the better one
        if let Some(pos) = self.seen.iter().position(|(e, _)| e == d) {
            if s >= self.seen[pos].1 {
                return;
            }
            self.seen.remove(pos);
        }
        // stable insert after equal scores (first-seen wins on ties)
        let at = self.seen.partition_point(|(_, e)| *e <= s);
        self.seen.insert(at, (d.clone(), s));
        self.seen.truncate(TRACK_CAP);
    }

    pub fn end_generation(&mut self) {
        self.history.push(self.best_score());
    }

    pub fn best_score(&self) -> f64 {
        self.seen.first().map(|(_, s)| *s).unwrap_or(f64::INFINITY)
    }

    pub fn into_result(
        self,
        algorithm: String,
        evals: usize,
        wall: Duration,
    ) -> OptResult {
        // `seen` is already sorted and distinct
        let (best, best_score) = self
            .seen
            .first()
            .cloned()
            .unwrap_or_else(|| (Design(vec![0; crate::space::NUM_PARAMS]), f64::INFINITY));
        let top = OptResult::top_k(self.seen, 5);
        OptResult {
            algorithm,
            best,
            best_score,
            history: self.history,
            top,
            evals,
            wall,
        }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// A cheap synthetic problem over any space: score is the squared
    /// distance of the index vector from a target point, so the global
    /// minimum is known exactly. Infeasibility can be injected on a
    /// sub-region to exercise constraint handling.
    pub struct Sphere {
        pub space: SearchSpace,
        pub target: Vec<f64>,
        pub infeasible_band: Option<(usize, u16)>,
        pub count: AtomicUsize,
    }

    impl Sphere {
        pub fn centered(space: SearchSpace) -> Sphere {
            let target = space
                .params
                .iter()
                .map(|p| (p.cardinality() as f64 - 1.0) / 2.0)
                .collect();
            Sphere {
                space,
                target,
                infeasible_band: None,
                count: AtomicUsize::new(0),
            }
        }
    }

    impl Problem for Sphere {
        fn space(&self) -> &SearchSpace {
            &self.space
        }
        fn score_batch(&self, designs: &[Design]) -> Vec<f64> {
            self.count.fetch_add(designs.len(), Ordering::Relaxed);
            designs
                .iter()
                .map(|d| {
                    if let Some((pi, v)) = self.infeasible_band {
                        if d.0[pi] == v {
                            return f64::INFINITY;
                        }
                    }
                    d.0.iter()
                        .zip(&self.target)
                        .map(|(&x, &t)| {
                            let dx = x as f64 - t;
                            dx * dx
                        })
                        .sum::<f64>()
                        + 1.0
                })
                .collect()
        }
        fn evals(&self) -> usize {
            self.count.load(Ordering::Relaxed)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::Sphere;
    use super::*;

    #[test]
    fn best_tracker_orders_and_dedups() {
        let mut t = BestTracker::default();
        let d1 = Design(vec![0; 10]);
        let d2 = Design(vec![1; 10]);
        t.observe(&[d1.clone(), d2.clone(), d1.clone()], &[3.0, 1.0, 3.0]);
        t.end_generation();
        let r = t.into_result("x".into(), 3, Duration::ZERO);
        assert_eq!(r.best, d2);
        assert_eq!(r.best_score, 1.0);
        assert_eq!(r.top.len(), 2);
        assert_eq!(r.history, vec![1.0]);
    }

    #[test]
    fn best_tracker_is_bounded_and_keeps_global_best() {
        let mut t = BestTracker::default();
        // stream far more distinct designs than the cap, best arriving
        // mid-stream; scores descend then ascend so insertion hits both
        // ends of the sorted vec
        for i in 0..1000u16 {
            let d = Design(vec![i; 10]);
            let s = (i as f64 - 500.0).abs() + 1.0;
            t.observe(std::slice::from_ref(&d), &[s]);
        }
        assert!(t.seen.len() <= TRACK_CAP);
        assert_eq!(t.best_score(), 1.0);
        // sorted ascending, all distinct
        for w in t.seen.windows(2) {
            assert!(w[0].1 <= w[1].1);
            assert_ne!(w[0].0, w[1].0);
        }
        let r = t.into_result("x".into(), 1000, Duration::ZERO);
        assert_eq!(r.best, Design(vec![500; 10]));
        assert_eq!(r.top.len(), 5);
        assert_eq!(r.top[0].1, 1.0);
    }

    #[test]
    fn best_tracker_rejects_duplicates_without_growth() {
        let mut t = BestTracker::default();
        let d = Design(vec![7; 10]);
        for _ in 0..100 {
            t.observe(std::slice::from_ref(&d), &[5.0]);
        }
        assert_eq!(t.seen.len(), 1);
        // infinite scores never enter
        t.observe(&[Design(vec![9; 10])], &[f64::INFINITY]);
        assert_eq!(t.seen.len(), 1);
    }

    #[test]
    fn sphere_minimum_is_target() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let best = Design(
            p.target
                .iter()
                .map(|t| t.round() as u16)
                .collect::<Vec<_>>(),
        );
        let s = p.score_batch(&[best])[0];
        // reduced space cardinalities: 5,5,4,... -> target .5 offsets
        assert!(s < 2.5, "{s}");
    }
}
