//! (μ+λ) evolution strategy, with an optional stochastic-ranking variant
//! (ERES, Runarsson & Yao 2000) — Table 3 baselines.
//!
//! Search happens in continuous index space with per-parameter Gaussian
//! mutation and self-adaptive global step size; candidates snap onto the
//! discrete grid for evaluation. ERES differs only in survivor selection:
//! stochastic ranking bubble-sorts by objective with probability `p_f` and
//! by constraint violation otherwise, which lets slightly-infeasible
//! designs survive while the population approaches a constrained optimum.

use super::{BestTracker, OptResult, Optimizer, Problem, SearchBudget};
use crate::space::Design;
use crate::util::rng::Rng;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EsVariant {
    /// Plain (μ+λ) ES: infeasible candidates rank last (score = +∞).
    Plain,
    /// Stochastic-ranking ES.
    StochasticRanking,
}

pub struct EvolutionStrategy {
    pub budget: SearchBudget,
    pub variant: EsVariant,
    /// Parent count μ (λ = budget.pop).
    pub mu: usize,
    /// Stochastic ranking objective-comparison probability.
    pub pf: f64,
}

impl EvolutionStrategy {
    pub fn plain(budget: SearchBudget) -> Self {
        EvolutionStrategy {
            budget,
            variant: EsVariant::Plain,
            mu: (budget.pop / 4).max(2),
            pf: 0.45,
        }
    }
    pub fn eres(budget: SearchBudget) -> Self {
        EvolutionStrategy {
            variant: EsVariant::StochasticRanking,
            ..EvolutionStrategy::plain(budget)
        }
    }
}

struct Individual {
    x: Vec<f64>,
    sigma: f64,
    score: f64,
    violation: f64,
}

impl Optimizer for EvolutionStrategy {
    fn name(&self) -> String {
        match self.variant {
            EsVariant::Plain => "ES".into(),
            EsVariant::StochasticRanking => "ERES".into(),
        }
    }

    fn run(&self, problem: &dyn Problem, rng: &mut Rng) -> OptResult {
        let t0 = Instant::now();
        let space = problem.space();
        let n = space.params.len();
        let lambda = self.budget.pop;
        let tau = 1.0 / (2.0 * n as f64).sqrt();
        let mut tracker = BestTracker::default();
        let mut evals = 0usize;

        let eval =
            |xs: &[Vec<f64>], problem: &dyn Problem| -> (Vec<Design>, Vec<f64>) {
                let ds: Vec<Design> = xs.iter().map(|x| space.clamp_round(x)).collect();
                let ss = problem.score_batch(&ds);
                (ds, ss)
            };

        // initial parents
        let init_x: Vec<Vec<f64>> = (0..self.mu)
            .map(|_| {
                problem
                    .random_candidate(rng)
                    .0
                    .iter()
                    .map(|&v| v as f64)
                    .collect()
            })
            .collect();
        let (ds, ss) = eval(&init_x, problem);
        evals += ds.len();
        tracker.observe(&ds, &ss);
        tracker.end_generation();
        let mut parents: Vec<Individual> = init_x
            .into_iter()
            .zip(ds.iter().zip(&ss))
            .map(|(x, (d, &s))| Individual {
                violation: if s.is_finite() { 0.0 } else { problem.violation(d) },
                x,
                sigma: 1.0,
                score: s,
            })
            .collect();

        for _gen in 1..self.budget.gens {
            // offspring
            let mut off_x: Vec<Vec<f64>> = Vec::with_capacity(lambda);
            let mut off_sigma: Vec<f64> = Vec::with_capacity(lambda);
            for _ in 0..lambda {
                let p = &parents[rng.below(parents.len())];
                let sigma = (p.sigma * (tau * rng.normal()).exp()).clamp(0.05, 4.0);
                let x: Vec<f64> = p
                    .x
                    .iter()
                    .enumerate()
                    .map(|(i, &xi)| {
                        let hi = space.params[i].cardinality() as f64 - 1.0;
                        (xi + sigma * rng.normal()).clamp(0.0, hi)
                    })
                    .collect();
                off_x.push(x);
                off_sigma.push(sigma);
            }
            let (ds, ss) = eval(&off_x, problem);
            evals += ds.len();
            tracker.observe(&ds, &ss);
            tracker.end_generation();

            let mut pool: Vec<Individual> = parents
                .into_iter()
                .chain(off_x.into_iter().zip(off_sigma).zip(ds.iter().zip(&ss)).map(
                    |((x, sigma), (d, &s))| Individual {
                        violation: if s.is_finite() { 0.0 } else { problem.violation(d) },
                        x,
                        sigma,
                        score: s,
                    },
                ))
                .collect();

            match self.variant {
                EsVariant::Plain => {
                    pool.sort_by(|a, b| a.score.partial_cmp(&b.score).unwrap());
                }
                EsVariant::StochasticRanking => {
                    stochastic_rank(&mut pool, self.pf, rng);
                }
            }
            pool.truncate(self.mu);
            parents = pool;
        }
        tracker.into_result(self.name(), evals, t0.elapsed())
    }
}

/// Runarsson & Yao's stochastic-ranking bubble sort: N sweeps, comparing
/// adjacent pairs by objective with probability `pf` when either violates,
/// and by violation otherwise.
fn stochastic_rank(pool: &mut [Individual], pf: f64, rng: &mut Rng) {
    let n = pool.len();
    for _ in 0..n {
        let mut swapped = false;
        for i in 0..n - 1 {
            let (a, b) = (&pool[i], &pool[i + 1]);
            let both_feasible = a.violation == 0.0 && b.violation == 0.0;
            let by_objective = both_feasible || rng.chance(pf);
            let should_swap = if by_objective {
                cmp_score(a.score, b.score)
            } else {
                a.violation > b.violation
            };
            if should_swap {
                pool.swap(i, i + 1);
                swapped = true;
            }
        }
        if !swapped {
            break;
        }
    }
}

/// Treat +∞ as worst; NaN never occurs.
fn cmp_score(a: f64, b: f64) -> bool {
    a > b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::testutil::Sphere;
    use crate::space::SearchSpace;

    #[test]
    fn es_converges_on_reduced_space() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let es = EvolutionStrategy::plain(SearchBudget { pop: 20, gens: 20 });
        let r = es.run(&p, &mut Rng::seed_from(3));
        assert!(r.best_score < 4.0, "{}", r.best_score);
    }

    #[test]
    fn eres_handles_infeasible_band() {
        let mut p = Sphere::centered(SearchSpace::rram_reduced());
        p.infeasible_band = Some((0, 2)); // rows index 2 infeasible
        let es = EvolutionStrategy::eres(SearchBudget { pop: 20, gens: 20 });
        let r = es.run(&p, &mut Rng::seed_from(4));
        assert!(r.best_score.is_finite());
        assert_ne!(r.best.0[0], 2, "best design sits in the infeasible band");
    }

    #[test]
    fn stochastic_rank_feasible_first_at_pf0() {
        let mk = |score: f64, v: f64| Individual {
            x: vec![],
            sigma: 1.0,
            score,
            violation: v,
        };
        let mut pool = vec![mk(5.0, 1.0), mk(9.0, 0.0), mk(1.0, 2.0)];
        let mut rng = Rng::seed_from(5);
        stochastic_rank(&mut pool, 0.0, &mut rng);
        // with pf=0, violation dominates: feasible (9.0) first
        assert_eq!(pool[0].violation, 0.0);
        assert!(pool[2].violation >= pool[1].violation);
    }
}
