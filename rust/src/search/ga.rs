//! Phased genetic algorithm engine (paper §III-C2, Algorithm 1, Table 4).
//!
//! One engine covers all three GA variants the paper compares:
//!
//! * **non-modified GA** \[44\]: random init, a single phase with
//!   conventional operator settings;
//! * **non-modified GA + enhanced sampling**: the same single phase but
//!   initialized by Hamming-diversity sampling;
//! * **four-phase GA (proposed)**: Hamming sampling + the
//!   Exploration → Transition → Convergence → Fine-tuning schedule of
//!   Table 4.
//!
//! Variation operators are simulated binary crossover (SBX) and polynomial
//! mutation (Deb et al.), applied to the index-coded genome and snapped
//! back onto the discrete grid.

use super::sampling;
use super::{BestTracker, OptResult, Optimizer, Problem, SearchBudget};
use crate::space::Design;
use crate::util::rng::Rng;
use std::time::Instant;

/// Crossover/mutation parameters of one phase (paper Table 4).
#[derive(Clone, Copy, Debug)]
pub struct PhaseParams {
    pub name: &'static str,
    /// Crossover probability `P_c`.
    pub pc: f64,
    /// SBX distribution index `η_c`.
    pub eta_c: f64,
    /// Mutation probability `P_m` (per offspring).
    pub pm: f64,
    /// Polynomial-mutation distribution index `η_m`.
    pub eta_m: f64,
}

/// Paper Table 4, verbatim.
pub const PAPER_PHASES: [PhaseParams; 4] = [
    PhaseParams { name: "exploration", pc: 1.0, eta_c: 3.0, pm: 1.0, eta_m: 3.0 },
    PhaseParams { name: "transition", pc: 0.9, eta_c: 7.0, pm: 0.5, eta_m: 7.0 },
    PhaseParams { name: "convergence", pc: 1.0, eta_c: 15.0, pm: 0.2, eta_m: 15.0 },
    PhaseParams { name: "fine-tuning", pc: 1.0, eta_c: 25.0, pm: 0.05, eta_m: 25.0 },
];

/// Conventional single-phase settings for the non-modified GA baseline
/// \[44\] (pymoo-style defaults: SBX η=15, polynomial mutation applied to
/// every offspring with per-gene probability 1/n).
pub const CLASSIC_PHASE: PhaseParams = PhaseParams {
    name: "classic",
    pc: 0.9,
    eta_c: 15.0,
    pm: 0.9,
    eta_m: 20.0,
};

/// Initial-population strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitStrategy {
    /// Uniform random (feasibility-prefiltered by the problem).
    Random,
    /// Hamming-diversity sampling pipeline (Algorithm 1): `P_H` random →
    /// `P_E` most diverse → evaluate → best `P_GA`.
    HammingDiverse { p_h: usize, p_e: usize },
}

/// Early-stopping policy (paper §V-D: "monitor the convergence of the
/// algorithm during the search and apply early stopping ... rather than
/// running through all generations in each phase").
#[derive(Clone, Copy, Debug)]
pub struct EarlyStop {
    /// Consecutive generations without sufficient improvement before the
    /// current phase is cut short.
    pub patience: usize,
    /// Minimum relative best-score improvement that counts as progress.
    pub min_rel_improve: f64,
}

impl EarlyStop {
    pub fn default_policy() -> EarlyStop {
        EarlyStop {
            patience: 3,
            min_rel_improve: 1e-3,
        }
    }
}

/// Full GA configuration.
#[derive(Clone, Debug)]
pub struct GaConfig {
    pub phases: Vec<PhaseParams>,
    pub init: InitStrategy,
    pub budget: SearchBudget,
    /// Elites copied unchanged each generation.
    pub elites: usize,
    /// Optional per-phase early stopping (§V-D extension).
    pub early_stop: Option<EarlyStop>,
    /// Distinct best designs reported in `OptResult::top` (the tracker
    /// keeps at least this many; `genmatrix` raises it via `--topk`).
    pub top_k: usize,
    /// Fraction of each generation's offspring pool that reaches the
    /// exact evaluator (`--screen-frac`). At the default `1.0` screening
    /// is off and the loop is bit-identical to the pre-surrogate engine;
    /// below `1.0` a [`ScreenState`](super::surrogate::ScreenState)
    /// ranks a `1/frac`-times
    /// larger variation pool and only the predicted-best λ evaluate —
    /// same evaluator calls per generation, wider candidate pool.
    pub screen_frac: f64,
    pub label: String,
}

impl GaConfig {
    /// Non-modified GA \[44\] at the paper's budget (one phase running all
    /// generations).
    pub fn classic(budget: SearchBudget) -> GaConfig {
        GaConfig {
            phases: vec![CLASSIC_PHASE],
            init: InitStrategy::Random,
            budget,
            elites: 2,
            early_stop: None,
            top_k: 5,
            screen_frac: 1.0,
            label: "GA (non-modified)".into(),
        }
    }

    /// Non-modified GA with the enhanced sampling front-end.
    pub fn classic_sampled(budget: SearchBudget) -> GaConfig {
        GaConfig {
            init: InitStrategy::HammingDiverse {
                p_h: sampling::P_H,
                p_e: sampling::P_E,
            },
            label: "GA (non-modified + sampling)".into(),
            ..GaConfig::classic(budget)
        }
    }

    /// The proposed four-phase GA with Hamming sampling.
    pub fn four_phase(budget: SearchBudget) -> GaConfig {
        GaConfig {
            phases: PAPER_PHASES.to_vec(),
            init: InitStrategy::HammingDiverse {
                p_h: sampling::P_H,
                p_e: sampling::P_E,
            },
            budget,
            elites: 2,
            early_stop: None,
            top_k: 5,
            screen_frac: 1.0,
            label: "4-phase GA (proposed)".into(),
        }
    }
}

/// The GA engine.
#[derive(Clone, Debug)]
pub struct GeneticAlgorithm {
    pub config: GaConfig,
}

impl GeneticAlgorithm {
    pub fn new(config: GaConfig) -> Self {
        GeneticAlgorithm { config }
    }
}

/// The proposed algorithm under its paper defaults — a convenience facade.
pub struct FourPhaseGa;

impl FourPhaseGa {
    pub fn paper_defaults() -> GeneticAlgorithm {
        GeneticAlgorithm::new(GaConfig::four_phase(SearchBudget::paper()))
    }
}

/// SBX crossover on one gene pair in continuous index space.
fn sbx_gene(a: f64, b: f64, eta: f64, rng: &mut Rng) -> (f64, f64) {
    let u = rng.f64();
    let beta = if u <= 0.5 {
        (2.0 * u).powf(1.0 / (eta + 1.0))
    } else {
        (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (eta + 1.0))
    };
    let c1 = 0.5 * ((1.0 + beta) * a + (1.0 - beta) * b);
    let c2 = 0.5 * ((1.0 - beta) * a + (1.0 + beta) * b);
    (c1, c2)
}

/// Polynomial mutation on one gene in `[0, hi]`.
fn poly_mut_gene(x: f64, hi: f64, eta: f64, rng: &mut Rng) -> f64 {
    if hi <= 0.0 {
        return x;
    }
    let u = rng.f64();
    let delta = if u < 0.5 {
        (2.0 * u).powf(1.0 / (eta + 1.0)) - 1.0
    } else {
        1.0 - (2.0 * (1.0 - u)).powf(1.0 / (eta + 1.0))
    };
    x + delta * hi
}

/// Produce two offspring from two parents under phase parameters.
/// Shared with the multi-objective engine (`pareto::nsga2`), so the
/// scalar GA and NSGA-II explore with bit-identical operators.
pub(crate) fn variate(
    space: &crate::space::SearchSpace,
    p1: &Design,
    p2: &Design,
    ph: &PhaseParams,
    rng: &mut Rng,
) -> (Design, Design) {
    let n = p1.0.len();
    let mut c1: Vec<f64> = p1.0.iter().map(|&x| x as f64).collect();
    let mut c2: Vec<f64> = p2.0.iter().map(|&x| x as f64).collect();
    if rng.chance(ph.pc) {
        for i in 0..n {
            if space.params[i].cardinality() > 1 && rng.chance(0.5) {
                let (a, b) = sbx_gene(c1[i], c2[i], ph.eta_c, rng);
                c1[i] = a;
                c2[i] = b;
            }
        }
    }
    let free = space.free_params();
    let gene_pm = 1.0 / free.len() as f64;
    for c in [&mut c1, &mut c2] {
        if rng.chance(ph.pm) {
            for &i in &free {
                if rng.chance(gene_pm) {
                    let hi = space.params[i].cardinality() as f64 - 1.0;
                    c[i] = poly_mut_gene(c[i], hi, ph.eta_m, rng);
                }
            }
        }
    }
    (space.clamp_round(&c1), space.clamp_round(&c2))
}

/// Binary tournament selection over a scored population (lower better).
fn tournament<'a>(
    scored: &'a [(Design, f64)],
    rng: &mut Rng,
) -> &'a Design {
    let a = rng.below(scored.len());
    let b = rng.below(scored.len());
    if scored[a].1 <= scored[b].1 {
        &scored[a].0
    } else {
        &scored[b].0
    }
}

impl Optimizer for GeneticAlgorithm {
    fn name(&self) -> String {
        self.config.label.clone()
    }

    fn run(&self, problem: &dyn Problem, rng: &mut Rng) -> OptResult {
        let t0 = Instant::now();
        let cfg = &self.config;
        let space = problem.space();
        let pop_size = cfg.budget.pop;
        let mut evals = 0usize;
        let mut tracker = BestTracker::with_cap(cfg.top_k.max(super::TRACK_CAP));
        // `None` at `screen_frac >= 1.0`: the loop below then runs the
        // exact pre-surrogate code path (same RNG draws, bit-identical)
        let mut screen = super::surrogate::ScreenState::new(cfg.screen_frac);

        // ---- initial population -------------------------------------------
        let mut pop: Vec<Design> = match cfg.init {
            InitStrategy::Random => (0..pop_size)
                .map(|_| problem.random_candidate(rng))
                .collect(),
            InitStrategy::HammingDiverse { p_h, p_e } => {
                let (init, used) =
                    sampling::hamming_init(problem, p_h, p_e, pop_size, rng);
                evals += used;
                init
            }
        };

        // generations are split evenly across phases
        let phases = &cfg.phases;
        let gens_per_phase = (cfg.budget.gens / phases.len()).max(1);

        // trace bookkeeping (out of band): generation index and the
        // surrogate-screen (accepted, pool) sizes that produced the
        // population being scored — the initial population is unscreened
        let mut gen_idx = 0usize;
        let mut last_accept = (pop_size, pop_size);

        for ph in phases {
            let mut stall = 0usize;
            let mut phase_best = f64::INFINITY;
            for _gen in 0..gens_per_phase {
                let scores = problem.score_batch(&pop);
                evals += pop.len();
                tracker.observe(&pop, &scores);
                tracker.end_generation();
                if let Some(s) = screen.as_mut() {
                    s.observe(space, &pop, &scores);
                }
                crate::telemetry::emit_generation(
                    gen_idx,
                    evals,
                    tracker.best_score(),
                    &scores,
                    last_accept.0,
                    last_accept.1,
                );
                gen_idx += 1;

                // §V-D early stopping: cut the phase short once the best
                // score plateaus
                if let Some(es) = cfg.early_stop {
                    let best_now = tracker.best_score();
                    if best_now < phase_best * (1.0 - es.min_rel_improve) {
                        phase_best = best_now;
                        stall = 0;
                    } else {
                        stall += 1;
                        if stall >= es.patience {
                            break;
                        }
                    }
                }

                let mut scored: Vec<(Design, f64)> =
                    pop.iter().cloned().zip(scores.iter().cloned()).collect();
                scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

                // next generation: elites + variation
                let mut next: Vec<Design> = scored
                    .iter()
                    .take(cfg.elites.min(scored.len()))
                    .map(|(d, _)| d.clone())
                    .collect();
                match screen.as_mut() {
                    None => {
                        // exact path (--screen-frac 1.0 / default)
                        while next.len() < pop_size {
                            let p1 = tournament(&scored, rng).clone();
                            let p2 = tournament(&scored, rng).clone();
                            let (c1, c2) = variate(space, &p1, &p2, ph, rng);
                            next.push(c1);
                            if next.len() < pop_size {
                                next.push(c2);
                            }
                        }
                    }
                    Some(s) => {
                        // two-stage path: recycle last round's rejects,
                        // variate up to a 1/frac-times larger pool, keep
                        // the surrogate's top λ for exact evaluation
                        let lambda = pop_size - next.len();
                        let target = s.pool_target(lambda);
                        let mut pool = s.take_carry();
                        while pool.len() < target {
                            let p1 = tournament(&scored, rng).clone();
                            let p2 = tournament(&scored, rng).clone();
                            let (c1, c2) = variate(space, &p1, &p2, ph, rng);
                            pool.push(c1);
                            if pool.len() < target {
                                pool.push(c2);
                            }
                        }
                        last_accept = (lambda, pool.len());
                        next.extend(s.select(space, pool, lambda));
                    }
                }
                pop = next;
            }
        }

        // final evaluation of the last population
        let scores = problem.score_batch(&pop);
        evals += pop.len();
        tracker.observe(&pop, &scores);
        tracker.end_generation();
        crate::telemetry::emit_generation(
            gen_idx,
            evals,
            tracker.best_score(),
            &scores,
            last_accept.0,
            last_accept.1,
        );

        tracker.into_result_k(self.name(), evals, t0.elapsed(), cfg.top_k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::testutil::Sphere;
    use crate::space::SearchSpace;

    fn budget() -> SearchBudget {
        SearchBudget { pop: 24, gens: 16 }
    }

    #[test]
    fn four_phase_finds_sphere_optimum() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let ga = GeneticAlgorithm::new(GaConfig {
            init: InitStrategy::HammingDiverse { p_h: 100, p_e: 50 },
            ..GaConfig::four_phase(budget())
        });
        let r = ga.run(&p, &mut Rng::seed_from(5));
        // global optimum of the centered sphere on the reduced space is
        // 1.0 + sum of .5^2 offsets for even-cardinality params (3 of them)
        assert!(r.best_score <= 2.0, "{}", r.best_score);
        assert!(!r.history.is_empty());
        assert!(r.top.len() <= 5 && !r.top.is_empty());
    }

    #[test]
    fn history_is_monotone_nonincreasing() {
        let p = Sphere::centered(SearchSpace::rram());
        let ga = GeneticAlgorithm::new(GaConfig::classic(budget()));
        let r = ga.run(&p, &mut Rng::seed_from(6));
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "history regressed: {:?}", r.history);
        }
    }

    #[test]
    fn sampled_init_beats_random_init_on_average() {
        // The paper's core algorithmic claim at miniature scale: enhanced
        // sampling should not be worse on average across seeds.
        let p = Sphere::centered(SearchSpace::rram());
        let score = |cfg: GaConfig, seed: u64| {
            GeneticAlgorithm::new(cfg)
                .run(&p, &mut Rng::seed_from(seed))
                .best_score
        };
        let seeds = [1u64, 2, 3, 4, 5, 6];
        let small = SearchBudget { pop: 16, gens: 8 };
        let rand_avg: f64 = seeds
            .iter()
            .map(|&s| score(GaConfig::classic(small), s))
            .sum::<f64>()
            / seeds.len() as f64;
        let samp_avg: f64 = seeds
            .iter()
            .map(|&s| {
                score(
                    GaConfig {
                        init: InitStrategy::HammingDiverse { p_h: 200, p_e: 100 },
                        ..GaConfig::classic(small)
                    },
                    s,
                )
            })
            .sum::<f64>()
            / seeds.len() as f64;
        assert!(
            samp_avg <= rand_avg * 1.05,
            "sampled {samp_avg} vs random {rand_avg}"
        );
    }

    #[test]
    fn variation_respects_domains() {
        let space = SearchSpace::rram();
        let mut rng = Rng::seed_from(7);
        for _ in 0..500 {
            let p1 = space.random(&mut rng);
            let p2 = space.random(&mut rng);
            let (c1, c2) = variate(&space, &p1, &p2, &PAPER_PHASES[0], &mut rng);
            for d in [&c1, &c2] {
                for (i, &v) in d.0.iter().enumerate() {
                    assert!((v as usize) < space.params[i].cardinality());
                }
            }
        }
    }

    #[test]
    fn high_eta_keeps_offspring_near_parents() {
        // Fine-tuning phase (η=25) must perturb less than exploration (η=3).
        let space = SearchSpace::rram();
        let mut rng = Rng::seed_from(8);
        let dist = |ph: &PhaseParams, rng: &mut Rng| -> f64 {
            let mut total = 0usize;
            let n = 400;
            for _ in 0..n {
                let p1 = space.random(rng);
                let p2 = p1.clone(); // identical parents isolate mutation
                let (c1, _) = variate(&space, &p1, &p2, ph, rng);
                total += p1.hamming(&c1);
            }
            total as f64 / n as f64
        };
        let explo = dist(&PAPER_PHASES[0], &mut rng);
        let fine = dist(&PAPER_PHASES[3], &mut rng);
        assert!(
            fine < explo,
            "fine-tuning drift {fine} !< exploration drift {explo}"
        );
    }

    #[test]
    fn top_k_is_configurable() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let ga = GeneticAlgorithm::new(GaConfig {
            top_k: 12,
            ..GaConfig::classic(budget())
        });
        let r = ga.run(&p, &mut Rng::seed_from(10));
        assert!(
            r.top.len() > 5 && r.top.len() <= 12,
            "top len {}",
            r.top.len()
        );
        for w in r.top.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn screened_run_keeps_eval_budget_and_differs_from_exact() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let budget = SearchBudget { pop: 12, gens: 8 };
        let exact = GeneticAlgorithm::new(GaConfig::classic(budget))
            .run(&p, &mut Rng::seed_from(21));
        let screened_cfg = GaConfig {
            screen_frac: 0.25,
            ..GaConfig::classic(budget)
        };
        let screened = GeneticAlgorithm::new(screened_cfg.clone())
            .run(&Sphere::centered(SearchSpace::rram_reduced()), &mut Rng::seed_from(21));
        // same exact-evaluation budget per construction
        assert_eq!(screened.evals, exact.evals);
        // explicit 1.0 is the exact path, bit for bit
        let one = GeneticAlgorithm::new(GaConfig {
            screen_frac: 1.0,
            ..GaConfig::classic(budget)
        })
        .run(&Sphere::centered(SearchSpace::rram_reduced()), &mut Rng::seed_from(21));
        assert_eq!(one.best_score.to_bits(), exact.best_score.to_bits());
        assert_eq!(one.history, exact.history);
        assert_eq!(one.best, exact.best);
        // screened runs are themselves deterministic per seed
        let screened2 = GeneticAlgorithm::new(screened_cfg)
            .run(&Sphere::centered(SearchSpace::rram_reduced()), &mut Rng::seed_from(21));
        assert_eq!(screened.best_score.to_bits(), screened2.best_score.to_bits());
        assert_eq!(screened.best, screened2.best);
    }

    #[test]
    fn evals_accounting() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let ga = GeneticAlgorithm::new(GaConfig::classic(SearchBudget { pop: 10, gens: 4 }));
        let r = ga.run(&p, &mut Rng::seed_from(9));
        // 4 generational evals + final
        assert_eq!(r.evals, 10 * 5);
        assert_eq!(p.evals(), r.evals);
    }
}
