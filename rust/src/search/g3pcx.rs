//! G3-PCX (Deb, Anand & Joshi 2002) — Table 3 baseline.
//!
//! Generalized generation-gap model with parent-centric crossover: each
//! step picks the best individual plus two random parents, generates
//! offspring distributed around one parent (biased along the direction to
//! the parent centroid), and replaces two random population members with
//! the best of the combined family. Like PSO, the paper finds it prone to
//! local minima on this discrete, constraint-riddled landscape.

use super::{BestTracker, OptResult, Optimizer, Problem, SearchBudget};
use crate::space::Design;
use crate::util::rng::Rng;
use std::time::Instant;

pub struct G3Pcx {
    pub budget: SearchBudget,
    /// PCX variance along the centroid direction.
    pub sigma_zeta: f64,
    /// PCX variance orthogonal to it.
    pub sigma_eta: f64,
    /// Offspring per step.
    pub offspring: usize,
}

impl G3Pcx {
    pub fn new(budget: SearchBudget) -> G3Pcx {
        G3Pcx {
            budget,
            sigma_zeta: 0.1,
            sigma_eta: 0.1,
            offspring: 2,
        }
    }
}

impl Optimizer for G3Pcx {
    fn name(&self) -> String {
        "G3PCX".into()
    }

    fn run(&self, problem: &dyn Problem, rng: &mut Rng) -> OptResult {
        let t0 = Instant::now();
        let space = problem.space();
        let n = space.params.len();
        let pop_n = self.budget.pop;
        let mut tracker = BestTracker::default();
        let mut evals = 0usize;

        let mut xs: Vec<Vec<f64>> = (0..pop_n)
            .map(|_| {
                problem
                    .random_candidate(rng)
                    .0
                    .iter()
                    .map(|&v| v as f64)
                    .collect()
            })
            .collect();
        let designs: Vec<Design> = xs.iter().map(|x| space.clamp_round(x)).collect();
        let mut scores = problem.score_batch(&designs);
        evals += pop_n;
        tracker.observe(&designs, &scores);
        tracker.end_generation();

        // G3 runs (gens-1) * pop/offspring family steps so total
        // evaluations match the generational algorithms' budget.
        let steps = (self.budget.gens - 1) * pop_n / self.offspring;
        for step in 0..steps {
            // parents: the best + 2 distinct random
            let best = (0..pop_n)
                .min_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap())
                .unwrap();
            let mut r1 = rng.below(pop_n);
            while r1 == best {
                r1 = rng.below(pop_n);
            }
            let mut r2 = rng.below(pop_n);
            while r2 == best || r2 == r1 {
                r2 = rng.below(pop_n);
            }
            let parents = [best, r1, r2];
            // centroid
            let g: Vec<f64> = (0..n)
                .map(|i| parents.iter().map(|&p| xs[p][i]).sum::<f64>() / 3.0)
                .collect();

            // offspring around the best parent
            let mut fam_x: Vec<Vec<f64>> = Vec::with_capacity(self.offspring);
            for _ in 0..self.offspring {
                let p = best;
                let d: Vec<f64> = (0..n).map(|i| g[i] - xs[p][i]).collect();
                let d_norm = d.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-9);
                let x: Vec<f64> = (0..n)
                    .map(|i| {
                        let hi = space.params[i].cardinality() as f64 - 1.0;
                        let along = self.sigma_zeta * rng.normal() * d[i];
                        // orthogonal perturbation approximated per-axis,
                        // scaled by the average parent spread
                        let spread = (xs[r1][i] - xs[r2][i]).abs().max(0.5);
                        let ortho = self.sigma_eta * rng.normal() * spread * d_norm
                            / d_norm;
                        (xs[p][i] + along + ortho).clamp(0.0, hi)
                    })
                    .collect();
                fam_x.push(x);
            }
            let fam_d: Vec<Design> = fam_x.iter().map(|x| space.clamp_round(x)).collect();
            let fam_s = problem.score_batch(&fam_d);
            evals += fam_d.len();
            tracker.observe(&fam_d, &fam_s);
            if (step + 1) % (pop_n / self.offspring).max(1) == 0 {
                tracker.end_generation();
            }

            // replacement: two random slots compete with the family
            for (fx, &fs) in fam_x.iter().zip(&fam_s) {
                let slot = rng.below(pop_n);
                if fs < scores[slot] {
                    xs[slot] = fx.clone();
                    scores[slot] = fs;
                }
            }
        }
        tracker.into_result(self.name(), evals, t0.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::testutil::Sphere;
    use crate::space::SearchSpace;

    #[test]
    fn improves_over_initial_population() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let g3 = G3Pcx::new(SearchBudget { pop: 20, gens: 15 });
        let r = g3.run(&p, &mut Rng::seed_from(11));
        assert!(r.best_score.is_finite());
        assert!(r.history.last().unwrap() <= &r.history[0]);
    }

    #[test]
    fn eval_budget_close_to_generational() {
        let p = Sphere::centered(SearchSpace::rram_reduced());
        let budget = SearchBudget { pop: 20, gens: 10 };
        let g3 = G3Pcx::new(budget);
        let r = g3.run(&p, &mut Rng::seed_from(12));
        let generational = budget.pop * budget.gens;
        assert!(
            r.evals >= generational / 2 && r.evals <= generational * 2,
            "evals {} vs budget {}",
            r.evals,
            generational
        );
    }
}
