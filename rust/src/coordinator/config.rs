//! Experiment configuration / context shared by the CLI, experiments,
//! benches and examples.

use crate::model::MemoryTech;
use crate::objective::{Objective, ObjectiveKind};
use crate::robustness::RobustConfig;
use crate::runtime::Engine;
use crate::scenarios::ScenarioSpec;
use crate::search::SearchBudget;
use crate::space::SearchSpace;
use crate::util::cli::Args;
use crate::workloads::WorkloadSet;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use super::{EvalBackend, JointProblem};

/// Which evaluation backend experiments should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendChoice {
    /// Use the AOT PJRT artifacts; error if missing.
    Pjrt,
    /// Use the native analytical evaluator.
    Native,
    /// Prefer PJRT, fall back to native with a notice (default).
    Auto,
}

/// Shared experiment context.
pub struct ExpContext {
    pub seed: u64,
    /// Reduced budgets for CI smoke runs (`--quick`).
    pub quick: bool,
    pub backend_choice: BackendChoice,
    pub out_dir: PathBuf,
    /// Worker threads for parallel population evaluation. Defaults to the
    /// machine's available parallelism; override with `--threads N` or the
    /// `IMCOPT_THREADS` environment variable (scores are identical for any
    /// value — only throughput changes).
    pub threads: usize,
    /// Replace wall-clock readings in reports with a stable placeholder
    /// (`--stable`). Timing columns are the only nondeterministic report
    /// content, so under this flag every report is a pure function of the
    /// seed — the property the checkpoint/resume bit-identity test and
    /// golden-file tests rely on.
    pub stable: bool,
    /// Resume from the checkpoint journals under `out_dir` (`--resume`):
    /// completed experiments replay their stored reports, completed cells
    /// inside partially-run experiments are not re-evaluated.
    pub resume: bool,
    /// Reported best-design count per search where an experiment supports
    /// it (`--topk`; `genmatrix` emits this many designs per cell).
    pub top_k: usize,
    /// Largest hold-out size swept by the `genmatrix_k` experiment
    /// (`--hold-k K`: every k in `1..=K` runs, clamped per set to
    /// `len − 1`). Defaults to 2; the paper-breadth sweep is `--hold-k 3`.
    pub hold_k: usize,
    /// Restrict the `transfer` experiment to a comma-separated list of
    /// portfolio ids (`--portfolio a,b`); `None` runs every registered
    /// transfer portfolio (see `scenarios::transfer_portfolios`).
    pub portfolio: Option<String>,
    /// Objective-vector mode(s) of the `pareto` experiment
    /// (`--moo-mode metric|workload`); `None` runs both modes.
    pub moo_mode: Option<String>,
    /// Pareto-archive capacity (`--pareto-cap`): the `pareto`
    /// experiment's reported fronts never exceed this many points.
    pub pareto_cap: usize,
    /// User-defined scenario family (`--spec <w1>+<w2>+...:<mem>[:<agg>]`
    /// with canonical names or `.json`/`.onnx` paths as workload tokens,
    /// or `synth:<dist>:<n>:<seed>[...]` for a seeded synthetic
    /// population; see `scenarios::ScenarioSpec::parse`), honored by
    /// `genmatrix_k`, `transfer`, `population` and `pareto`; `None` runs
    /// the paper families (`population`: the default 200-net synthetic
    /// family derived from the seed).
    pub spec: Option<String>,
    /// Surrogate screening fraction for the GA/NSGA-II generation loops
    /// (`--screen-frac`, clamped to `[0.05, 1.0]`). At the default `1.0`
    /// the exact loops run unchanged (bit-identical to pre-surrogate
    /// builds); below `1.0` only this fraction of each generation's
    /// offspring pool reaches the exact evaluator (see
    /// `search::surrogate::ScreenState` and `docs/search.md`). Part of
    /// the checkpoint config fingerprint, so `--resume` never mixes
    /// screened and exact cells.
    pub screen_frac: f64,
    /// Robust-objective mode (`--robust worst|cvar<q>|mean`): when set,
    /// accuracy-aware searches score the aggregate over a seeded
    /// device-variation [`PerturbationEnsemble`] instead of the nominal
    /// operating point (see `docs/robustness.md`). `None` (the default)
    /// leaves every loop bit-identical to non-robust builds. Part of the
    /// checkpoint config fingerprint and forwarded in orchestrator
    /// worker argv.
    ///
    /// [`PerturbationEnsemble`]: crate::robustness::PerturbationEnsemble
    pub robust: Option<String>,
    /// Minimum nominal accuracy a design must reach on every active
    /// workload before it can enter a Pareto front (`--acc-floor`,
    /// constraint-domination in `pareto::VectorObjective`); `None` (the
    /// default) disables the floor. Also part of the config fingerprint.
    pub acc_floor: Option<f64>,
    /// Worker processes for `imcopt run` (`--workers N`): 1 (the default)
    /// runs in-process, more spawn the orchestrator supervisor. Excluded
    /// from the checkpoint config fingerprint — cells are deterministic at
    /// any worker count, so journals resume across counts.
    pub workers: usize,
    /// Set (from `IMCOPT_WORKER_ID`) when this process *is* an
    /// orchestrator worker.
    pub worker_id: Option<usize>,
    /// Degradation notices accumulated mid-run (e.g. a requested PJRT
    /// engine failing to load), surfaced in reports instead of aborting
    /// the sweep.
    backend_notices: Mutex<Vec<String>>,
    /// Lazily loaded PJRT engine, shared across experiments.
    engine: Mutex<Option<Option<Arc<Mutex<Engine>>>>>,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            seed: 42,
            quick: false,
            backend_choice: BackendChoice::Auto,
            out_dir: PathBuf::from("results"),
            threads: crate::util::pool::default_threads(),
            stable: false,
            resume: false,
            top_k: 5,
            hold_k: 2,
            portfolio: None,
            moo_mode: None,
            pareto_cap: 128,
            spec: None,
            screen_frac: 1.0,
            robust: None,
            acc_floor: None,
            workers: 1,
            worker_id: None,
            backend_notices: Mutex::new(Vec::new()),
            engine: Mutex::new(None),
        }
    }
}

impl ExpContext {
    /// Build from CLI arguments (`--seed`, `--quick`, `--native`,
    /// `--pjrt`, `--out-dir`/`--out`, `--threads`, `--stable`,
    /// `--resume`, `--topk`, `--hold-k`, `--portfolio`, `--moo-mode`,
    /// `--pareto-cap`, `--spec`, `--screen-frac`, `--robust`,
    /// `--acc-floor`).
    pub fn from_args(args: &Args) -> ExpContext {
        let backend_choice = if args.flag("native") {
            BackendChoice::Native
        } else if args.flag("pjrt") {
            BackendChoice::Pjrt
        } else {
            BackendChoice::Auto
        };
        let out_dir = args
            .opt("out-dir")
            .or_else(|| args.opt("out"))
            .unwrap_or("results");
        ExpContext {
            seed: args.opt_u64("seed", 42),
            quick: args.flag("quick"),
            backend_choice,
            out_dir: PathBuf::from(out_dir),
            threads: args.opt_usize("threads", crate::util::pool::default_threads()),
            stable: args.flag("stable"),
            resume: args.flag("resume"),
            top_k: args.opt_usize("topk", 5),
            hold_k: args.opt_usize("hold-k", 2).max(1),
            portfolio: args.opt("portfolio").map(String::from),
            moo_mode: args.opt("moo-mode").map(String::from),
            pareto_cap: args.opt_usize("pareto-cap", 128).max(1),
            spec: args.opt("spec").map(String::from),
            screen_frac: args.opt_f64("screen-frac", 1.0).clamp(0.05, 1.0),
            robust: args.opt("robust").map(String::from),
            acc_floor: args
                .opt("acc-floor")
                .and_then(|v| v.parse::<f64>().ok())
                .filter(|f| f.is_finite() && *f > 0.0 && *f < 1.0),
            workers: args.opt_usize("workers", 1).max(1),
            worker_id: std::env::var("IMCOPT_WORKER_ID")
                .ok()
                .and_then(|v| v.parse().ok()),
            ..ExpContext::default()
        }
    }

    /// CI-friendly quick context for tests.
    pub fn quick(seed: u64) -> ExpContext {
        ExpContext {
            seed,
            quick: true,
            backend_choice: BackendChoice::Native,
            out_dir: std::env::temp_dir().join("imcopt-results"),
            ..ExpContext::default()
        }
    }

    /// The paper's search budget, or a reduced one under `--quick`.
    pub fn budget(&self) -> SearchBudget {
        if self.quick {
            SearchBudget { pop: 12, gens: 8 }
        } else {
            SearchBudget::paper()
        }
    }

    /// Sampling pool sizes `(P_H, P_E)` (paper: 1000/500).
    pub fn sampling(&self) -> (usize, usize) {
        if self.quick {
            (80, 40)
        } else {
            (1000, 500)
        }
    }

    /// Number of repeated independent runs for variance experiments.
    pub fn repeats(&self, full: usize) -> usize {
        if self.quick {
            2.min(full)
        } else {
            full
        }
    }

    /// Format a wall-clock reading for a report: real time normally, a
    /// stable placeholder under `--stable` (see [`ExpContext::stable`]).
    pub fn fmt_wall(&self, d: std::time::Duration) -> String {
        if self.stable {
            "-".into()
        } else {
            crate::util::fmt_duration(d)
        }
    }

    /// Format a wall-clock-derived ratio (`1.50x`), stable-aware.
    pub fn fmt_ratio(&self, x: f64) -> String {
        if self.stable {
            "-".into()
        } else {
            format!("{x:.2}x")
        }
    }

    /// Format a wall-clock-derived percentage (`30%`), stable-aware.
    pub fn fmt_pct(&self, x: f64) -> String {
        if self.stable {
            "-".into()
        } else {
            format!("{x:.0}%")
        }
    }

    /// Get (or lazily load) the shared PJRT engine; `None` when artifacts
    /// are unavailable or the backend choice is native.
    pub fn engine(&self) -> Option<Arc<Mutex<Engine>>> {
        if self.backend_choice == BackendChoice::Native {
            return None;
        }
        let mut slot = self.engine.lock().unwrap();
        if slot.is_none() {
            let loaded = match Engine::load_default() {
                Ok(e) => Some(Arc::new(Mutex::new(e))),
                Err(e) => {
                    // Degrade instead of panicking: the native evaluator is
                    // always available, so a mid-run PJRT failure costs the
                    // sweep nothing but speed. Under an explicit `--pjrt`
                    // the notice is recorded so reports surface it (and
                    // `require_backend` turns it into a startup error).
                    if self.backend_choice == BackendChoice::Pjrt {
                        self.record_notice(format!(
                            "--pjrt requested but artifacts unavailable ({e}); \
                             fell back to the native evaluator"
                        ));
                    }
                    eprintln!(
                        "[imcopt] artifacts unavailable ({e}); using native evaluator"
                    );
                    None
                }
            };
            *slot = Some(loaded);
        }
        slot.as_ref().unwrap().clone()
    }

    /// Record a degradation notice (deduplicated) for reports to surface.
    /// Occurrence counts live in the telemetry counters, not here: reports
    /// keep one line per distinct notice and render an `(xN)` suffix from
    /// [`crate::telemetry::notice_count`] when N > 1.
    pub fn record_notice(&self, notice: String) {
        crate::telemetry::count_notice(&notice);
        let mut notices = self.backend_notices.lock().unwrap();
        if !notices.contains(&notice) {
            notices.push(notice);
        }
    }

    /// Degradation notices recorded so far.
    pub fn notices(&self) -> Vec<String> {
        self.backend_notices.lock().unwrap().clone()
    }

    /// Fail fast when an explicitly requested backend cannot be provided:
    /// `--pjrt` with no loadable artifacts is a proper CLI error here
    /// instead of a mid-sweep panic. (`Auto` silently falls back; `Native`
    /// never loads an engine.)
    pub fn require_backend(&self) -> anyhow::Result<()> {
        if self.backend_choice == BackendChoice::Pjrt && self.engine().is_none() {
            let notice = self
                .notices()
                .into_iter()
                .next()
                .unwrap_or_else(|| "--pjrt requested but artifacts unavailable".into());
            anyhow::bail!("{notice} (run with --native, or provide the PJRT artifacts)");
        }
        Ok(())
    }

    /// Construct the evaluation backend for a memory technology.
    pub fn backend(&self, mem: MemoryTech) -> EvalBackend {
        match self.engine() {
            Some(engine) => EvalBackend::Pjrt(engine, mem),
            None => EvalBackend::native(mem),
        }
    }

    /// Monte-Carlo draws per corner for `--robust` ensembles (reduced
    /// under `--quick`, like every other budget knob).
    pub fn robust_draws(&self) -> usize {
        if self.quick {
            2
        } else {
            8
        }
    }

    /// Parse the `--robust` flag into a resolved [`RobustConfig`]
    /// (corners-and-draws ensemble seeded from `--seed`); `None` when the
    /// flag is unset, an error on an unparsable mode. `imcopt run`
    /// validates this once at startup, so later callers may `expect`.
    pub fn robust_config(&self) -> anyhow::Result<Option<RobustConfig>> {
        match &self.robust {
            None => Ok(None),
            Some(mode) => Ok(Some(RobustConfig::from_flag(
                mode,
                self.seed,
                self.robust_draws(),
            )?)),
        }
    }

    /// Convenience: build a joint problem wired to this context's backend
    /// and worker-thread count (`--threads` / `IMCOPT_THREADS`). With
    /// `--robust` set and an accuracy-aware objective, the robust
    /// configuration is attached (non-accuracy objectives never see it,
    /// so their scores and config keys stay byte-identical).
    pub fn problem<'a>(
        &self,
        space: &'a SearchSpace,
        workloads: &'a WorkloadSet,
        mem: MemoryTech,
        objective: Objective,
    ) -> JointProblem<'a> {
        let robust = if objective.kind == ObjectiveKind::EdapAccuracy {
            self.robust_config().expect("--robust validated at startup")
        } else {
            None
        };
        JointProblem::with_backend(space, workloads, self.backend(mem), objective)
            .with_threads(self.threads)
            .with_robust(robust)
    }

    /// Build the joint problem of a scenario spec. A corner spec
    /// (`--spec …:<corner>`) pins the accuracy model to that single
    /// operating point — overriding any `--robust` ensemble, since the
    /// noise-sweep family asks "what does the front look like *at* this
    /// corner", not "robust to all corners".
    pub fn spec_problem<'a>(&self, spec: &'a ScenarioSpec) -> JointProblem<'a> {
        let p = self.problem(&spec.space, &spec.set, spec.mem, spec.objective());
        match spec.corner {
            Some(c) => p.with_robust(Some(RobustConfig::at_corner(c))),
            None => p,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_context_reduces_budget() {
        let ctx = ExpContext::quick(1);
        assert!(ctx.budget().pop < SearchBudget::paper().pop);
        assert!(ctx.sampling().0 < 1000);
        assert_eq!(ctx.repeats(25), 2);
    }

    #[test]
    fn from_args_parses_backend() {
        let args = Args::parse(
            ["exp", "fig3", "--native", "--seed=7", "--quick"]
                .iter()
                .map(|s| s.to_string()),
        );
        let ctx = ExpContext::from_args(&args);
        assert_eq!(ctx.backend_choice, BackendChoice::Native);
        assert_eq!(ctx.seed, 7);
        assert!(ctx.quick);
        assert!(ctx.engine().is_none());
    }

    #[test]
    fn from_args_parses_registry_flags() {
        let args = Args::parse(
            ["run", "--stable", "--resume", "--out-dir", "/tmp/x", "--topk", "8"]
                .iter()
                .map(|s| s.to_string()),
        );
        let ctx = ExpContext::from_args(&args);
        assert!(ctx.stable);
        assert!(ctx.resume);
        assert_eq!(ctx.out_dir, PathBuf::from("/tmp/x"));
        assert_eq!(ctx.top_k, 8);
        // stable mode hides wall-clock readings from reports
        assert_eq!(ctx.fmt_wall(std::time::Duration::from_secs(1)), "-");
        assert_eq!(ctx.fmt_ratio(1.5), "-");
        let live = ExpContext::default();
        assert_eq!(live.fmt_ratio(1.5), "1.50x");
        assert_eq!(live.fmt_pct(30.4), "30%");
        // --out remains a working alias
        let args = Args::parse(["run", "--out", "r2"].iter().map(|s| s.to_string()));
        assert_eq!(ExpContext::from_args(&args).out_dir, PathBuf::from("r2"));
    }

    #[test]
    fn workers_flag_parses_and_clamps() {
        let args =
            Args::parse(["run", "--workers", "4"].iter().map(|s| s.to_string()));
        assert_eq!(ExpContext::from_args(&args).workers, 4);
        let args =
            Args::parse(["run", "--workers", "0"].iter().map(|s| s.to_string()));
        assert_eq!(ExpContext::from_args(&args).workers, 1);
        assert_eq!(ExpContext::from_args(&Args::default()).workers, 1);
    }

    #[test]
    fn missing_pjrt_artifacts_degrade_with_a_notice_not_a_panic() {
        // This environment has no PJRT artifacts, which is exactly the
        // failure the satellite fix covers.
        let mut ctx = ExpContext::quick(1);
        ctx.backend_choice = BackendChoice::Pjrt;
        if ctx.engine().is_some() {
            return; // artifacts actually present; nothing to degrade
        }
        assert!(
            ctx.notices().iter().any(|n| n.contains("native evaluator")),
            "explicit --pjrt failure must be recorded, got {:?}",
            ctx.notices()
        );
        let err = ctx.require_backend().unwrap_err();
        assert!(format!("{err}").contains("--native"), "{err}");
        // Auto mode degrades silently (no report-visible notice)
        let mut auto = ExpContext::quick(2);
        auto.backend_choice = BackendChoice::Auto;
        let _ = auto.engine();
        assert!(auto.notices().is_empty());
        auto.require_backend().unwrap();
        // notices deduplicate
        ctx.record_notice("x".into());
        ctx.record_notice("x".into());
        assert_eq!(ctx.notices().iter().filter(|n| *n == "x").count(), 1);
    }

    #[test]
    fn from_args_parses_portfolio_flags() {
        let args = Args::parse(
            ["run", "genmatrix_k", "--hold-k", "3", "--portfolio", "cnn4-to-extras"]
                .iter()
                .map(|s| s.to_string()),
        );
        let ctx = ExpContext::from_args(&args);
        assert_eq!(ctx.hold_k, 3);
        assert_eq!(ctx.portfolio.as_deref(), Some("cnn4-to-extras"));
        // pareto knobs default sensibly and parse
        assert!(ctx.moo_mode.is_none());
        assert_eq!(ctx.pareto_cap, 128);
        assert!(ctx.spec.is_none());
        let args = Args::parse(
            [
                "run", "pareto", "--moo-mode", "metric", "--pareto-cap", "32",
                "--spec", "resnet18+vgg16:rram",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let ctx = ExpContext::from_args(&args);
        assert_eq!(ctx.moo_mode.as_deref(), Some("metric"));
        assert_eq!(ctx.pareto_cap, 32);
        assert_eq!(ctx.spec.as_deref(), Some("resnet18+vgg16:rram"));
        // a zero cap clamps to 1
        let args =
            Args::parse(["run", "--pareto-cap", "0"].iter().map(|s| s.to_string()));
        assert_eq!(ExpContext::from_args(&args).pareto_cap, 1);
        // defaults: hold-k 2, every portfolio; 0 clamps to 1
        let ctx = ExpContext::from_args(&Args::parse(["run"].iter().map(|s| s.to_string())));
        assert_eq!(ctx.hold_k, 2);
        assert!(ctx.portfolio.is_none());
        let args = Args::parse(["run", "--hold-k", "0"].iter().map(|s| s.to_string()));
        assert_eq!(ExpContext::from_args(&args).hold_k, 1);
    }

    #[test]
    fn from_args_parses_robust_flags() {
        // defaults are off
        let ctx = ExpContext::from_args(&Args::parse(["run"].iter().map(|s| s.to_string())));
        assert!(ctx.robust.is_none());
        assert!(ctx.acc_floor.is_none());
        assert!(ctx.robust_config().unwrap().is_none());
        let args = Args::parse(
            ["run", "robustness", "--robust", "cvar0.25", "--acc-floor", "0.9"]
                .iter()
                .map(|s| s.to_string()),
        );
        let ctx = ExpContext::from_args(&args);
        assert_eq!(ctx.robust.as_deref(), Some("cvar0.25"));
        assert_eq!(ctx.acc_floor, Some(0.9));
        let rc = ctx.robust_config().unwrap().expect("configured");
        assert_eq!(rc.descriptor(), format!("cvar0.25@ens-s{}-k8", ctx.seed));
        // --quick shrinks the ensemble like every other budget knob
        let args = Args::parse(
            ["run", "--robust", "worst", "--quick"].iter().map(|s| s.to_string()),
        );
        let ctx = ExpContext::from_args(&args);
        assert_eq!(
            ctx.robust_config().unwrap().unwrap().ensemble.len(),
            3 + 3 * 2
        );
        // a bad mode is a startup error, out-of-range floors are dropped
        let args = Args::parse(
            ["run", "--robust", "median", "--acc-floor", "1.5"]
                .iter()
                .map(|s| s.to_string()),
        );
        let ctx = ExpContext::from_args(&args);
        assert!(ctx.robust_config().is_err());
        assert!(ctx.acc_floor.is_none());
    }

    #[test]
    fn spec_problem_pins_corner_robust_config() {
        let ctx = ExpContext::quick(3);
        let spec = ScenarioSpec::parse("resnet18+alexnet:rram:high").unwrap();
        let p = ctx.spec_problem(&spec);
        assert_eq!(
            p.robust().map(|rc| rc.descriptor()),
            Some("worst@corner-high".into())
        );
        assert!(p.config_key().contains("robust:worst@corner-high"));
        // corner-free specs stay robust-free (and key-identical to seed)
        let plain = ScenarioSpec::parse("resnet18+alexnet:rram").unwrap();
        let p = ctx.spec_problem(&plain);
        assert!(p.robust().is_none());
        assert!(!p.config_key().contains("robust:"));
    }

    #[test]
    fn from_args_parses_and_clamps_screen_frac() {
        // default is off (exact loops)
        let ctx = ExpContext::from_args(&Args::parse(["run"].iter().map(|s| s.to_string())));
        assert_eq!(ctx.screen_frac, 1.0);
        let args = Args::parse(
            ["run", "surrogate", "--screen-frac", "0.25"].iter().map(|s| s.to_string()),
        );
        assert_eq!(ExpContext::from_args(&args).screen_frac, 0.25);
        // out-of-range values clamp instead of poisoning the sweep
        let args =
            Args::parse(["run", "--screen-frac", "0.0"].iter().map(|s| s.to_string()));
        assert_eq!(ExpContext::from_args(&args).screen_frac, 0.05);
        let args =
            Args::parse(["run", "--screen-frac", "7"].iter().map(|s| s.to_string()));
        assert_eq!(ExpContext::from_args(&args).screen_frac, 1.0);
        // unparsable falls back to the default
        let args =
            Args::parse(["run", "--screen-frac", "x"].iter().map(|s| s.to_string()));
        assert_eq!(ExpContext::from_args(&args).screen_frac, 1.0);
    }
}
