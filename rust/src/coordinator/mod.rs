//! L3 coordinator: joint-evaluation problem, backend routing, caching and
//! the experiment context (the paper's system contribution lives in
//! `search`; this module wires search to evaluation).
//!
//! The search loop scores populations through [`JointProblem`], which
//! computes each design's cache key (`SearchSpace::linear_index`) exactly
//! once per call, resolves hits against a 16-way **sharded** memo cache
//! (`util::shards::ShardedCache`, striped locks keyed by `key % SHARDS`),
//! and evaluates misses in parallel on `threads` workers
//! (`util::pool::parallel_map`; configured by `--threads` /
//! `IMCOPT_THREADS` via [`ExpContext`]).
//!
//! Threading model per backend:
//!
//! * **Native** — design-major fan-out: each worker evaluates one design
//!   across the whole active workload set and scores it, so the batch
//!   scales with cores and per-design results are bit-identical to the
//!   sequential path (every design's evaluation is independent and
//!   deterministic; the accuracy-proxy memo computes under its stripe
//!   lock, so cache contents are thread-count-invariant too). Each
//!   (design, workload) evaluation is itself O(1): `NativeEvaluator`
//!   reads the workload's compiled aggregate tables
//!   (`model::compiled::CompiledWorkload`) instead of walking layers.
//! * **PJRT** — executions stay batched per workload, chunked by
//!   `Engine::max_fitness_batch`; the engine `Mutex` is held **per
//!   execution only**, and a dedicated scorer thread overlaps the
//!   native-side decode/score/accuracy work of completed chunks with the
//!   artifact runs of later chunks.

pub mod config;

use crate::accuracy;
use crate::model::{MemoryTech, Metrics, NativeEvaluator};
use crate::objective::{Aggregation, Objective, ObjectiveKind};
use crate::robustness::RobustConfig;
use crate::runtime::Engine;
use crate::search::Problem;
use crate::space::{idx, Design, SearchSpace};
use crate::telemetry;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::shards::ShardedCache;
use crate::workloads::WorkloadSet;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

pub use config::ExpContext;

/// Evaluation backend for hardware metrics.
#[derive(Clone)]
pub enum EvalBackend {
    /// Closed-form Rust evaluator (oracle / fallback).
    Native(NativeEvaluator),
    /// AOT JAX/Pallas fitness artifact via PJRT (the production hot path).
    Pjrt(Arc<Mutex<Engine>>, MemoryTech),
}

impl EvalBackend {
    pub fn native(mem: MemoryTech) -> EvalBackend {
        EvalBackend::Native(NativeEvaluator::new(mem))
    }

    pub fn mem(&self) -> MemoryTech {
        match self {
            EvalBackend::Native(ev) => ev.mem,
            EvalBackend::Pjrt(_, mem) => *mem,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvalBackend::Native(_) => "native",
            EvalBackend::Pjrt(..) => "pjrt",
        }
    }
}

/// Per-design evaluation record (metrics per workload + accuracies when
/// the objective needs them).
#[derive(Clone, Debug)]
pub struct Evaluations {
    pub metrics: Vec<Metrics>,
    pub accuracies: Option<Vec<f64>>,
    pub score: f64,
}

/// The joint hardware-workload co-optimization problem (paper Fig. 2).
pub struct JointProblem<'a> {
    pub space: &'a SearchSpace,
    pub workloads: &'a WorkloadSet,
    pub backend: EvalBackend,
    pub objective: Objective,
    /// Restrict joint evaluation to this subset of workload indices
    /// (used by "separate search" baselines). `None` = all workloads.
    pub subset: Option<Vec<usize>>,
    /// Worker threads for miss evaluation (1 = sequential).
    threads: usize,
    cache: ShardedCache<u64, Evaluations>,
    evals: AtomicUsize,
    /// Cache for the (expensive) accuracy proxy keyed by (rows, cols,
    /// bits, perturbation id) — the design parameters the noise model
    /// depends on, plus which [`RobustConfig`] ensemble member (if any)
    /// transformed the noise spec. Id 0 is the unperturbed nominal path;
    /// ids `1..=N` index `robust.ensemble.members`.
    acc_cache: ShardedCache<(u16, u16, u16, u16), f64>,
    /// Robust-objective configuration (`--robust`): when set and the
    /// objective is accuracy-aware, scores aggregate over the
    /// perturbation ensemble instead of the nominal point alone.
    robust: Option<RobustConfig>,
}

impl<'a> JointProblem<'a> {
    pub fn new(
        space: &'a SearchSpace,
        workloads: &'a WorkloadSet,
        evaluator: NativeEvaluator,
        objective: Objective,
        agg: Aggregation,
    ) -> JointProblem<'a> {
        let mut objective = objective;
        objective.agg = agg;
        JointProblem::with_backend(space, workloads, EvalBackend::Native(evaluator), objective)
    }

    pub fn with_backend(
        space: &'a SearchSpace,
        workloads: &'a WorkloadSet,
        backend: EvalBackend,
        objective: Objective,
    ) -> JointProblem<'a> {
        JointProblem {
            space,
            workloads,
            backend,
            objective,
            subset: None,
            threads: pool::default_threads(),
            cache: ShardedCache::new(),
            evals: AtomicUsize::new(0),
            acc_cache: ShardedCache::new(),
            robust: None,
        }
    }

    /// Set the worker-thread count for miss evaluation (builder-style).
    /// Scores and cache contents are identical for any thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Attach a robust-objective configuration (builder-style). Only
    /// meaningful for [`ObjectiveKind::EdapAccuracy`]; `None` (the
    /// default) keeps every score bit-identical to the nominal path.
    /// The config joins [`JointProblem::config_key`] and
    /// [`JointProblem::acc_scope`] so persisted memos never mix across
    /// ensembles or modes.
    pub fn with_robust(mut self, robust: Option<RobustConfig>) -> Self {
        self.robust = robust;
        self
    }

    /// The attached robust configuration, if any.
    pub fn robust(&self) -> Option<&RobustConfig> {
        self.robust.as_ref()
    }

    /// Restrict to a single workload (the paper's "separate search").
    pub fn restricted(mut self, workload_index: usize) -> Self {
        assert!(workload_index < self.workloads.len());
        self.subset = Some(vec![workload_index]);
        self
    }

    /// Restrict joint evaluation to an arbitrary workload subset — the
    /// training side of a [`crate::scenarios::Portfolio`] (`genmatrix`
    /// optimizes on N−1 workloads, `genmatrix_k`/`transfer` on any train
    /// set). Indices are deduplicated and sorted so equal subsets produce
    /// equal scores and memo-cache contents regardless of caller order.
    ///
    /// ```
    /// use imcopt::prelude::*;
    ///
    /// let space = SearchSpace::rram();
    /// let set = WorkloadSet::cnn4();
    /// let problem = JointProblem::with_backend(
    ///     &space,
    ///     &set,
    ///     EvalBackend::native(MemoryTech::Rram),
    ///     Objective::edap(),
    /// )
    /// .restricted_to(vec![2, 0, 2]); // normalized to {0, 2}
    ///
    /// let mut rng = Rng::seed_from(1);
    /// let d = space.random(&mut rng);
    /// // the joint score sees only the two active workloads ...
    /// assert_eq!(problem.evaluate_design(&d).metrics.len(), 2);
    /// // ... but cross-reporting still covers the full set
    /// assert_eq!(problem.metrics_all_workloads(&d).len(), set.len());
    /// ```
    pub fn restricted_to(mut self, mut indices: Vec<usize>) -> Self {
        indices.sort_unstable();
        indices.dedup();
        assert!(!indices.is_empty(), "subset must keep at least one workload");
        assert!(indices.iter().all(|&i| i < self.workloads.len()));
        self.subset = Some(indices);
        self
    }

    fn active_indices(&self) -> Vec<usize> {
        self.subset
            .clone()
            .unwrap_or_else(|| (0..self.workloads.len()).collect())
    }

    /// Per-layer eps for one noise spec: the AOT noisy-crossbar proxy
    /// when available, with the analytical model as fallback.
    fn eps_for_spec(&self, spec: &accuracy::NoiseSpec) -> f64 {
        if let EvalBackend::Pjrt(engine, _) = &self.backend {
            let eng = engine.lock().unwrap();
            if eng.has_accproxy() {
                if let Ok(eps) = eng.accproxy_eps(spec.weight_sigma(), spec.ir_drop) {
                    return eps;
                }
            }
        }
        accuracy::analytical_eps(spec, 1)
    }

    /// Memoized per-layer eps at one perturbation id (0 = nominal,
    /// `1..=N` = ensemble member `pert - 1` of the attached
    /// [`RobustConfig`]). The sharded stripe lock is held during the
    /// computation, so concurrent workers compute each key exactly once.
    fn per_layer_eps(&self, raw: &[f64; 10], d: &Design, pert: u16) -> f64 {
        let key = (d.0[idx::ROWS], d.0[idx::COLS], d.0[idx::BITS_CELL], pert);
        let mut missed = false;
        let eps = self.acc_cache.get_or_insert_with(key, || {
            missed = true;
            let spec = accuracy::NoiseSpec::from_design(raw, self.backend.mem());
            let spec = match (&self.robust, pert) {
                (Some(rc), p) if p > 0 => {
                    rc.ensemble.members[(p - 1) as usize].apply(&spec)
                }
                _ => spec,
            };
            self.eps_for_spec(&spec)
        });
        telemetry::acc_memo_lookup(missed);
        eps
    }

    /// Accuracy estimates per active workload for one design at one
    /// perturbation id (Fig. 8; id 0 reproduces the paper's nominal
    /// operating point).
    fn accuracies_at(&self, raw: &[f64; 10], d: &Design, pert: u16) -> Vec<f64> {
        let per_layer_eps = self.per_layer_eps(raw, d, pert);
        self.active_indices()
            .iter()
            .map(|&wi| {
                let w = &self.workloads.workloads[wi];
                let eps = per_layer_eps * (w.mapped_layers() as f64).sqrt();
                let (base, chance) = accuracy::baseline(&w.name);
                accuracy::accuracy_from_eps(eps, base, chance)
            })
            .collect()
    }

    /// Nominal (unperturbed) accuracy estimates per active workload —
    /// used by accuracy-floor constraints and robustness reporting.
    /// Panics on workloads without a Fig. 8 baseline.
    pub fn nominal_accuracies(&self, d: &Design) -> Vec<f64> {
        let raw = self.space.decode(d);
        self.accuracies_at(&raw, d, 0)
    }

    /// Assemble the full evaluation record of one design from its
    /// per-workload metrics (accuracies + objective score). With a
    /// [`RobustConfig`] attached and an accuracy-aware objective, the
    /// score is the robust aggregate over the perturbation ensemble
    /// (hardware metrics are perturbation-invariant — only accuracies
    /// move); the recorded `accuracies` stay nominal for reporting.
    fn build_evaluation(
        &self,
        d: &Design,
        raw: &[f64; 10],
        metrics: Vec<Metrics>,
    ) -> Evaluations {
        let accuracies = if self.objective.kind == ObjectiveKind::EdapAccuracy {
            Some(self.accuracies_at(raw, d, 0))
        } else {
            None
        };
        let score = match (&self.robust, self.objective.kind) {
            (Some(rc), ObjectiveKind::EdapAccuracy) => {
                let mut member_scores: Vec<f64> = (0..rc.ensemble.len())
                    .map(|i| {
                        let accs = self.accuracies_at(raw, d, (i + 1) as u16);
                        self.objective
                            .score(&metrics, Some(&accs), raw[idx::TECH_NM])
                    })
                    .collect();
                rc.mode.aggregate(&mut member_scores)
            }
            _ => self
                .objective
                .score(&metrics, accuracies.as_deref(), raw[idx::TECH_NM]),
        };
        Evaluations {
            metrics,
            accuracies,
            score,
        }
    }

    /// Evaluate cache-missing designs (deduplicated by the caller) and
    /// return one record per input, in order. This is the parallel hot
    /// path; results are bit-identical for any thread count.
    fn evaluate_misses(&self, designs: &[&Design], raws: &[[f64; 10]]) -> Vec<Evaluations> {
        debug_assert_eq!(designs.len(), raws.len());
        let _span = telemetry::span(telemetry::Stage::EvaluateMisses);
        telemetry::exact_evals(raws.len());
        self.evals.fetch_add(raws.len(), Ordering::Relaxed);
        let active = self.active_indices();
        match &self.backend {
            EvalBackend::Native(ev) => {
                // design-major: each worker evaluates one design across the
                // whole active workload set and scores it
                let items: Vec<usize> = (0..raws.len()).collect();
                pool::parallel_map(&items, self.threads, |&i| {
                    let mut metrics = Vec::with_capacity(active.len());
                    for &wi in &active {
                        metrics.push(ev.evaluate(&raws[i], &self.workloads.workloads[wi]));
                    }
                    self.build_evaluation(designs[i], &raws[i], metrics)
                })
            }
            EvalBackend::Pjrt(engine, mem) => {
                // workload-major batched executions, chunked by the largest
                // compiled batch; the engine lock is held per execution
                // only, and a scorer thread overlaps the native-side
                // scoring of finished chunks with later artifact runs
                let maxb = engine.lock().unwrap().max_fitness_batch().max(1);
                let results: Vec<Mutex<Option<Evaluations>>> =
                    (0..raws.len()).map(|_| Mutex::new(None)).collect();
                std::thread::scope(|scope| {
                    let (tx, rx) =
                        std::sync::mpsc::channel::<(usize, Vec<Vec<Metrics>>)>();
                    let results_ref = &results;
                    scope.spawn(move || {
                        for (start, per_design) in rx {
                            let items: Vec<usize> = (0..per_design.len()).collect();
                            let evs = pool::parallel_map(&items, self.threads, |&j| {
                                self.build_evaluation(
                                    designs[start + j],
                                    &raws[start + j],
                                    per_design[j].clone(),
                                )
                            });
                            for (j, ev) in evs.into_iter().enumerate() {
                                *results_ref[start + j].lock().unwrap() = Some(ev);
                            }
                        }
                    });
                    let mut start = 0usize;
                    for chunk in raws.chunks(maxb) {
                        let mut per_design: Vec<Vec<Metrics>> =
                            vec![Vec::with_capacity(active.len()); chunk.len()];
                        for &wi in &active {
                            let w = &self.workloads.workloads[wi];
                            let ms = engine
                                .lock()
                                .unwrap()
                                .fitness(chunk, w, *mem)
                                .expect("PJRT fitness execution failed");
                            for (slot, m) in per_design.iter_mut().zip(ms) {
                                slot.push(m);
                            }
                        }
                        tx.send((start, per_design)).expect("scorer thread alive");
                        start += chunk.len();
                    }
                    drop(tx); // scorer drains and exits
                });
                results
                    .into_iter()
                    .map(|m| m.into_inner().unwrap().expect("chunk scored"))
                    .collect()
            }
        }
    }

    /// Full evaluation record for one design (used by experiment reports).
    /// The cache key is computed once; a hit returns the memoized record
    /// and a miss evaluates directly without re-entering `score_batch`.
    pub fn evaluate_design(&self, d: &Design) -> Evaluations {
        let key = self.space.linear_index(d);
        if let Some(ev) = self.cache.get(&key) {
            telemetry::eval_memo_hit((key % telemetry::EVAL_SHARDS as u64) as usize);
            return ev;
        }
        telemetry::eval_memo_miss();
        let raw = self.space.decode(d);
        let ev = self
            .evaluate_misses(&[d], std::slice::from_ref(&raw))
            .pop()
            .expect("one evaluation");
        self.cache.insert(key, ev.clone());
        ev
    }

    /// Per-workload metrics of a design on *all* workloads regardless of
    /// subset (for cross-reporting a separately-optimized design). The
    /// design is decoded once and evaluated against the full workload set
    /// in one pass (reusing the memo cache when it already covers it).
    pub fn metrics_all_workloads(&self, d: &Design) -> Vec<Metrics> {
        if self.subset.is_none() {
            if let Some(metrics) = self.cache.map_get(&self.space.linear_index(d), |ev| {
                ev.metrics.clone()
            }) {
                return metrics;
            }
        }
        let raw = self.space.decode(d);
        match &self.backend {
            EvalBackend::Native(ev) => {
                pool::parallel_map(&self.workloads.workloads, self.threads, |w| {
                    ev.evaluate(&raw, w)
                })
            }
            EvalBackend::Pjrt(engine, mem) => {
                // the artifact shape is (designs × one workload), so this
                // stays one execution per workload, but under a single lock
                // hold with a single decode
                let eng = engine.lock().unwrap();
                self.workloads
                    .workloads
                    .iter()
                    .map(|w| {
                        eng.fitness(std::slice::from_ref(&raw), w, *mem)
                            .expect("PJRT fitness execution failed")[0]
                    })
                    .collect()
            }
        }
    }

    /// Number of cached distinct designs (diagnostics).
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// A string identifying everything the memo cache's contents depend
    /// on: space variant, workload set, active subset, backend memory
    /// technology and objective. The checkpoint subsystem keys persisted
    /// memo snapshots by this, so a snapshot is only ever replayed into an
    /// identically-configured problem.
    pub fn config_key(&self) -> String {
        let subset = match &self.subset {
            Some(s) => s
                .iter()
                .map(|i| i.to_string())
                .collect::<Vec<_>>()
                .join("+"),
            None => "all".to_string(),
        };
        let robust = match &self.robust {
            Some(rc) => format!("|robust:{}", rc.descriptor()),
            None => String::new(),
        };
        format!(
            "{}|{}|{}|{}|{}{robust}",
            self.space.variant,
            self.workloads.names().join(","),
            subset,
            self.backend.mem().name(),
            self.objective.name(),
        )
    }

    /// Snapshot of the evaluation memo, sorted by linear index (persisted
    /// per experiment by `experiments::checkpoint` to make resume warm).
    pub fn cache_snapshot(&self) -> Vec<(u64, Evaluations)> {
        self.cache.sorted_entries()
    }

    /// Preload memoized evaluations from a checkpoint snapshot. Entries
    /// must come from a problem with the same [`JointProblem::config_key`];
    /// preloading changes only throughput (fewer evaluator invocations on
    /// re-run), never scores.
    pub fn preload_cache(&self, entries: Vec<(u64, Evaluations)>) {
        for (k, v) in entries {
            self.cache.insert(k, v);
        }
    }

    /// A string identifying everything the accuracy-proxy memo's contents
    /// depend on: the space variant (index → `(rows, cols, bits)` decode),
    /// memory technology (noise spec) and the eps *source* — a PJRT
    /// engine with the accproxy artifact produces different eps than the
    /// analytical fallback, and the two must never mix across a resume,
    /// so artifact availability is part of the scope. The checkpoint
    /// subsystem keys persisted accuracy snapshots by this, independent of
    /// workload set/subset — the proxy is purely design-keyed, so it is
    /// shared across problems that agree on this scope.
    pub fn acc_scope(&self) -> String {
        let source = match &self.backend {
            EvalBackend::Native(_) => "analytical",
            EvalBackend::Pjrt(engine, _) => {
                if engine.lock().unwrap().has_accproxy() {
                    "accproxy"
                } else {
                    "analytical"
                }
            }
        };
        let robust = match &self.robust {
            Some(rc) => format!("|robust:{}", rc.descriptor()),
            None => String::new(),
        };
        format!(
            "{}|{}|{source}{robust}",
            self.space.variant,
            self.backend.mem().name(),
        )
    }

    /// Number of memoized accuracy-proxy entries (diagnostics).
    pub fn acc_cache_len(&self) -> usize {
        self.acc_cache.len()
    }

    /// Snapshot of the accuracy-proxy memo (per-layer eps keyed by the
    /// `(rows, cols, bits, perturbation id)` indices), sorted by key.
    pub fn acc_snapshot(&self) -> Vec<((u16, u16, u16, u16), f64)> {
        self.acc_cache.sorted_entries()
    }

    /// Preload accuracy-proxy memo entries from a checkpoint snapshot.
    /// Entries must come from a problem with the same
    /// [`JointProblem::acc_scope`]; like the evaluation memo, preloading
    /// changes only throughput, never scores.
    pub fn preload_acc_cache(&self, entries: Vec<((u16, u16, u16, u16), f64)>) {
        for (k, v) in entries {
            self.acc_cache.insert(k, v);
        }
    }

    /// Cached (linear index, score) pairs sorted by key — used by the
    /// thread-count-determinism tests to compare cache contents.
    pub fn cached_scores(&self) -> Vec<(u64, f64)> {
        self.cache
            .sorted_entries()
            .into_iter()
            .map(|(k, ev)| (k, ev.score))
            .collect()
    }
}

impl Problem for JointProblem<'_> {
    fn space(&self) -> &SearchSpace {
        self.space
    }

    fn score_batch(&self, designs: &[Design]) -> Vec<f64> {
        let _span = telemetry::span(telemetry::Stage::ScoreBatch);
        // one linear_index per design, computed exactly once
        let keys: Vec<u64> = designs.iter().map(|d| self.space.linear_index(d)).collect();
        // resolve cache hits, collect misses
        let mut out = vec![f64::NAN; designs.len()];
        let mut miss_idx: Vec<usize> = Vec::new();
        for (i, key) in keys.iter().enumerate() {
            match self.cache.map_get(key, |ev| ev.score) {
                Some(s) => {
                    telemetry::eval_memo_hit((key % telemetry::EVAL_SHARDS as u64) as usize);
                    out[i] = s;
                }
                None => {
                    telemetry::eval_memo_miss();
                    miss_idx.push(i);
                }
            }
        }
        if miss_idx.is_empty() {
            return out;
        }
        // de-duplicate misses within the batch (first occurrence wins,
        // deterministic order)
        let mut uniq: Vec<(u64, usize)> = Vec::new();
        {
            let mut seen: HashMap<u64, usize> = HashMap::new();
            for &i in &miss_idx {
                seen.entry(keys[i]).or_insert(i);
            }
            uniq.extend(seen.into_iter());
        }
        uniq.sort_by_key(|&(_, i)| i);
        let miss_designs: Vec<&Design> = uniq.iter().map(|&(_, i)| &designs[i]).collect();
        let miss_raws: Vec<[f64; 10]> = uniq
            .iter()
            .map(|&(_, i)| self.space.decode(&designs[i]))
            .collect();

        let evaluations = self.evaluate_misses(&miss_designs, &miss_raws);

        // cache + fill outputs (duplicates within the batch share the
        // unique design's record; no cache re-read needed)
        let mut miss_scores: HashMap<u64, f64> = HashMap::with_capacity(uniq.len());
        for ((key, _), ev) in uniq.iter().zip(evaluations) {
            miss_scores.insert(*key, ev.score);
            self.cache.insert(*key, ev);
        }
        for &i in &miss_idx {
            out[i] = miss_scores[&keys[i]];
        }
        out
    }

    /// Algorithm 1's initial-sampling feasibility pre-filter: only designs
    /// whose macro capacity covers the largest workload enter the pool. In
    /// the weight-stationary (RRAM) case the *whole* largest model must
    /// fit; in the weight-swapping (SRAM) case only its largest single
    /// layer must (a mild strengthening of the paper's pure random
    /// sampling — our analytical mapper, unlike CIMLoop's flexible
    /// temporal mapping, cannot split a layer across swap phases, so
    /// capacity-infeasible seeds would stall the search; see DESIGN.md).
    fn random_candidate(&self, rng: &mut Rng) -> Design {
        let mem = self.backend.mem();
        let largest = match mem {
            MemoryTech::Rram => {
                &self.workloads.workloads[self.workloads.largest_by_total()]
            }
            MemoryTech::Sram => {
                &self.workloads.workloads[self.workloads.largest_by_layer()]
            }
        };
        for _ in 0..500 {
            let d = self.space.random(rng);
            let raw = self.space.decode(&d);
            let view = crate::model::DesignView::new(&raw, mem);
            let (sum, max) = crate::model::xbar_demand(&view, largest);
            let demand = match mem {
                MemoryTech::Rram => sum,
                MemoryTech::Sram => max,
            };
            if demand <= view.macros {
                return d;
            }
        }
        self.space.random(rng)
    }

    /// Graded violation for stochastic ranking: capacity shortfall +
    /// area excess + timing violation, all normalized. O(1) per design:
    /// the area is the closed-form native model (~a dozen float ops,
    /// and the *same* model for every design — a cached PJRT metric
    /// would grade cached vs uncached designs with two different area
    /// models), and the capacity margins come from the compiled
    /// per-workload aggregate tables (`model::xbar_demand`) — never a
    /// full `score_batch` or layer walk.
    fn violation(&self, design: &Design) -> f64 {
        let raw = self.space.decode(design);
        let mem = self.backend.mem();
        let view = crate::model::DesignView::new(&raw, mem);
        let area = NativeEvaluator::new(mem).area(&raw);
        let mut v = (area / self.objective.area_constraint - 1.0).max(0.0);
        if !view.timing_ok {
            v += 0.5;
        }
        // capacity violation against the largest active workload
        let mut worst: f64 = 0.0;
        for &wi in &self.active_indices() {
            let w = &self.workloads.workloads[wi];
            let (sum_xb, max_xb) = crate::model::xbar_demand(&view, w);
            let demand = match mem {
                MemoryTech::Rram => sum_xb,
                MemoryTech::Sram => max_xb,
            };
            worst = worst.max((demand / view.macros - 1.0).max(0.0));
        }
        v + worst
    }

    fn evals(&self) -> usize {
        self.evals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{GaConfig, GeneticAlgorithm, Optimizer, SearchBudget};

    fn problem<'a>(
        space: &'a SearchSpace,
        set: &'a WorkloadSet,
        mem: MemoryTech,
    ) -> JointProblem<'a> {
        JointProblem::with_backend(
            space,
            set,
            EvalBackend::native(mem),
            Objective::edap(),
        )
    }

    #[test]
    fn caching_avoids_reevaluation() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let mut rng = Rng::seed_from(1);
        let d = space.random(&mut rng);
        let s1 = p.score_batch(std::slice::from_ref(&d))[0];
        let n1 = p.evals();
        let s2 = p.score_batch(std::slice::from_ref(&d))[0];
        assert_eq!(s1.to_bits(), s2.to_bits());
        assert_eq!(p.evals(), n1, "cache hit must not re-evaluate");
        // duplicate within one batch evaluates once
        let d2 = space.random(&mut rng);
        let before = p.evals();
        p.score_batch(&[d2.clone(), d2.clone(), d2]);
        assert_eq!(p.evals(), before + 1);
    }

    #[test]
    fn evaluate_design_caches_and_reuses() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let mut rng = Rng::seed_from(11);
        let d = p.random_candidate(&mut rng);
        let ev1 = p.evaluate_design(&d);
        let n = p.evals();
        // second call is a pure cache hit
        let ev2 = p.evaluate_design(&d);
        assert_eq!(p.evals(), n);
        assert_eq!(ev1.score.to_bits(), ev2.score.to_bits());
        // score_batch agrees with the record and hits the same cache
        let s = p.score_batch(std::slice::from_ref(&d))[0];
        assert_eq!(p.evals(), n);
        assert_eq!(s.to_bits(), ev1.score.to_bits());
        assert_eq!(p.cache_len(), 1);
    }

    #[test]
    fn score_batch_thread_invariant() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let mut rng = Rng::seed_from(12);
        let mut batch: Vec<Design> = (0..24).map(|_| space.random(&mut rng)).collect();
        // inject duplicates
        let dup = batch[3].clone();
        batch.push(dup.clone());
        batch.insert(7, dup);
        let p1 = problem(&space, &set, MemoryTech::Rram).with_threads(1);
        let p4 = problem(&space, &set, MemoryTech::Rram).with_threads(4);
        let s1 = p1.score_batch(&batch);
        let s4 = p4.score_batch(&batch);
        for (a, b) in s1.iter().zip(&s4) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let c1 = p1.cached_scores();
        let c4 = p4.cached_scores();
        assert_eq!(c1.len(), c4.len());
        for ((k1, v1), (k4, v4)) in c1.iter().zip(&c4) {
            assert_eq!(k1, k4);
            assert_eq!(v1.to_bits(), v4.to_bits());
        }
        assert_eq!(p1.evals(), p4.evals());
    }

    #[test]
    fn feasible_designs_exist_and_score_finite() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let mut rng = Rng::seed_from(2);
        let designs: Vec<Design> =
            (0..64).map(|_| p.random_candidate(&mut rng)).collect();
        let scores = p.score_batch(&designs);
        let finite = scores.iter().filter(|s| s.is_finite()).count();
        assert!(
            finite > 10,
            "capacity-prefiltered candidates should mostly be feasible ({finite}/64)"
        );
    }

    #[test]
    fn rram_prefilter_covers_vgg() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let mut rng = Rng::seed_from(3);
        for _ in 0..20 {
            let d = p.random_candidate(&mut rng);
            let raw = space.decode(&d);
            let view = crate::model::DesignView::new(&raw, MemoryTech::Rram);
            let vgg = &set.workloads[1];
            let needed: f64 = vgg
                .layers
                .iter()
                .map(|l| view.xbars_for(l.k as f64, l.n as f64))
                .sum();
            assert!(needed <= view.macros);
        }
    }

    #[test]
    fn restricted_problem_scores_single_workload() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p_all = problem(&space, &set, MemoryTech::Rram);
        let p_one = problem(&space, &set, MemoryTech::Rram).restricted(0);
        let mut rng = Rng::seed_from(4);
        let d = p_all.random_candidate(&mut rng);
        let ev_all = p_all.evaluate_design(&d);
        let ev_one = p_one.evaluate_design(&d);
        assert_eq!(ev_all.metrics.len(), 4);
        assert_eq!(ev_one.metrics.len(), 1);
        // single-workload joint score == that workload's own score
        assert!(ev_one.score <= ev_all.score || !ev_all.score.is_finite());
        // cross-reporting still covers the full set
        assert_eq!(p_one.metrics_all_workloads(&d).len(), 4);
    }

    #[test]
    fn restricted_to_subset_is_order_insensitive() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram).restricted_to(vec![2, 0, 2]);
        let mut rng = Rng::seed_from(21);
        let d = p.random_candidate(&mut rng);
        let ev = p.evaluate_design(&d);
        assert_eq!(ev.metrics.len(), 2);
        assert!(p.config_key().contains("|0+2|"), "{}", p.config_key());
        let p2 = problem(&space, &set, MemoryTech::Rram).restricted_to(vec![0, 2]);
        assert_eq!(p.config_key(), p2.config_key());
        assert_eq!(
            p2.evaluate_design(&d).score.to_bits(),
            ev.score.to_bits()
        );
        // full problem has a different key
        let p_all = problem(&space, &set, MemoryTech::Rram);
        assert_ne!(p_all.config_key(), p.config_key());
    }

    #[test]
    fn preload_cache_skips_reevaluation() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let mut rng = Rng::seed_from(22);
        let designs: Vec<Design> = (0..6).map(|_| p.random_candidate(&mut rng)).collect();
        let scores = p.score_batch(&designs);
        let snapshot = p.cache_snapshot();
        assert_eq!(snapshot.len(), p.cache_len());

        let q = problem(&space, &set, MemoryTech::Rram);
        q.preload_cache(snapshot);
        let warm = q.score_batch(&designs);
        assert_eq!(q.evals(), 0, "preloaded cache must satisfy every lookup");
        for (a, b) in scores.iter().zip(&warm) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn metrics_all_workloads_reuses_cache() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let mut rng = Rng::seed_from(13);
        let d = p.random_candidate(&mut rng);
        let ev = p.evaluate_design(&d);
        let n = p.evals();
        let ms = p.metrics_all_workloads(&d);
        assert_eq!(p.evals(), n, "cached record must be reused");
        for (a, b) in ms.iter().zip(&ev.metrics) {
            assert_eq!(a.energy.to_bits(), b.energy.to_bits());
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
        }
    }

    #[test]
    fn end_to_end_ga_on_native_backend() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let ga = GeneticAlgorithm::new(GaConfig {
            init: crate::search::InitStrategy::HammingDiverse { p_h: 60, p_e: 30 },
            ..GaConfig::four_phase(SearchBudget { pop: 12, gens: 8 })
        });
        let r = ga.run(&p, &mut Rng::seed_from(5));
        assert!(r.best_score.is_finite(), "GA found no feasible design");
        let ev = p.evaluate_design(&r.best);
        assert!(ev.metrics.iter().all(|m| m.feasible));
    }

    #[test]
    fn accuracy_objective_populates_accuracies() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max),
        );
        let mut rng = Rng::seed_from(6);
        let d = p.random_candidate(&mut rng);
        let ev = p.evaluate_design(&d);
        let accs = ev.accuracies.expect("accuracies required");
        assert_eq!(accs.len(), 4);
        assert!(accs.iter().all(|&a| a > 0.0 && a < 1.0));
    }

    #[test]
    fn acc_snapshot_roundtrips_and_scopes() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let acc_obj =
            Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max);
        let p = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            acc_obj,
        );
        let mut rng = Rng::seed_from(31);
        let designs: Vec<Design> =
            (0..6).map(|_| p.random_candidate(&mut rng)).collect();
        p.score_batch(&designs);
        assert!(p.acc_cache_len() > 0, "accuracy objective must memoize eps");
        let snap = p.acc_snapshot();
        assert_eq!(snap.len(), p.acc_cache_len());
        // keys sorted, values finite
        for pair in snap.windows(2) {
            assert!(pair[0].0 < pair[1].0);
        }
        let q = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            acc_obj,
        );
        assert_eq!(p.acc_scope(), q.acc_scope());
        q.preload_acc_cache(snap);
        assert_eq!(q.acc_cache_len(), p.acc_cache_len());
        // preloading never changes scores
        let warm = q.score_batch(&designs);
        for (a, b) in p.score_batch(&designs).iter().zip(&warm) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // a different memory tech / space is a different scope
        let sspace = SearchSpace::sram();
        let r = JointProblem::with_backend(
            &sspace,
            &set,
            EvalBackend::native(MemoryTech::Sram),
            acc_obj,
        );
        assert_ne!(p.acc_scope(), r.acc_scope());
    }

    #[test]
    fn robust_worst_never_beats_nominal() {
        use crate::robustness::RobustConfig;
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let acc_obj = Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max);
        let nominal = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            acc_obj,
        );
        let robust = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            acc_obj,
        )
        .with_robust(Some(RobustConfig::from_flag("worst", 9, 2).unwrap()));
        let mut rng = Rng::seed_from(41);
        let mut checked = 0;
        for _ in 0..32 {
            let d = nominal.random_candidate(&mut rng);
            let sn = nominal.evaluate_design(&d).score;
            if !sn.is_finite() {
                continue;
            }
            let sr = robust.evaluate_design(&d).score;
            // worst case over an ensemble containing the (identity)
            // nominal corner can only cost more
            assert!(sr >= sn * (1.0 - 1e-12), "robust {sr} < nominal {sn}");
            // the high corner strictly degrades RRAM accuracy
            assert!(sr > sn, "high corner must strictly worsen {sn}");
            checked += 1;
        }
        assert!(checked >= 3, "too few feasible probes ({checked})");
        // the robust problem memoizes one eps per perturbation id it saw
        assert!(robust.acc_cache_len() > nominal.acc_cache_len());
        // nominal accuracies are still reported (pert id 0)
        let d = nominal.random_candidate(&mut rng);
        let ev = robust.evaluate_design(&d);
        assert_eq!(ev.accuracies.as_ref().map(Vec::len), Some(4));
    }

    #[test]
    fn robust_config_scopes_keys() {
        use crate::robustness::RobustConfig;
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let acc_obj = Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max);
        let plain = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            acc_obj,
        );
        assert!(!plain.config_key().contains("robust:"));
        assert!(!plain.acc_scope().contains("robust:"));
        let rc = RobustConfig::from_flag("cvar0.5", 3, 1).unwrap();
        let r = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            acc_obj,
        )
        .with_robust(Some(rc.clone()));
        assert!(r.config_key().contains("robust:cvar0.5@ens-s3-k1"));
        assert!(r.acc_scope().contains("robust:cvar0.5@ens-s3-k1"));
        assert_ne!(plain.config_key(), r.config_key());
    }

    #[test]
    fn robust_ignored_for_non_accuracy_objectives() {
        use crate::robustness::RobustConfig;
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let plain = problem(&space, &set, MemoryTech::Rram);
        let r = problem(&space, &set, MemoryTech::Rram)
            .with_robust(Some(RobustConfig::from_flag("worst", 1, 1).unwrap()));
        let mut rng = Rng::seed_from(17);
        let designs: Vec<Design> =
            (0..8).map(|_| plain.random_candidate(&mut rng)).collect();
        for (a, b) in plain
            .score_batch(&designs)
            .iter()
            .zip(&r.score_batch(&designs))
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn violation_grades_area_excess() {
        let space = SearchSpace::sram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Sram);
        // max-everything SRAM design: far over the area budget but with
        // ample capacity and relaxed timing
        let huge = Design(
            space
                .params
                .iter()
                .map(|pd| (pd.cardinality() - 1) as u16)
                .collect(),
        );
        // a mid design that fits the largest layer and the area budget:
        // rows/cols 512, 32 macros/tile, 8 tiles, 16 groups, slow cycle
        let mid = space.clamp_round(&[4.0, 4.0, 3.0, 2.0, 5.0, 0.0, 4.0, 3.0, 4.0, 0.0]);
        assert!(p.violation(&huge) > 0.0, "huge must violate area");
        assert!(
            p.violation(&huge) > p.violation(&mid),
            "huge {} vs mid {}",
            p.violation(&huge),
            p.violation(&mid)
        );
        // graded, not binary: bigger excess -> bigger violation
        assert!(p.violation(&huge) > 0.1);
    }
}
