//! L3 coordinator: joint-evaluation problem, backend routing, caching and
//! the experiment context (the paper's system contribution lives in
//! `search`; this module wires search to evaluation).
//!
//! The search loop scores populations through [`JointProblem`], which
//! decodes designs, routes hardware evaluation to the AOT **PJRT artifact**
//! (default; Python never runs here) or the native analytical evaluator,
//! memoizes per-design metrics (GAs re-visit elites constantly), and
//! applies the configured objective across the workload set.

pub mod config;

use crate::accuracy;
use crate::model::{MemoryTech, Metrics, NativeEvaluator};
use crate::objective::{Aggregation, Objective, ObjectiveKind};
use crate::runtime::Engine;
use crate::search::Problem;
use crate::space::{idx, Design, SearchSpace};
use crate::util::rng::Rng;
use crate::workloads::WorkloadSet;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

pub use config::ExpContext;

/// Evaluation backend for hardware metrics.
#[derive(Clone)]
pub enum EvalBackend {
    /// Closed-form Rust evaluator (oracle / fallback).
    Native(NativeEvaluator),
    /// AOT JAX/Pallas fitness artifact via PJRT (the production hot path).
    Pjrt(Arc<Mutex<Engine>>, MemoryTech),
}

impl EvalBackend {
    pub fn native(mem: MemoryTech) -> EvalBackend {
        EvalBackend::Native(NativeEvaluator::new(mem))
    }

    pub fn mem(&self) -> MemoryTech {
        match self {
            EvalBackend::Native(ev) => ev.mem,
            EvalBackend::Pjrt(_, mem) => *mem,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvalBackend::Native(_) => "native",
            EvalBackend::Pjrt(..) => "pjrt",
        }
    }

    /// Evaluate a batch of decoded designs against one workload.
    fn eval_batch(
        &self,
        raws: &[[f64; 10]],
        workload: &crate::workloads::Workload,
    ) -> Vec<Metrics> {
        match self {
            EvalBackend::Native(ev) => {
                raws.iter().map(|r| ev.evaluate(r, workload)).collect()
            }
            EvalBackend::Pjrt(engine, mem) => engine
                .lock()
                .unwrap()
                .fitness(raws, workload, *mem)
                .expect("PJRT fitness execution failed"),
        }
    }
}

/// Per-design evaluation record (metrics per workload + accuracies when
/// the objective needs them).
#[derive(Clone, Debug)]
pub struct Evaluations {
    pub metrics: Vec<Metrics>,
    pub accuracies: Option<Vec<f64>>,
    pub score: f64,
}

/// The joint hardware-workload co-optimization problem (paper Fig. 2).
pub struct JointProblem<'a> {
    pub space: &'a SearchSpace,
    pub workloads: &'a WorkloadSet,
    pub backend: EvalBackend,
    pub objective: Objective,
    /// Restrict joint evaluation to this subset of workload indices
    /// (used by "separate search" baselines). `None` = all workloads.
    pub subset: Option<Vec<usize>>,
    cache: Mutex<HashMap<u64, Evaluations>>,
    evals: AtomicUsize,
    /// Cache for the (expensive) accuracy proxy keyed by (rows, cols,
    /// bits) — the only parameters the noise model depends on.
    acc_cache: Mutex<HashMap<(u16, u16, u16), f64>>,
}

impl<'a> JointProblem<'a> {
    pub fn new(
        space: &'a SearchSpace,
        workloads: &'a WorkloadSet,
        evaluator: NativeEvaluator,
        objective: Objective,
        agg: Aggregation,
    ) -> JointProblem<'a> {
        let mut objective = objective;
        objective.agg = agg;
        JointProblem::with_backend(space, workloads, EvalBackend::Native(evaluator), objective)
    }

    pub fn with_backend(
        space: &'a SearchSpace,
        workloads: &'a WorkloadSet,
        backend: EvalBackend,
        objective: Objective,
    ) -> JointProblem<'a> {
        JointProblem {
            space,
            workloads,
            backend,
            objective,
            subset: None,
            cache: Mutex::new(HashMap::new()),
            evals: AtomicUsize::new(0),
            acc_cache: Mutex::new(HashMap::new()),
        }
    }

    /// Restrict to a single workload (the paper's "separate search").
    pub fn restricted(mut self, workload_index: usize) -> Self {
        assert!(workload_index < self.workloads.len());
        self.subset = Some(vec![workload_index]);
        self
    }

    fn active_indices(&self) -> Vec<usize> {
        self.subset
            .clone()
            .unwrap_or_else(|| (0..self.workloads.len()).collect())
    }

    /// Accuracy estimates per active workload for one design (Fig. 8).
    /// Uses the AOT noisy-crossbar proxy when available, with the
    /// analytical model as fallback; memoized on (rows, cols, bits).
    fn accuracies(&self, raw: &[f64; 10], d: &Design) -> Vec<f64> {
        let mem = self.backend.mem();
        let key = (d.0[idx::ROWS], d.0[idx::COLS], d.0[idx::BITS_CELL]);
        let per_layer_eps = {
            let mut cache = self.acc_cache.lock().unwrap();
            *cache.entry(key).or_insert_with(|| {
                let spec = accuracy::NoiseSpec::from_design(raw, mem);
                if let EvalBackend::Pjrt(engine, _) = &self.backend {
                    let eng = engine.lock().unwrap();
                    if eng.has_accproxy() {
                        if let Ok(eps) =
                            eng.accproxy_eps(spec.weight_sigma(), spec.ir_drop)
                        {
                            return eps;
                        }
                    }
                }
                accuracy::analytical_eps(&spec, 1)
            })
        };
        self.active_indices()
            .iter()
            .map(|&wi| {
                let w = &self.workloads.workloads[wi];
                let eps = per_layer_eps * (w.mapped_layers() as f64).sqrt();
                let (base, chance) = accuracy::baseline(w.name);
                accuracy::accuracy_from_eps(eps, base, chance)
            })
            .collect()
    }

    /// Full evaluation record for one design (used by experiment reports).
    pub fn evaluate_design(&self, d: &Design) -> Evaluations {
        self.score_batch(std::slice::from_ref(d));
        self.cache
            .lock()
            .unwrap()
            .get(&self.space.linear_index(d))
            .cloned()
            .expect("design just scored must be cached")
    }

    /// Per-workload metrics of a design on *all* workloads regardless of
    /// subset (for cross-reporting a separately-optimized design).
    pub fn metrics_all_workloads(&self, d: &Design) -> Vec<Metrics> {
        let raw = self.space.decode(d);
        self.workloads
            .workloads
            .iter()
            .map(|w| self.backend.eval_batch(std::slice::from_ref(&raw), w)[0])
            .collect()
    }

    /// Number of cached distinct designs (diagnostics).
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl Problem for JointProblem<'_> {
    fn space(&self) -> &SearchSpace {
        self.space
    }

    fn score_batch(&self, designs: &[Design]) -> Vec<f64> {
        // resolve cache hits, collect misses
        let mut out = vec![f64::NAN; designs.len()];
        let mut miss_idx: Vec<usize> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            for (i, d) in designs.iter().enumerate() {
                if let Some(ev) = cache.get(&self.space.linear_index(d)) {
                    out[i] = ev.score;
                } else {
                    miss_idx.push(i);
                }
            }
        }
        if miss_idx.is_empty() {
            return out;
        }
        // de-duplicate misses within the batch
        let mut uniq: Vec<(u64, usize)> = Vec::new(); // (key, first index)
        {
            let mut seen: HashMap<u64, usize> = HashMap::new();
            for &i in &miss_idx {
                let key = self.space.linear_index(&designs[i]);
                seen.entry(key).or_insert(i);
            }
            uniq.extend(seen.into_iter());
        }
        uniq.sort_by_key(|&(_, i)| i); // deterministic order
        let raws: Vec<[f64; 10]> =
            uniq.iter().map(|&(_, i)| self.space.decode(&designs[i])).collect();
        self.evals.fetch_add(raws.len(), Ordering::Relaxed);

        // evaluate per active workload in workload-major order (each
        // workload is one batched artifact execution)
        let active = self.active_indices();
        let mut per_design_metrics: Vec<Vec<Metrics>> =
            vec![Vec::with_capacity(active.len()); raws.len()];
        for &wi in &active {
            let w = &self.workloads.workloads[wi];
            let ms = self.backend.eval_batch(&raws, w);
            for (slot, m) in per_design_metrics.iter_mut().zip(ms) {
                slot.push(m);
            }
        }

        // score + cache
        let mut cache = self.cache.lock().unwrap();
        for ((key, di), metrics) in uniq.iter().zip(per_design_metrics) {
            let d = &designs[*di];
            let raw = self.space.decode(d);
            let accuracies = if self.objective.kind == ObjectiveKind::EdapAccuracy {
                Some(self.accuracies(&raw, d))
            } else {
                None
            };
            let score = self.objective.score(
                &metrics,
                accuracies.as_deref(),
                raw[idx::TECH_NM],
            );
            cache.insert(
                *key,
                Evaluations {
                    metrics,
                    accuracies,
                    score,
                },
            );
        }
        for i in 0..designs.len() {
            if out[i].is_nan() {
                out[i] = cache[&self.space.linear_index(&designs[i])].score;
            }
        }
        out
    }

    /// Algorithm 1's initial-sampling feasibility pre-filter: only designs
    /// whose macro capacity covers the largest workload enter the pool. In
    /// the weight-stationary (RRAM) case the *whole* largest model must
    /// fit; in the weight-swapping (SRAM) case only its largest single
    /// layer must (a mild strengthening of the paper's pure random
    /// sampling — our analytical mapper, unlike CIMLoop's flexible
    /// temporal mapping, cannot split a layer across swap phases, so
    /// capacity-infeasible seeds would stall the search; see DESIGN.md).
    fn random_candidate(&self, rng: &mut Rng) -> Design {
        let mem = self.backend.mem();
        let largest = match mem {
            MemoryTech::Rram => {
                &self.workloads.workloads[self.workloads.largest_by_total()]
            }
            MemoryTech::Sram => {
                &self.workloads.workloads[self.workloads.largest_by_layer()]
            }
        };
        for _ in 0..500 {
            let d = self.space.random(rng);
            let raw = self.space.decode(&d);
            let view = crate::model::DesignView::new(&raw, mem);
            let mut sum = 0.0f64;
            let mut max: f64 = 0.0;
            for l in largest.layers.iter().filter(|l| !l.dynamic()) {
                let xb = view.xbars_for(l.k as f64, l.n as f64);
                sum += xb;
                max = max.max(xb);
            }
            let demand = match mem {
                MemoryTech::Rram => sum,
                MemoryTech::Sram => max,
            };
            if demand <= view.macros {
                return d;
            }
        }
        self.space.random(rng)
    }

    /// Graded violation for stochastic ranking: capacity shortfall +
    /// area excess + timing violation, all normalized.
    fn violation(&self, design: &Design) -> f64 {
        let raw = self.space.decode(design);
        let mem = self.backend.mem();
        let view = crate::model::DesignView::new(&raw, mem);
        let ev = NativeEvaluator::new(mem);
        let area = ev.area(&raw);
        let mut v = (area / self.objective.area_constraint - 1.0).max(0.0);
        if !view.timing_ok {
            v += 0.5;
        }
        // capacity violation against the largest active workload
        let active = self.active_indices();
        let mut worst: f64 = 0.0;
        for &wi in &active {
            let w = &self.workloads.workloads[wi];
            let mut sum_xb = 0.0;
            let mut max_xb: f64 = 0.0;
            for l in &w.layers {
                if l.dynamic() {
                    continue;
                }
                let xb = view.xbars_for(l.k as f64, l.n as f64);
                sum_xb += xb;
                max_xb = max_xb.max(xb);
            }
            let demand = match mem {
                MemoryTech::Rram => sum_xb,
                MemoryTech::Sram => max_xb,
            };
            worst = worst.max((demand / view.macros - 1.0).max(0.0));
        }
        v + worst
    }

    fn evals(&self) -> usize {
        self.evals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{GaConfig, GeneticAlgorithm, Optimizer, SearchBudget};

    fn problem<'a>(
        space: &'a SearchSpace,
        set: &'a WorkloadSet,
        mem: MemoryTech,
    ) -> JointProblem<'a> {
        JointProblem::with_backend(
            space,
            set,
            EvalBackend::native(mem),
            Objective::edap(),
        )
    }

    #[test]
    fn caching_avoids_reevaluation() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let mut rng = Rng::seed_from(1);
        let d = space.random(&mut rng);
        let s1 = p.score_batch(std::slice::from_ref(&d))[0];
        let n1 = p.evals();
        let s2 = p.score_batch(std::slice::from_ref(&d))[0];
        assert_eq!(s1.to_bits(), s2.to_bits());
        assert_eq!(p.evals(), n1, "cache hit must not re-evaluate");
        // duplicate within one batch evaluates once
        let d2 = space.random(&mut rng);
        let before = p.evals();
        p.score_batch(&[d2.clone(), d2.clone(), d2]);
        assert_eq!(p.evals(), before + 1);
    }

    #[test]
    fn feasible_designs_exist_and_score_finite() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let mut rng = Rng::seed_from(2);
        let designs: Vec<Design> =
            (0..64).map(|_| p.random_candidate(&mut rng)).collect();
        let scores = p.score_batch(&designs);
        let finite = scores.iter().filter(|s| s.is_finite()).count();
        assert!(
            finite > 10,
            "capacity-prefiltered candidates should mostly be feasible ({finite}/64)"
        );
    }

    #[test]
    fn rram_prefilter_covers_vgg() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let mut rng = Rng::seed_from(3);
        for _ in 0..20 {
            let d = p.random_candidate(&mut rng);
            let raw = space.decode(&d);
            let view = crate::model::DesignView::new(&raw, MemoryTech::Rram);
            let vgg = &set.workloads[1];
            let needed: f64 = vgg
                .layers
                .iter()
                .map(|l| view.xbars_for(l.k as f64, l.n as f64))
                .sum();
            assert!(needed <= view.macros);
        }
    }

    #[test]
    fn restricted_problem_scores_single_workload() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p_all = problem(&space, &set, MemoryTech::Rram);
        let p_one = problem(&space, &set, MemoryTech::Rram).restricted(0);
        let mut rng = Rng::seed_from(4);
        let d = p_all.random_candidate(&mut rng);
        let ev_all = p_all.evaluate_design(&d);
        let ev_one = p_one.evaluate_design(&d);
        assert_eq!(ev_all.metrics.len(), 4);
        assert_eq!(ev_one.metrics.len(), 1);
        // single-workload joint score == that workload's own score
        assert!(ev_one.score <= ev_all.score || !ev_all.score.is_finite());
    }

    #[test]
    fn end_to_end_ga_on_native_backend() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Rram);
        let ga = GeneticAlgorithm::new(GaConfig {
            init: crate::search::InitStrategy::HammingDiverse { p_h: 60, p_e: 30 },
            ..GaConfig::four_phase(SearchBudget { pop: 12, gens: 8 })
        });
        let r = ga.run(&p, &mut Rng::seed_from(5));
        assert!(r.best_score.is_finite(), "GA found no feasible design");
        let ev = p.evaluate_design(&r.best);
        assert!(ev.metrics.iter().all(|m| m.feasible));
    }

    #[test]
    fn accuracy_objective_populates_accuracies() {
        let space = SearchSpace::rram();
        let set = WorkloadSet::cnn4();
        let p = JointProblem::with_backend(
            &space,
            &set,
            EvalBackend::native(MemoryTech::Rram),
            Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max),
        );
        let mut rng = Rng::seed_from(6);
        let d = p.random_candidate(&mut rng);
        let ev = p.evaluate_design(&d);
        let accs = ev.accuracies.expect("accuracies required");
        assert_eq!(accs.len(), 4);
        assert!(accs.iter().all(|&a| a > 0.0 && a < 1.0));
    }

    #[test]
    fn violation_grades_area_excess() {
        let space = SearchSpace::sram();
        let set = WorkloadSet::cnn4();
        let p = problem(&space, &set, MemoryTech::Sram);
        // max-everything SRAM design: far over the area budget but with
        // ample capacity and relaxed timing
        let huge = Design(
            space
                .params
                .iter()
                .map(|pd| (pd.cardinality() - 1) as u16)
                .collect(),
        );
        // a mid design that fits the largest layer and the area budget:
        // rows/cols 512, 32 macros/tile, 8 tiles, 16 groups, slow cycle
        let mid = space.clamp_round(&[4.0, 4.0, 3.0, 2.0, 5.0, 0.0, 4.0, 3.0, 4.0, 0.0]);
        assert!(p.violation(&huge) > 0.0, "huge must violate area");
        assert!(
            p.violation(&huge) > p.violation(&mid),
            "huge {} vs mid {}",
            p.violation(&huge),
            p.violation(&mid)
        );
        // graded, not binary: bigger excess -> bigger violation
        assert!(p.violation(&huge) > 0.1);
    }
}
