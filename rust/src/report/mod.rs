//! Experiment report assembly: collects tables + notes, prints to the
//! terminal and persists markdown/CSV under `results/`.

use crate::util::table::Table;
use anyhow::Result;
use std::path::Path;

/// One experiment's full output.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub tables: Vec<Table>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            ..Report::default()
        }
    }

    pub fn table(&mut self, t: Table) -> &mut Self {
        self.tables.push(t);
        self
    }

    pub fn note(&mut self, n: impl Into<String>) -> &mut Self {
        self.notes.push(n.into());
        self
    }

    /// Render the full report as terminal text.
    pub fn to_text(&self) -> String {
        let mut out = format!("# {} — {}\n\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.to_text());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Render as markdown (persisted to `results/<id>.md`).
    pub fn to_markdown(&self) -> String {
        let mut out = format!("# {} — {}\n\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        if !self.notes.is_empty() {
            out.push_str("## Notes\n\n");
            for n in &self.notes {
                out.push_str(&format!("- {n}\n"));
            }
        }
        out
    }

    /// Print to stdout and persist `<out_dir>/<id>.md` (+ one CSV per
    /// table).
    pub fn emit(&self, out_dir: &Path) -> Result<()> {
        print!("{}", self.to_text());
        std::fs::create_dir_all(out_dir)?;
        std::fs::write(out_dir.join(format!("{}.md", self.id)), self.to_markdown())?;
        for (i, t) in self.tables.iter().enumerate() {
            let name = if self.tables.len() == 1 {
                format!("{}.csv", self.id)
            } else {
                format!("{}_{}.csv", self.id, i)
            };
            std::fs::write(out_dir.join(name), t.to_csv())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_writes_files() {
        let dir = std::env::temp_dir().join("imcopt-report-test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut r = Report::new("t0", "demo");
        let mut t = Table::new("tbl", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        r.table(t);
        r.note("hello");
        r.emit(&dir).unwrap();
        assert!(dir.join("t0.md").exists());
        assert!(dir.join("t0.csv").exists());
        let md = std::fs::read_to_string(dir.join("t0.md")).unwrap();
        assert!(md.contains("demo") && md.contains("hello"));
    }

    #[test]
    fn multiple_tables_get_indexed_csvs() {
        let dir = std::env::temp_dir().join("imcopt-report-test2");
        let _ = std::fs::remove_dir_all(&dir);
        let mut r = Report::new("t1", "demo2");
        for _ in 0..2 {
            let mut t = Table::new("x", &["c"]);
            t.row(vec!["v".into()]);
            r.table(t);
        }
        r.emit(&dir).unwrap();
        assert!(dir.join("t1_0.csv").exists());
        assert!(dir.join("t1_1.csv").exists());
    }
}
