//! Experiment report assembly: collects tables + notes, prints to the
//! terminal and persists markdown/CSV/JSON under `--out-dir` (default
//! `results/`). The JSON artifact (`<id>.json`) is the machine-readable
//! form consumed by `imcopt validate` (checked against
//! `schemas/experiment_report.schema.json`) and by the checkpoint
//! subsystem, which journals a completed experiment's report and replays
//! it byte-identically on `--resume`.

use crate::util::json::Json;
use crate::util::table::Table;
use anyhow::{Context, Result};
use std::path::Path;

/// One experiment's full output.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub tables: Vec<Table>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            ..Report::default()
        }
    }

    pub fn table(&mut self, t: Table) -> &mut Self {
        self.tables.push(t);
        self
    }

    pub fn note(&mut self, n: impl Into<String>) -> &mut Self {
        self.notes.push(n.into());
        self
    }

    /// Render the full report as terminal text.
    pub fn to_text(&self) -> String {
        let mut out = format!("# {} — {}\n\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.to_text());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Render as markdown (persisted to `results/<id>.md`).
    pub fn to_markdown(&self) -> String {
        let mut out = format!("# {} — {}\n\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        if !self.notes.is_empty() {
            out.push_str("## Notes\n\n");
            for n in &self.notes {
                out.push_str(&format!("- {n}\n"));
            }
        }
        out
    }

    /// Machine-readable form (persisted as `<id>.json` and journaled by
    /// the checkpoint subsystem). Round-trips exactly through
    /// [`Report::from_json`].
    pub fn to_json(&self) -> Json {
        let table_json = |t: &Table| {
            Json::obj(vec![
                ("title", Json::Str(t.title.clone())),
                (
                    "headers",
                    Json::Arr(t.headers.iter().map(|h| Json::Str(h.clone())).collect()),
                ),
                (
                    "rows",
                    Json::Arr(
                        t.rows
                            .iter()
                            .map(|r| {
                                Json::Arr(
                                    r.iter().map(|c| Json::Str(c.clone())).collect(),
                                )
                            })
                            .collect(),
                    ),
                ),
            ])
        };
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("title", Json::Str(self.title.clone())),
            ("tables", Json::Arr(self.tables.iter().map(table_json).collect())),
            (
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::Str(n.clone())).collect()),
            ),
        ])
    }

    /// Reconstruct a report from its JSON artifact.
    pub fn from_json(v: &Json) -> Result<Report> {
        let get_str = |v: &Json, key: &str| -> Result<String> {
            Ok(v.get(key)
                .and_then(|s| s.as_str())
                .with_context(|| format!("report json missing string '{key}'"))?
                .to_string())
        };
        let mut report = Report::new(&get_str(v, "id")?, &get_str(v, "title")?);
        for t in v
            .get("tables")
            .and_then(|t| t.as_arr())
            .context("report json missing 'tables'")?
        {
            let headers: Vec<String> = t
                .get("headers")
                .and_then(|h| h.as_arr())
                .context("table json missing 'headers'")?
                .iter()
                .filter_map(|h| h.as_str().map(String::from))
                .collect();
            let mut table = Table {
                title: get_str(t, "title")?,
                headers,
                rows: Vec::new(),
            };
            for row in t
                .get("rows")
                .and_then(|r| r.as_arr())
                .context("table json missing 'rows'")?
            {
                let cells: Vec<String> = row
                    .as_arr()
                    .context("table row is not an array")?
                    .iter()
                    .filter_map(|c| c.as_str().map(String::from))
                    .collect();
                table.row(cells);
            }
            report.table(table);
        }
        for n in v
            .get("notes")
            .and_then(|n| n.as_arr())
            .context("report json missing 'notes'")?
        {
            report.note(n.as_str().context("note is not a string")?);
        }
        Ok(report)
    }

    /// Print to stdout and persist `<out_dir>/<id>.md`, `<id>.json` and
    /// one CSV per table.
    pub fn emit(&self, out_dir: &Path) -> Result<()> {
        let _span = crate::telemetry::span(crate::telemetry::Stage::ArtifactWrite);
        print!("{}", self.to_text());
        std::fs::create_dir_all(out_dir)?;
        // temp-file + rename per artifact: concurrent orchestrator workers
        // replaying the same report each land a complete file instead of
        // interleaving writes
        crate::util::write_atomic(
            &out_dir.join(format!("{}.md", self.id)),
            &self.to_markdown(),
        )?;
        crate::telemetry::artifact_write();
        crate::util::write_atomic(
            &out_dir.join(format!("{}.json", self.id)),
            &(self.to_json().to_string() + "\n"),
        )?;
        crate::telemetry::artifact_write();
        for (i, t) in self.tables.iter().enumerate() {
            let name = if self.tables.len() == 1 {
                format!("{}.csv", self.id)
            } else {
                format!("{}_{}.csv", self.id, i)
            };
            crate::util::write_atomic(&out_dir.join(name), &t.to_csv())?;
            crate::telemetry::artifact_write();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_writes_files() {
        let dir = std::env::temp_dir().join("imcopt-report-test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut r = Report::new("t0", "demo");
        let mut t = Table::new("tbl", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        r.table(t);
        r.note("hello");
        r.emit(&dir).unwrap();
        assert!(dir.join("t0.md").exists());
        assert!(dir.join("t0.csv").exists());
        assert!(dir.join("t0.json").exists());
        let md = std::fs::read_to_string(dir.join("t0.md")).unwrap();
        assert!(md.contains("demo") && md.contains("hello"));
        let parsed = crate::util::json::parse(
            &std::fs::read_to_string(dir.join("t0.json")).unwrap(),
        )
        .unwrap();
        assert_eq!(parsed.get("id").unwrap().as_str(), Some("t0"));
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let mut r = Report::new("rt", "round trip");
        let mut t = Table::new("tbl", &["a", "b"]);
        t.row(vec!["x, quoted \"v\"".into(), "1.25".into()]);
        r.table(t);
        r.note("α note with unicode");
        let j = r.to_json();
        let back = Report::from_json(&crate::util::json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert_eq!(back.id, r.id);
        assert_eq!(back.title, r.title);
        assert_eq!(back.notes, r.notes);
        assert_eq!(back.tables.len(), 1);
        assert_eq!(back.tables[0].headers, r.tables[0].headers);
        assert_eq!(back.tables[0].rows, r.tables[0].rows);
        // serialized forms agree byte-for-byte (resume replay relies on it)
        assert_eq!(back.to_json().to_string(), j.to_string());
        assert_eq!(back.to_markdown(), r.to_markdown());
    }

    #[test]
    fn multiple_tables_get_indexed_csvs() {
        let dir = std::env::temp_dir().join("imcopt-report-test2");
        let _ = std::fs::remove_dir_all(&dir);
        let mut r = Report::new("t1", "demo2");
        for _ in 0..2 {
            let mut t = Table::new("x", &["c"]);
            t.row(vec!["v".into()]);
            r.table(t);
        }
        r.emit(&dir).unwrap();
        assert!(dir.join("t1_0.csv").exists());
        assert!(dir.join("t1_1.csv").exists());
    }
}
