//! Analytical IMC hardware evaluator — the CIMLoop substitute (DESIGN.md §3).
//!
//! Computes energy, latency and on-chip area of one hardware design
//! executing one workload on a tiled crossbar architecture:
//!
//! ```text
//! chip = G tile-groups ── each: 1 router + T tiles ── each: M crossbar
//! macros (R×C cells + drivers + 1 shared 8-bit ADC + I/O buffer)
//! + global buffer (GLB) + I/O; SRAM designs add LPDDR4 weight swapping.
//! ```
//!
//! The model is **closed-form per layer** so it can be mirrored exactly by
//! the AOT-compiled JAX/Pallas fitness kernel (`python/compile/kernels/
//! fitness.py`); the cross-language consistency test holds both to ≤0.5 %.
//! Absolute numbers are ballpark-calibrated (ISAAC/NeuroSim); the paper's
//! conclusions only require faithful *relative* ordering (§III-A).
//!
//! The canonical native hot path no longer walks layers at all: the
//! per-layer formulas are compiled once per workload into aggregate tables
//! ([`compiled::CompiledWorkload`]) and each (design, workload) evaluation
//! becomes a handful of table lookups. The layer-loop implementation
//! survives as [`NativeEvaluator::evaluate_naive`] — the test oracle the
//! compiled path is property-tested against (≤1e-9 relative agreement,
//! `rust/tests/compiled_vs_naive.rs`) and the fallback for off-grid
//! geometries.

pub mod compiled;
pub mod consts;
pub mod tech;

pub use compiled::CompiledWorkload;

use crate::space::idx;
use crate::workloads::Workload;
use consts::*;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of evaluations that fell back to the naive layer
/// walk because the crossbar geometry was off the compiled grid (the
/// workload still matched its compiled tables). Monotone; experiments
/// snapshot it around a session and surface any delta as a report notice
/// so silent fallbacks become visible without perturbing results.
static OFFGRID_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Current value of the off-grid fallback counter.
pub fn offgrid_fallbacks() -> u64 {
    OFFGRID_FALLBACKS.load(Ordering::Relaxed)
}

/// Memory technology of the IMC macro (paper §III-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MemoryTech {
    /// Weight-stationary; the whole model must fit on-chip.
    Rram,
    /// Weight-swapping through LPDDR4; one layer must fit at a time.
    Sram,
}

impl MemoryTech {
    pub fn name(&self) -> &'static str {
        match self {
            MemoryTech::Rram => "RRAM",
            MemoryTech::Sram => "SRAM",
        }
    }
}

/// Evaluation result for (design, workload).
#[derive(Clone, Copy, Debug)]
pub struct Metrics {
    /// Energy per inference (J), dynamic + leakage.
    pub energy: f64,
    /// Latency per inference (s).
    pub latency: f64,
    /// On-chip area (mm²) — workload-independent.
    pub area: f64,
    /// Mapping feasibility: capacity, area constraint and V/f timing.
    pub feasible: bool,
}

impl Metrics {
    /// Energy-delay-area product in the paper's mJ·ms·mm² units.
    pub fn edap(&self) -> f64 {
        (self.energy * 1e3) * (self.latency * 1e3) * self.area
    }
    /// Energy-delay product (mJ·ms).
    pub fn edp(&self) -> f64 {
        (self.energy * 1e3) * (self.latency * 1e3)
    }
}

/// Derived per-design quantities shared across layers.
#[derive(Clone, Copy, Debug)]
pub struct DesignView {
    pub rows: f64,
    pub cols: f64,
    pub macros: f64,
    pub tiles: f64,
    pub groups: f64,
    pub bits_cell: f64,
    pub v: f64,
    pub t_cycle_s: f64,
    pub glb_bytes: f64,
    pub tech: f64,
    /// Devices per 8-bit weight after bit slicing.
    pub dpw: f64,
    /// Dynamic-energy scale (tech/32)·V².
    pub s_e: f64,
    /// Area scale (tech/32)².
    pub s_a: f64,
    /// V/f timing feasibility.
    pub timing_ok: bool,
}

impl DesignView {
    /// Build from the canonical raw design vector (see `space::PARAM_NAMES`).
    pub fn new(raw: &[f64; 10], mem: MemoryTech) -> DesignView {
        let rows = raw[idx::ROWS];
        let cols = raw[idx::COLS];
        let m = raw[idx::C_PER_TILE];
        let t = raw[idx::T_PER_ROUTER];
        let g = raw[idx::G_PER_CHIP];
        let bits = match mem {
            MemoryTech::Rram => raw[idx::BITS_CELL],
            MemoryTech::Sram => 1.0,
        };
        let v = raw[idx::V_STEP]; // already decoded to volts by SearchSpace
        let tc_ns = raw[idx::T_CYCLE_NS];
        let tech = raw[idx::TECH_NM];
        DesignView {
            rows,
            cols,
            macros: m * t * g,
            tiles: t * g,
            groups: g,
            bits_cell: bits,
            v,
            t_cycle_s: tc_ns * 1e-9,
            glb_bytes: raw[idx::GLB_KB] * 1024.0,
            tech,
            dpw: (W_BITS / bits).ceil(),
            s_e: (tech / 32.0) * v * v,
            s_a: (tech / 32.0) * (tech / 32.0),
            timing_ok: tc_ns >= t_min_ns(v, tech),
        }
    }

    /// Crossbars needed by a `k × n` weight matrix.
    pub fn xbars_for(&self, k: f64, n: f64) -> f64 {
        (k / self.rows).ceil() * (n * self.dpw / self.cols).ceil()
    }
}

/// Per-layer metric contributions; summed over the workload.
#[derive(Clone, Copy, Debug, Default)]
struct LayerCost {
    energy: f64,
    latency: f64,
}

/// The native (Rust) evaluator. The hot search path normally runs the AOT
/// PJRT artifact (`runtime::Engine`); this implementation is the oracle
/// for tests, the fallback backend, and the reference for the JAX mirror.
#[derive(Clone, Copy, Debug)]
pub struct NativeEvaluator {
    pub mem: MemoryTech,
}

impl NativeEvaluator {
    pub fn new(mem: MemoryTech) -> Self {
        NativeEvaluator { mem }
    }

    /// On-chip area (mm²) of a design — workload-independent.
    pub fn area(&self, raw: &[f64; 10]) -> f64 {
        let d = DesignView::new(raw, self.mem);
        self.area_view(&d)
    }

    fn area_view(&self, d: &DesignView) -> f64 {
        let f_um = d.tech * 1e-3; // feature size in µm
        let cell_f2 = match self.mem {
            MemoryTech::Rram => CELL_F2_RRAM,
            MemoryTech::Sram => CELL_F2_SRAM,
        };
        // cell area in mm²: F² count × (F in µm)² × 1e-6 (µm² → mm²)
        let cell_mm2 = cell_f2 * f_um * f_um * 1e-6;
        let array = d.rows * d.cols * cell_mm2 * ARRAY_OVH;
        let macro_area =
            array + (ADC_AREA_MM2 + DRV_AREA_MM2 + MACRO_BUF_AREA_MM2) * d.s_a;
        let m_per_tile = d.macros / d.tiles;
        let tile_area = m_per_tile * macro_area + TILE_BUF_AREA_MM2 * d.s_a;
        let glb_area = (d.glb_bytes / (1024.0 * 1024.0)) * GLB_MM2_PER_MB * d.s_a;
        d.tiles * tile_area + d.groups * ROUTER_AREA_MM2 * d.s_a + glb_area + IO_AREA_MM2
    }

    /// Evaluate one design on one workload.
    ///
    /// Routes through the O(1) compiled aggregate tables
    /// ([`CompiledWorkload`], built lazily per workload instance), falling
    /// back to the naive layer loop when the crossbar geometry is off the
    /// precomputed grid or the workload's layers were mutated after
    /// compilation ([`CompiledWorkload::matches`] — count plus first/last
    /// layer signatures). Both paths are deterministic pure functions of
    /// (design, workload), so results are bit-identical across thread
    /// counts and resume replays.
    pub fn evaluate(&self, raw: &[f64; 10], w: &Workload) -> Metrics {
        let d = DesignView::new(raw, self.mem);
        let area = self.area_view(&d);
        let cw = w.compiled();
        if cw.matches(&w.layers) {
            if let Some(m) = cw.metrics(self.mem, &d, area) {
                return m;
            }
            // geometry off the precomputed grid: correct but slow path
            OFFGRID_FALLBACKS.fetch_add(1, Ordering::Relaxed);
        }
        self.naive_with_view(&d, area, w)
    }

    /// The original O(layers) closed-form walk — kept as the test oracle
    /// for the compiled path and as the fallback for geometries outside
    /// the precomputed [`compiled::GRID_ROWS_COLS`]/[`compiled::GRID_DPW`]
    /// grid. Semantics are identical to [`NativeEvaluator::evaluate`] up
    /// to float summation order (≤1e-9 relative; capacity/feasibility are
    /// bit-identical).
    pub fn evaluate_naive(&self, raw: &[f64; 10], w: &Workload) -> Metrics {
        let d = DesignView::new(raw, self.mem);
        let area = self.area_view(&d);
        self.naive_with_view(&d, area, w)
    }

    fn naive_with_view(&self, d: &DesignView, area: f64, w: &Workload) -> Metrics {
        // ---- mapping pass: crossbar demand --------------------------------
        let mut sum_xb = 0.0f64;
        let mut max_xb = 0.0f64;
        for l in &w.layers {
            if l.dynamic() {
                continue;
            }
            let xb = d.xbars_for(l.k as f64, l.n as f64);
            sum_xb += xb;
            max_xb = max_xb.max(xb);
        }
        let capacity_ok = match self.mem {
            MemoryTech::Rram => sum_xb <= d.macros,
            MemoryTech::Sram => max_xb <= d.macros,
        };
        // SRAM weight swapping engages when the whole model exceeds chip
        // capacity (paper §III-B: only a subset of layers resident).
        let swapping = self.mem == MemoryTech::Sram && sum_xb > d.macros;
        // RRAM replication is uniform across the resident model; SRAM
        // replicates the active layer over all macros. Both are bounded by
        // the broadcast/reduction fan-out cap REP_MAX.
        let rep_rram = (d.macros / sum_xb.max(1.0))
            .floor()
            .clamp(1.0, REP_MAX);

        let mut total = LayerCost::default();
        for l in &w.layers {
            let c = if l.dynamic() {
                self.dynamic_layer_cost(d, l)
            } else {
                let rep = match self.mem {
                    MemoryTech::Rram => rep_rram,
                    MemoryTech::Sram => {
                        let xb = d.xbars_for(l.k as f64, l.n as f64);
                        (d.macros / xb.max(1.0)).floor().clamp(1.0, REP_MAX)
                    }
                };
                self.static_layer_cost(d, l, rep, swapping)
            };
            total.energy += c.energy;
            total.latency += c.latency;
        }

        // leakage over the whole inference
        let p_leak =
            P_LEAK_W_PER_MM2 * (32.0 / d.tech).sqrt() * d.v * area;
        total.energy += p_leak * total.latency;

        Metrics {
            energy: total.energy,
            latency: total.latency,
            area,
            feasible: capacity_ok && d.timing_ok && area <= AREA_CONSTR_MM2,
        }
    }

    /// Evaluate a batch of decoded designs on one workload, design-major
    /// across `threads` workers. Output order matches `raws`, and every
    /// per-design result is bit-identical to a sequential
    /// [`NativeEvaluator::evaluate`] call (each design's evaluation is
    /// independent and deterministic).
    pub fn evaluate_batch(
        &self,
        raws: &[[f64; 10]],
        w: &Workload,
        threads: usize,
    ) -> Vec<Metrics> {
        crate::util::pool::parallel_map(raws, threads, |raw| self.evaluate(raw, w))
    }

    /// Weight-stationary crossbar layer.
    fn static_layer_cost(
        &self,
        d: &DesignView,
        l: &crate::workloads::Layer,
        rep: f64,
        swapping: bool,
    ) -> LayerCost {
        let (e_cell, e_adc) = match self.mem {
            MemoryTech::Rram => (E_CELL_RRAM, E_ADC_RRAM),
            MemoryTech::Sram => (E_CELL_SRAM, E_ADC_SRAM),
        };
        let k = l.k as f64;
        let n = l.n as f64;
        let passes = l.passes as f64;
        let ndpw = n * d.dpw;
        let xb_r = (k / d.rows).ceil();
        let xb_c = (ndpw / d.cols).ceil();

        // ---- compute ------------------------------------------------------
        // Bit-serial over IN_BITS; the macro's single ADC sweeps its
        // *physical* columns at ADC_CONV_PER_CYCLE conversions/cycle and
        // the drivers bias the full allocated row span — under-utilized
        // arrays waste conversions and driver energy, which is the
        // crossbar-size/workload coupling the paper's trade-offs hinge on
        // (small-layer networks prefer small macros, VGG amortizes big
        // ones). Row-groups (xb_r) convert in parallel in separate macros.
        let lat_compute = (passes / rep).ceil()
            * IN_BITS
            * (d.cols / ADC_CONV_PER_CYCLE).ceil()
            * d.t_cycle_s;
        let e_array = passes * IN_BITS * k * ndpw * e_cell * d.s_e;
        let conversions = passes * IN_BITS * xb_r * (xb_c * d.cols);
        let e_adc_total = conversions * e_adc * d.s_e;
        let e_drv = passes * IN_BITS * (xb_r * d.rows) * xb_c * E_DRV * d.s_e;

        // ---- weight swapping (SRAM only) -----------------------------------
        let swap_bytes = if swapping { l.weights as f64 } else { 0.0 };
        let e_swap = swap_bytes * (E_DRAM_BYTE + E_SRAM_WRITE_BYTE);
        let lat_swap = swap_bytes / DRAM_BW;

        // ---- on-chip traffic -------------------------------------------------
        let io_bytes = (l.in_bytes + l.out_bytes) as f64;
        let noc_bytes = io_bytes + swap_bytes;
        let hops = d.groups.sqrt();
        let lat_noc =
            noc_bytes * hops * d.t_cycle_s / (NOC_BYTES_PER_CYCLE * d.groups);
        let e_noc = noc_bytes * hops * E_NOC_BYTE * d.s_e;
        let e_glb = (io_bytes + swap_bytes) * E_GLB_BYTE * d.s_e;

        // activation working set beyond the GLB spills to DRAM
        let spill = (io_bytes - d.glb_bytes).max(0.0);
        let e_spill = 2.0 * spill * E_DRAM_BYTE;
        let lat_spill = 2.0 * spill / DRAM_BW;

        LayerCost {
            energy: e_array + e_adc_total + e_drv + e_swap + e_noc + e_glb + e_spill,
            latency: lat_compute + lat_swap + lat_noc + lat_spill,
        }
    }

    /// Activation×activation matmul on the per-tile digital vector units.
    fn dynamic_layer_cost(
        &self,
        d: &DesignView,
        l: &crate::workloads::Layer,
    ) -> LayerCost {
        let macs = l.macs() as f64;
        let lat = macs / (d.tiles * DIG_LANES) * d.t_cycle_s;
        let e = macs * E_DIG_MAC * d.s_e;
        let io_bytes = (l.in_bytes + l.out_bytes) as f64;
        let hops = d.groups.sqrt();
        let lat_noc =
            io_bytes * hops * d.t_cycle_s / (NOC_BYTES_PER_CYCLE * d.groups);
        let e_noc = io_bytes * hops * E_NOC_BYTE * d.s_e;
        let e_glb = io_bytes * E_GLB_BYTE * d.s_e;
        LayerCost {
            energy: e + e_noc + e_glb,
            latency: lat + lat_noc,
        }
    }
}

/// Crossbar demand `(Σ xbars, max xbars)` of `w`'s static layers on `d` —
/// the capacity terms of the mapping pass. Uses the compiled aggregate
/// tables when the geometry is on-grid (O(1)), and walks the layers
/// otherwise. Exact either way: the sums are integer-valued `f64`s.
pub fn xbar_demand(d: &DesignView, w: &Workload) -> (f64, f64) {
    let cw = w.compiled();
    if cw.matches(&w.layers) {
        if let Some(demand) = cw.xbar_demand(d) {
            return demand;
        }
    }
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    for l in w.layers.iter().filter(|l| !l.dynamic()) {
        let xb = d.xbars_for(l.k as f64, l.n as f64);
        sum += xb;
        max = max.max(xb);
    }
    (sum, max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{idx, SearchSpace};
    use crate::util::rng::Rng;
    use crate::workloads::{resnet18, vgg16, WorkloadSet};

    /// A comfortable mid-size RRAM design used across tests:
    /// 512×256, 16 macros/tile, 8 tiles/router, 24 groups, 2 bits/cell,
    /// 0.85 V, 2 ns, 4 MB GLB, 32 nm.
    fn mid_raw() -> [f64; 10] {
        [512.0, 256.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0]
    }

    #[test]
    fn metrics_positive_and_feasible() {
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        let m = ev.evaluate(&mid_raw(), &resnet18());
        assert!(m.energy > 0.0 && m.energy < 1.0, "E={}", m.energy);
        assert!(m.latency > 0.0 && m.latency < 10.0, "L={}", m.latency);
        assert!(m.area > 2.0 && m.area < 800.0, "A={}", m.area);
        assert!(m.feasible);
    }

    #[test]
    fn vgg_costs_more_than_resnet18() {
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        let raw = mid_raw();
        let r = ev.evaluate(&raw, &resnet18());
        let v = ev.evaluate(&raw, &vgg16());
        assert!(v.energy > r.energy);
        assert!(v.latency > r.latency);
        assert_eq!(v.area, r.area); // area is workload-independent
    }

    #[test]
    fn rram_capacity_constraint() {
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        // Tiny chip: 32×32 crossbars, 4 macros/tile, 2 tiles, 2 groups,
        // 1 bit/cell -> nowhere near enough for VGG16 (138M weights).
        let raw = [32.0, 32.0, 4.0, 2.0, 2.0, 1.0, 0.85, 2.0, 1024.0, 32.0];
        let m = ev.evaluate(&raw, &vgg16());
        assert!(!m.feasible);
        // The same tiny chip in SRAM mode swaps and only needs the largest
        // layer to fit... which it also can't (fc6 needs 25088 rows).
        let ev_s = NativeEvaluator::new(MemoryTech::Sram);
        let m2 = ev_s.evaluate(&raw, &vgg16());
        assert!(!m2.feasible);
    }

    #[test]
    fn sram_swapping_adds_latency() {
        // A chip that holds the largest VGG16 layer but not the model:
        // swapping engages and adds DRAM latency vs the same-shape chip
        // evaluating ResNet18-small... compare VGG16 SRAM latency with an
        // artificially fitting (huge) chip.
        let ev = NativeEvaluator::new(MemoryTech::Sram);
        // SRAM stores 8 one-bit cells per weight, so VGG16's fc6 needs
        // ceil(25088/512)·ceil(4096·8/512) = 49·64 = 3136 macros.
        let small = [512.0, 512.0, 32.0, 8.0, 16.0, 1.0, 0.85, 2.0, 8192.0, 32.0];
        let huge = [512.0, 512.0, 32.0, 16.0, 64.0, 1.0, 0.85, 2.0, 8192.0, 32.0];
        let m_small = ev.evaluate(&small, &vgg16());
        let m_huge = ev.evaluate(&huge, &vgg16());
        assert!(m_small.feasible, "largest layer should fit");
        // the huge chip holds everything: no swap, lower latency
        assert!(m_huge.latency < m_small.latency);
        // VGG16 is 138MB; swap time alone is >= 138e6/25.6e9 ≈ 5.4ms
        assert!(m_small.latency > 5.0e-3, "lat={}", m_small.latency);
    }

    #[test]
    fn timing_constraint_binds_at_low_voltage() {
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        let mut raw = mid_raw();
        raw[idx::V_STEP] = 0.65; // volts (decoded form)
        raw[idx::T_CYCLE_NS] = 1.0; // too fast for 0.65 V at 32 nm
        let m = ev.evaluate(&raw, &resnet18());
        assert!(!m.feasible);
        raw[idx::T_CYCLE_NS] = 2.0;
        assert!(ev.evaluate(&raw, &resnet18()).feasible);
    }

    #[test]
    fn bits_per_cell_reduces_rram_crossbar_demand() {
        let d1 = DesignView::new(&[512.0, 256.0, 16.0, 8.0, 24.0, 1.0, 0.85, 2.0, 4096.0, 32.0], MemoryTech::Rram);
        let d4 = DesignView::new(&[512.0, 256.0, 16.0, 8.0, 24.0, 4.0, 0.85, 2.0, 4096.0, 32.0], MemoryTech::Rram);
        assert_eq!(d1.dpw, 8.0);
        assert_eq!(d4.dpw, 2.0);
        assert!(d4.xbars_for(512.0, 512.0) < d1.xbars_for(512.0, 512.0));
    }

    #[test]
    fn sram_ignores_bits_cell() {
        let raw = mid_raw();
        let d = DesignView::new(&raw, MemoryTech::Sram);
        assert_eq!(d.dpw, 8.0); // always 1-bit cells
    }

    #[test]
    fn area_scales_with_tech_and_glb() {
        let ev = NativeEvaluator::new(MemoryTech::Sram);
        let mut a = mid_raw();
        let mut b = mid_raw();
        b[idx::TECH_NM] = 7.0;
        assert!(ev.area(&b) < ev.area(&a));
        a[idx::GLB_KB] = 16384.0;
        assert!(ev.area(&a) > ev.area(&mid_raw()));
    }

    #[test]
    fn max_config_violates_area_constraint() {
        // Paper §IV-G: sequential optimization starting from the largest
        // configuration fails the area constraint.
        let raw = [512.0, 512.0, 32.0, 16.0, 64.0, 4.0, 1.0, 1.0, 16384.0, 32.0];
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        assert!(ev.area(&raw) > AREA_CONSTR_MM2, "area={}", ev.area(&raw));
    }

    #[test]
    fn energy_monotone_in_voltage() {
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        let mut lo = mid_raw();
        let mut hi = mid_raw();
        lo[idx::V_STEP] = 0.7;
        hi[idx::V_STEP] = 1.0;
        let ml = ev.evaluate(&lo, &resnet18());
        let mh = ev.evaluate(&hi, &resnet18());
        assert!(ml.energy < mh.energy);
    }

    #[test]
    fn latency_monotone_in_cycle_time() {
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        let mut fast = mid_raw();
        let mut slow = mid_raw();
        fast[idx::T_CYCLE_NS] = 2.0;
        slow[idx::T_CYCLE_NS] = 10.0;
        let mf = ev.evaluate(&fast, &resnet18());
        let ms = ev.evaluate(&slow, &resnet18());
        assert!(mf.latency < ms.latency);
    }

    #[test]
    fn random_designs_never_produce_nan() {
        let space = SearchSpace::rram();
        let mut rng = Rng::seed_from(17);
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        let set = WorkloadSet::cnn4();
        for _ in 0..300 {
            let d = space.random(&mut rng);
            let raw = space.decode(&d);
            for w in &set.workloads {
                let m = ev.evaluate(&raw, w);
                assert!(m.energy.is_finite() && m.energy > 0.0);
                assert!(m.latency.is_finite() && m.latency > 0.0);
                assert!(m.area.is_finite() && m.area > 0.0);
            }
        }
    }

    #[test]
    fn evaluate_batch_matches_sequential_any_thread_count() {
        let space = SearchSpace::rram();
        let mut rng = Rng::seed_from(23);
        let raws: Vec<[f64; 10]> = (0..40)
            .map(|_| space.decode(&space.random(&mut rng)))
            .collect();
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        let w = resnet18();
        let seq: Vec<Metrics> = raws.iter().map(|r| ev.evaluate(r, &w)).collect();
        for threads in [1, 2, 8] {
            let par = ev.evaluate_batch(&raws, &w, threads);
            assert_eq!(par.len(), seq.len());
            for (a, b) in par.iter().zip(&seq) {
                assert_eq!(a.energy.to_bits(), b.energy.to_bits());
                assert_eq!(a.latency.to_bits(), b.latency.to_bits());
                assert_eq!(a.area.to_bits(), b.area.to_bits());
                assert_eq!(a.feasible, b.feasible);
            }
        }
    }

    #[test]
    fn compiled_path_agrees_with_naive_oracle_on_mid_design() {
        // the exhaustive ≤1e-9 sweep lives in tests/compiled_vs_naive.rs;
        // this is the in-module smoke for both memory technologies
        let raw = mid_raw();
        for mem in [MemoryTech::Rram, MemoryTech::Sram] {
            let ev = NativeEvaluator::new(mem);
            for w in &WorkloadSet::all9().workloads {
                let d = DesignView::new(&raw, mem);
                assert!(w.compiled().covers(&d), "{} off grid", w.name);
                let c = ev.evaluate(&raw, w);
                let o = ev.evaluate_naive(&raw, w);
                assert!(
                    (c.energy - o.energy).abs() <= 1e-9 * o.energy.abs(),
                    "{}: E {} vs {}",
                    w.name,
                    c.energy,
                    o.energy
                );
                assert!(
                    (c.latency - o.latency).abs() <= 1e-9 * o.latency.abs(),
                    "{}: L {} vs {}",
                    w.name,
                    c.latency,
                    o.latency
                );
                assert_eq!(c.area.to_bits(), o.area.to_bits());
                assert_eq!(c.feasible, o.feasible);
            }
        }
    }

    #[test]
    fn in_place_layer_edit_falls_back_to_naive() {
        // same-length mutation of an end layer after first evaluation:
        // the staleness fingerprint must reject the compiled table, so
        // the result is bit-identical to the naive walk of the *edited*
        // layers rather than silently stale
        let raw = mid_raw();
        let ev = NativeEvaluator::new(MemoryTech::Rram);
        let mut w = resnet18();
        let before = ev.evaluate(&raw, &w); // builds the tables
        w.layers[0].k *= 2;
        let after = ev.evaluate(&raw, &w);
        let oracle = ev.evaluate_naive(&raw, &w);
        assert_eq!(after.energy.to_bits(), oracle.energy.to_bits());
        assert_eq!(after.latency.to_bits(), oracle.latency.to_bits());
        assert_ne!(after.energy.to_bits(), before.energy.to_bits());
        assert!(!w.compiled().matches(&w.layers));
        // io-only edits are part of the fingerprint too (they feed the
        // NoC/GLB/spill aggregates)
        let mut w2 = resnet18();
        let _ = ev.evaluate(&raw, &w2);
        w2.layers[0].in_bytes *= 2;
        assert!(!w2.compiled().matches(&w2.layers));
        let m = ev.evaluate(&raw, &w2);
        let o = ev.evaluate_naive(&raw, &w2);
        assert_eq!(m.energy.to_bits(), o.energy.to_bits());
    }

    #[test]
    fn xbar_demand_matches_layer_walk() {
        let raw = mid_raw();
        let w = vgg16();
        for mem in [MemoryTech::Rram, MemoryTech::Sram] {
            let d = DesignView::new(&raw, mem);
            let (sum, max) = xbar_demand(&d, &w);
            let mut esum = 0.0f64;
            let mut emax = 0.0f64;
            for l in w.layers.iter().filter(|l| !l.dynamic()) {
                let xb = d.xbars_for(l.k as f64, l.n as f64);
                esum += xb;
                emax = emax.max(xb);
            }
            assert_eq!(sum.to_bits(), esum.to_bits());
            assert_eq!(max.to_bits(), emax.to_bits());
        }
        // off-grid geometry takes the walking fallback
        let odd = [100.0, 256.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0];
        let d = DesignView::new(&odd, MemoryTech::Rram);
        let (sum, _) = xbar_demand(&d, &w);
        assert!(sum > 0.0);
    }

    #[test]
    fn edap_units() {
        let m = Metrics {
            energy: 1e-3,  // 1 mJ
            latency: 1e-3, // 1 ms
            area: 10.0,
            feasible: true,
        };
        assert!((m.edap() - 10.0).abs() < 1e-12);
        assert!((m.edp() - 1.0).abs() < 1e-12);
    }
}
