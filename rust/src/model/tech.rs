//! CMOS technology node data (paper Table 7) and the fabrication cost
//! model of §IV-I.
//!
//! Cost per mm² is derived from published 300 mm wafer prices and average
//! yields, normalized to the 32 nm node (scaling factor α). Voltage ranges
//! per node bound the `v_step` decode in `space`.

/// One technology node's entry from paper Table 7.
#[derive(Clone, Copy, Debug)]
pub struct TechNode {
    pub nm: f64,
    /// Average 300 mm wafer cost (USD).
    pub wafer_cost_usd: f64,
    /// Average yield (midpoint of the published band).
    pub yield_frac: f64,
    /// Cost scaling factor α per mm², normalized to 32 nm.
    pub alpha: f64,
    pub v_min: f64,
    pub v_max: f64,
}

/// Paper Table 7, verbatim (α column as published).
pub const TECH_TABLE: [TechNode; 8] = [
    TechNode { nm: 90.0, wafer_cost_usd: 1651.5, yield_frac: 0.925, alpha: 0.413, v_min: 0.95, v_max: 1.30 },
    TechNode { nm: 65.0, wafer_cost_usd: 1939.0, yield_frac: 0.925, alpha: 0.477, v_min: 0.85, v_max: 1.20 },
    TechNode { nm: 45.0, wafer_cost_usd: 2237.5, yield_frac: 0.850, alpha: 0.606, v_min: 0.75, v_max: 1.10 },
    TechNode { nm: 32.0, wafer_cost_usd: 3500.0, yield_frac: 0.800, alpha: 1.000, v_min: 0.65, v_max: 1.00 },
    TechNode { nm: 22.0, wafer_cost_usd: 4338.5, yield_frac: 0.800, alpha: 1.282, v_min: 0.65, v_max: 1.00 },
    TechNode { nm: 14.0, wafer_cost_usd: 4492.0, yield_frac: 0.700, alpha: 1.498, v_min: 0.55, v_max: 0.90 },
    TechNode { nm: 10.0, wafer_cost_usd: 5600.0, yield_frac: 0.600, alpha: 2.243, v_min: 0.50, v_max: 0.85 },
    TechNode { nm: 7.0,  wafer_cost_usd: 9291.5, yield_frac: 0.600, alpha: 3.871, v_min: 0.45, v_max: 0.80 },
];

/// Look up a node by feature size; panics on unknown nodes (the search
/// space only ever produces values from `TECH_TABLE`).
pub fn node(nm: f64) -> &'static TechNode {
    TECH_TABLE
        .iter()
        .find(|t| (t.nm - nm).abs() < 0.5)
        .unwrap_or_else(|| panic!("unknown technology node {nm} nm"))
}

/// Voltage range for a node (paper Table 7, rightmost column).
pub fn voltage_range(nm: f64) -> (f64, f64) {
    let t = node(nm);
    (t.v_min, t.v_max)
}

/// Normalized fabrication cost of a die of `area_mm2` at `nm`
/// (`Cost = α · A`, paper §IV-I).
pub fn fabrication_cost(nm: f64, area_mm2: f64) -> f64 {
    node(nm).alpha * area_mm2
}

/// Recompute α from wafer cost and yield the way the paper does
/// (`C_per_mm² = C_avg / (A_e · yield)`, normalized to 32 nm) — used as a
/// self-check that the published α column is consistent with its inputs.
pub fn alpha_from_first_principles(nm: f64) -> f64 {
    const EFFECTIVE_WAFER_MM2: f64 = 70_000.0; // 95% of a 300mm wafer
    let per_mm2 = |t: &TechNode| t.wafer_cost_usd / (EFFECTIVE_WAFER_MM2 * t.yield_frac);
    per_mm2(node(nm)) / per_mm2(node(32.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_covers_paper_nodes() {
        for nm in [7.0, 10.0, 14.0, 22.0, 32.0, 45.0, 65.0, 90.0] {
            let t = node(nm);
            assert_eq!(t.nm, nm);
            assert!(t.v_min < t.v_max);
        }
    }

    #[test]
    fn alpha_normalized_at_32nm() {
        assert_eq!(node(32.0).alpha, 1.0);
        assert!((fabrication_cost(32.0, 100.0) - 100.0).abs() < 1e-12);
    }

    #[test]
    fn alpha_monotone_below_32nm() {
        // advanced nodes cost more per mm² (paper: exponential trend)
        assert!(node(22.0).alpha > node(32.0).alpha);
        assert!(node(14.0).alpha > node(22.0).alpha);
        assert!(node(10.0).alpha > node(14.0).alpha);
        assert!(node(7.0).alpha > node(10.0).alpha);
        // mature nodes cost less
        assert!(node(45.0).alpha < 1.0);
        assert!(node(90.0).alpha < node(65.0).alpha);
    }

    #[test]
    fn published_alpha_consistent_with_inputs() {
        // The published α column should be reproducible from wafer cost and
        // yield midpoints within ~15 % (the paper averaged several sources).
        for t in &TECH_TABLE {
            let a = alpha_from_first_principles(t.nm);
            let rel = (a - t.alpha).abs() / t.alpha;
            assert!(rel < 0.15, "{} nm: derived {a:.3} vs published {:.3}", t.nm, t.alpha);
        }
    }

    #[test]
    #[should_panic(expected = "unknown technology node")]
    fn unknown_node_panics() {
        node(28.0);
    }
}
