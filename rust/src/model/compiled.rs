//! Compiled-workload evaluator: the O(layers) closed-form model collapsed
//! into O(1) table lookups (ROADMAP "make a hot path measurably faster").
//!
//! Every per-layer formula in [`super::NativeEvaluator`] decomposes into a
//! *workload-constant aggregate* × a *design-dependent factor*, because the
//! only design-dependent quantities inside a `ceil()` come from tiny
//! discrete sets:
//!
//! * crossbar geometry `(rows, cols, dpw)` — drawn from the union of every
//!   `SearchSpace` variant's grids ([`GRID_ROWS_COLS`], [`GRID_DPW`]), so
//!   `Σ ceil(k/rows)·ceil(n·dpw/cols)`, its max, and the
//!   conversion/driver sum `Σ passes·xb_r·xb_c` are precomputed per
//!   **shape bucket**;
//! * the RRAM replication factor `rep ∈ 1..=REP_MAX` — an 8-entry table of
//!   `Σ ceil(passes/rep)` covers it;
//! * the SRAM per-layer replication `clamp(⌊macros/xb_l⌋, 1, REP_MAX)` —
//!   layers sorted by `xb` with per-`rep` prefix sums turn the sum into
//!   [`REP_MAX`] binary searches (`⌊macros/xb⌋ ≥ r ⇔ r·xb ≤ macros`,
//!   exact for the integer-valued `f64`s involved);
//! * the GLB spill `Σ max(io_l − glb, 0)` — sorted prefix sums over
//!   `io_bytes` plus one binary search on `glb`;
//! * everything else is a flat sum (`Σ passes·k·n`, `Σ weights`,
//!   `Σ io_bytes`, `Σ macs`).
//!
//! All aggregates are sums/maxima of integer-valued `f64`s below 2⁵³, so
//! they are **exact** regardless of summation order — in particular
//! `sum_xb`/`max_xb` (and therefore capacity feasibility, swapping mode and
//! every replication factor) are bit-identical to the naive layer walk.
//! Energy/latency recombine the aggregates in a different floating-point
//! order than the per-layer loop, so those agree to ~1e-15 relative (the
//! property test `rust/tests/compiled_vs_naive.rs` enforces ≤1e-9); the
//! compiled path itself is a pure function of (design, workload) and stays
//! bit-identical across thread counts and resume replays.
//!
//! Designs whose geometry is off-grid (hand-written raw vectors in tests,
//! future space variants) return `None` from [`CompiledWorkload::metrics`]
//! and fall back to the naive oracle in `NativeEvaluator::evaluate`.

use super::consts::*;
use super::{DesignView, MemoryTech, Metrics};
use crate::workloads::Layer;

/// Crossbar row/column grid covered by the shape buckets — aliased from
/// the search space's single source of truth
/// ([`crate::space::ALL_ROWS_COLS`]), so a new space value automatically
/// gets buckets instead of silently dropping to the naive walk.
pub const GRID_ROWS_COLS: [f64; 8] = crate::space::ALL_ROWS_COLS;

/// Devices-per-weight values reachable from the spaces' bits/cell domains:
/// `dpw = ceil(W_BITS/bits)` with `bits ∈` [`crate::space::ALL_BITS_CELL`]
/// (SRAM pins bits = 1). A test pins this to the bits domain.
pub const GRID_DPW: [f64; 3] = [2.0, 4.0, 8.0];

/// `REP_MAX` as a table size (the replication factor is integer-valued;
/// a test pins this to `consts::REP_MAX`).
const REP_MAX_I: usize = 8;

/// Per-(rows, cols, dpw) aggregates over a workload's static layers.
#[derive(Clone, Debug, Default)]
struct ShapeBucket {
    /// `Σ xb_r·xb_c` — RRAM capacity demand and replication denominator.
    sum_xb: f64,
    /// `max xb_r·xb_c` — SRAM (largest-resident-layer) capacity demand.
    max_xb: f64,
    /// `Σ passes·xb_r·xb_c` — ADC conversion and row-driver sums.
    sum_pxb: f64,
    /// Distinct per-layer crossbar counts, ascending.
    xb_distinct: Vec<f64>,
    /// `rep_prefix[i][r-1]` = Σ over the first `i` distinct-xb groups of
    /// `Σ_{layer in group} ceil(passes/r)`; length `xb_distinct.len()+1`.
    rep_prefix: Vec<[f64; REP_MAX_I]>,
}

impl ShapeBucket {
    /// `Σ_l ceil(passes_l / rep_l)` with the SRAM per-layer replication
    /// `rep_l = clamp(⌊macros/xb_l⌋, 1, REP_MAX)`, via one binary search
    /// per replication class (`⌊macros/xb⌋ ≥ r ⇔ r·xb ≤ macros`; both
    /// sides are exact integer-valued `f64`s, so the class boundaries
    /// match the naive float `floor` bit-for-bit).
    fn sram_rep_sum(&self, macros: f64) -> f64 {
        let ng = self.xb_distinct.len();
        if ng == 0 {
            return 0.0;
        }
        // c[r] = #groups with rep ≥ r (i.e. r·xb ≤ macros); c is
        // non-increasing in r, and the rep-r class is c[r+1]..c[r]
        let mut c = [0usize; REP_MAX_I + 1];
        for (r, slot) in c.iter_mut().enumerate().skip(1) {
            *slot = self
                .xb_distinct
                .partition_point(|&xb| (r as f64) * xb <= macros);
        }
        let pref = |i: usize, r: usize| self.rep_prefix[i][r - 1];
        // rep = REP_MAX absorbs every ⌊macros/xb⌋ ≥ REP_MAX (the clamp)
        let mut sum = pref(c[REP_MAX_I], REP_MAX_I);
        for r in 2..REP_MAX_I {
            sum += pref(c[r], r) - pref(c[r + 1], r);
        }
        // rep = 1 absorbs ⌊macros/xb⌋ ≤ 1, i.e. everything above c[2]
        sum + pref(ng, 1) - pref(c[2], 1)
    }
}

/// Precomputed aggregate tables for one workload; built once per
/// [`crate::workloads::Workload`] instance (lazily, via
/// `Workload::compiled`) and shared by every evaluation of it.
///
/// Callers never construct this directly — [`super::NativeEvaluator`]
/// consults it transparently and falls back to the per-layer walk for
/// off-grid geometries:
///
/// ```
/// use imcopt::model::{MemoryTech, NativeEvaluator};
/// use imcopt::space::SearchSpace;
/// use imcopt::util::rng::Rng;
/// use imcopt::workloads;
///
/// let w = workloads::resnet18();
/// let space = SearchSpace::rram();
/// let raw = space.decode(&space.random(&mut Rng::seed_from(7)));
/// let ev = NativeEvaluator::new(MemoryTech::Rram);
/// let fast = ev.evaluate(&raw, &w); // O(1) compiled tables
/// let slow = ev.evaluate_naive(&raw, &w); // O(layers) oracle
/// // capacity aggregates are integer-exact: feasibility always agrees
/// assert_eq!(fast.feasible, slow.feasible);
/// assert!(((fast.energy - slow.energy) / slow.energy).abs() < 1e-9);
/// ```
#[derive(Clone, Debug)]
pub struct CompiledWorkload {
    /// Layer count at build time — `NativeEvaluator` falls back to the
    /// naive walk if the workload was mutated after compilation.
    n_layers: usize,
    /// O(1) staleness fingerprint: shape signatures of the first and
    /// last layers at build time. Together with `n_layers` this catches
    /// the common in-place edits (`w.layers[0].k *= 2`, push/pop) that
    /// the count alone cannot; mutating only interior layers of an
    /// already-evaluated instance remains unsupported (see
    /// `Workload::compiled` — clone first, clones recompile).
    first_sig: Option<u64>,
    last_sig: Option<u64>,
    // ---- flat sums over static (weight-stationary) layers ----------------
    /// `Σ passes·k·n` (crossbar MACs; `e_array` up to constant factors).
    s_pkn: f64,
    /// `Σ weights` (SRAM swap traffic when swapping engages).
    s_weights: f64,
    /// `Σ (in_bytes + out_bytes)` (NoC/GLB traffic).
    s_io_static: f64,
    // ---- flat sums over dynamic (digital vector-unit) layers -------------
    /// `Σ k·n·passes`.
    s_macs: f64,
    /// `Σ (in_bytes + out_bytes)`.
    s_io_dyn: f64,
    /// `rep_sums[rep-1] = Σ_static ceil(passes/rep)` — RRAM's uniform
    /// replication factor indexes straight into this.
    rep_sums: [f64; REP_MAX_I],
    /// Static-layer `io_bytes`, ascending, plus prefix sums (the GLB
    /// spill term `Σ max(io − glb, 0)`).
    io_sorted: Vec<f64>,
    io_prefix: Vec<f64>,
    /// One bucket per grid point, indexed by [`Self::bucket_index`].
    buckets: Vec<ShapeBucket>,
}

/// Position of `x` in a small exact-valued grid.
fn grid_pos(grid: &[f64], x: f64) -> Option<usize> {
    grid.iter().position(|&v| v == x)
}

/// Shape signature of one layer (staleness fingerprint component) —
/// covers every field the aggregate tables read.
fn layer_sig(l: &Layer) -> u64 {
    l.k ^ l.n.rotate_left(11)
        ^ l.passes.rotate_left(22)
        ^ l.weights.rotate_left(33)
        ^ l.in_bytes.rotate_left(44)
        ^ l.out_bytes.rotate_left(55)
        ^ ((l.dynamic() as u64) << 63)
}

impl CompiledWorkload {
    /// Precompute every aggregate table for `layers`. O(grid × layers)
    /// once, amortized over the millions of evaluations of a search run.
    pub fn build(layers: &[Layer]) -> CompiledWorkload {
        let mut cw = CompiledWorkload {
            n_layers: layers.len(),
            first_sig: layers.first().map(layer_sig),
            last_sig: layers.last().map(layer_sig),
            s_pkn: 0.0,
            s_weights: 0.0,
            s_io_static: 0.0,
            s_macs: 0.0,
            s_io_dyn: 0.0,
            rep_sums: [0.0; REP_MAX_I],
            io_sorted: Vec::new(),
            io_prefix: Vec::new(),
            buckets: Vec::new(),
        };
        for l in layers {
            let io = (l.in_bytes + l.out_bytes) as f64;
            if l.dynamic() {
                cw.s_macs += l.macs() as f64;
                cw.s_io_dyn += io;
            } else {
                let passes = l.passes as f64;
                cw.s_pkn += passes * l.k as f64 * l.n as f64;
                cw.s_weights += l.weights as f64;
                cw.s_io_static += io;
                cw.io_sorted.push(io);
                for rep in 1..=REP_MAX_I {
                    cw.rep_sums[rep - 1] += (passes / rep as f64).ceil();
                }
            }
        }
        cw.io_sorted.sort_by(f64::total_cmp);
        cw.io_prefix = Vec::with_capacity(cw.io_sorted.len() + 1);
        let mut acc = 0.0;
        cw.io_prefix.push(acc);
        for &io in &cw.io_sorted {
            acc += io;
            cw.io_prefix.push(acc);
        }

        let statics: Vec<&Layer> = layers.iter().filter(|l| !l.dynamic()).collect();
        cw.buckets = Vec::with_capacity(GRID_ROWS_COLS.len().pow(2) * GRID_DPW.len());
        for &rows in &GRID_ROWS_COLS {
            for &cols in &GRID_ROWS_COLS {
                for &dpw in &GRID_DPW {
                    cw.buckets.push(Self::build_bucket(&statics, rows, cols, dpw));
                }
            }
        }
        cw
    }

    fn build_bucket(statics: &[&Layer], rows: f64, cols: f64, dpw: f64) -> ShapeBucket {
        let mut b = ShapeBucket::default();
        // (xb, passes) per layer, mirroring DesignView::xbars_for exactly
        let mut per_layer: Vec<(f64, f64)> = Vec::with_capacity(statics.len());
        for l in statics {
            let xb = (l.k as f64 / rows).ceil() * (l.n as f64 * dpw / cols).ceil();
            let passes = l.passes as f64;
            b.sum_xb += xb;
            b.max_xb = b.max_xb.max(xb);
            b.sum_pxb += passes * xb;
            per_layer.push((xb, passes));
        }
        per_layer.sort_by(|a, b| a.0.total_cmp(&b.0));
        b.rep_prefix.push([0.0; REP_MAX_I]);
        for (xb, passes) in per_layer {
            if b.xb_distinct.last() != Some(&xb) {
                b.xb_distinct.push(xb);
                let last = *b.rep_prefix.last().unwrap();
                b.rep_prefix.push(last);
            }
            let acc = b.rep_prefix.last_mut().unwrap();
            for rep in 1..=REP_MAX_I {
                acc[rep - 1] += (passes / rep as f64).ceil();
            }
        }
        b
    }

    /// Layer count the tables were built from.
    pub fn layer_count(&self) -> usize {
        self.n_layers
    }

    /// Whether these tables were built from `layers` — the O(1)
    /// staleness check `NativeEvaluator` runs before trusting the
    /// compiled path (count plus first/last-layer signatures).
    pub fn matches(&self, layers: &[Layer]) -> bool {
        self.n_layers == layers.len()
            && self.first_sig == layers.first().map(layer_sig)
            && self.last_sig == layers.last().map(layer_sig)
    }

    fn bucket_index(&self, rows: f64, cols: f64, dpw: f64) -> Option<usize> {
        let ri = grid_pos(&GRID_ROWS_COLS, rows)?;
        let ci = grid_pos(&GRID_ROWS_COLS, cols)?;
        let di = grid_pos(&GRID_DPW, dpw)?;
        Some((ri * GRID_ROWS_COLS.len() + ci) * GRID_DPW.len() + di)
    }

    /// Whether the design's crossbar geometry has a precomputed bucket.
    pub fn covers(&self, d: &DesignView) -> bool {
        self.bucket_index(d.rows, d.cols, d.dpw).is_some()
    }

    /// Crossbar demand `(Σ xbars, max xbars)` of the static layers on
    /// `d`'s geometry — the capacity terms of the mapping pass. `None`
    /// when the geometry is off-grid.
    pub fn xbar_demand(&self, d: &DesignView) -> Option<(f64, f64)> {
        let b = &self.buckets[self.bucket_index(d.rows, d.cols, d.dpw)?];
        Some((b.sum_xb, b.max_xb))
    }

    /// `Σ max(io_bytes − glb, 0)` over static layers (GLB spill to DRAM).
    fn spill_sum(&self, glb: f64) -> f64 {
        let i = self.io_sorted.partition_point(|&io| io <= glb);
        let n = self.io_sorted.len();
        (self.io_prefix[n] - self.io_prefix[i]) - (n - i) as f64 * glb
    }

    /// Evaluate one design on this workload from the aggregate tables —
    /// the O(1) equivalent of `NativeEvaluator::evaluate_naive`'s layer
    /// loop. `area` is the (workload-independent) chip area the caller
    /// already computed. `None` when the geometry is off-grid.
    pub fn metrics(&self, mem: MemoryTech, d: &DesignView, area: f64) -> Option<Metrics> {
        let b = &self.buckets[self.bucket_index(d.rows, d.cols, d.dpw)?];

        // ---- mapping pass (exact: integer-valued sums) --------------------
        let capacity_ok = match mem {
            MemoryTech::Rram => b.sum_xb <= d.macros,
            MemoryTech::Sram => b.max_xb <= d.macros,
        };
        let swapping = mem == MemoryTech::Sram && b.sum_xb > d.macros;

        // ---- static compute ----------------------------------------------
        let (e_cell, e_adc) = match mem {
            MemoryTech::Rram => (E_CELL_RRAM, E_ADC_RRAM),
            MemoryTech::Sram => (E_CELL_SRAM, E_ADC_SRAM),
        };
        let sum_ceil = match mem {
            MemoryTech::Rram => {
                let rep = (d.macros / b.sum_xb.max(1.0)).floor().clamp(1.0, REP_MAX);
                self.rep_sums[rep as usize - 1]
            }
            MemoryTech::Sram => b.sram_rep_sum(d.macros),
        };
        let lat_compute = sum_ceil * IN_BITS * (d.cols / ADC_CONV_PER_CYCLE).ceil() * d.t_cycle_s;
        let e_array = self.s_pkn * d.dpw * IN_BITS * e_cell * d.s_e;
        let e_adc_total = b.sum_pxb * IN_BITS * d.cols * e_adc * d.s_e;
        let e_drv = b.sum_pxb * IN_BITS * d.rows * E_DRV * d.s_e;

        // ---- weight swapping (SRAM only) ----------------------------------
        let swap_bytes = if swapping { self.s_weights } else { 0.0 };
        let e_swap = swap_bytes * (E_DRAM_BYTE + E_SRAM_WRITE_BYTE);
        let lat_swap = swap_bytes / DRAM_BW;

        // ---- on-chip traffic (static + dynamic) ---------------------------
        let hops = d.groups.sqrt();
        let noc_static = self.s_io_static + swap_bytes;
        let lat_noc = (noc_static + self.s_io_dyn) * hops * d.t_cycle_s
            / (NOC_BYTES_PER_CYCLE * d.groups);
        let e_noc = (noc_static + self.s_io_dyn) * hops * E_NOC_BYTE * d.s_e;
        let e_glb = (noc_static + self.s_io_dyn) * E_GLB_BYTE * d.s_e;

        // activation working sets beyond the GLB spill to DRAM
        let spill = self.spill_sum(d.glb_bytes);
        let e_spill = 2.0 * spill * E_DRAM_BYTE;
        let lat_spill = 2.0 * spill / DRAM_BW;

        // ---- dynamic layers (digital vector units) ------------------------
        let lat_dig = self.s_macs / (d.tiles * DIG_LANES) * d.t_cycle_s;
        let e_dig = self.s_macs * E_DIG_MAC * d.s_e;

        let latency = lat_compute + lat_swap + lat_noc + lat_spill + lat_dig;
        let mut energy = e_array + e_adc_total + e_drv + e_swap + e_noc + e_glb + e_spill + e_dig;

        // leakage over the whole inference
        let p_leak = P_LEAK_W_PER_MM2 * (32.0 / d.tech).sqrt() * d.v * area;
        energy += p_leak * latency;

        Some(Metrics {
            energy,
            latency,
            area,
            feasible: capacity_ok && d.timing_ok && area <= AREA_CONSTR_MM2,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{idx, SearchSpace};
    use crate::util::rng::Rng;
    use crate::workloads::{by_name, Workload, ALL_NAMES};

    fn rel(a: f64, b: f64) -> f64 {
        if a == b {
            0.0
        } else {
            (a - b).abs() / b.abs().max(f64::MIN_POSITIVE)
        }
    }

    #[test]
    fn rep_table_size_matches_rep_max() {
        assert_eq!(REP_MAX_I as f64, REP_MAX);
    }

    #[test]
    fn grid_dpw_covers_every_bits_cell_value() {
        for bits in crate::space::ALL_BITS_CELL {
            let dpw = (W_BITS / bits).ceil();
            assert!(
                grid_pos(&GRID_DPW, dpw).is_some(),
                "bits {bits} -> dpw {dpw} missing from GRID_DPW"
            );
        }
    }

    /// Ingested and synthetic geometries are covered exactly like the
    /// hand-coded nets: bucket keys are built per grid point regardless
    /// of layer shapes, so any workload that passes ingestion validation
    /// rides the O(1) path for every on-grid design — the `population`
    /// experiment's "no off-grid fallbacks at 200-net scale" guarantee.
    #[test]
    fn buckets_cover_ingested_and_synthetic_geometries() {
        let spaces = [
            (SearchSpace::rram(), MemoryTech::Rram),
            (SearchSpace::sram(), MemoryTech::Sram),
        ];
        let dist = crate::ingest::WorkloadDistribution::named("mixed").unwrap();
        let mut pop = dist.population(12, 77).workloads;
        // an ingested workload (JSON round trip of a canonical net)
        let text = crate::ingest::workload_to_json(&by_name("mobilenetv3").unwrap()).to_string();
        pop.push(crate::ingest::parse_workload_text(&text, "ingested").unwrap());
        for w in &pop {
            let cw = w.compiled();
            for (space, mem) in &spaces {
                let mut rng = Rng::seed_from(11);
                for _ in 0..20 {
                    let raw = space.decode(&space.random(&mut rng));
                    let view = DesignView::new(&raw, *mem);
                    assert!(cw.covers(&view), "{}: {} off-grid", w.name, space.variant);
                }
            }
        }
    }

    /// Bucket keys cover every (rows, cols, bits) combination of every
    /// space variant — the compiled path must never fall back on-grid.
    #[test]
    fn buckets_cover_every_space_combination() {
        let spaces = [
            (SearchSpace::rram(), MemoryTech::Rram),
            (SearchSpace::rram_reduced(), MemoryTech::Rram),
            (SearchSpace::sram(), MemoryTech::Sram),
            (SearchSpace::sram_tech(), MemoryTech::Sram),
        ];
        let cw = by_name("resnet18").unwrap().compiled().clone();
        for (space, mem) in spaces {
            for &rows in &space.params[idx::ROWS].values {
                assert!(grid_pos(&GRID_ROWS_COLS, rows).is_some(), "rows {rows}");
            }
            for &cols in &space.params[idx::COLS].values {
                assert!(grid_pos(&GRID_ROWS_COLS, cols).is_some(), "cols {cols}");
            }
            // every decoded design's geometry lands in a bucket
            let mut rng = Rng::seed_from(7);
            for _ in 0..50 {
                let raw = space.decode(&space.random(&mut rng));
                let view = DesignView::new(&raw, mem);
                assert!(cw.covers(&view), "{} off-grid: {raw:?}", space.variant);
            }
        }
    }

    #[test]
    fn prefix_sums_are_monotone() {
        for name in ALL_NAMES {
            let w = by_name(name).unwrap();
            let cw = w.compiled();
            // io prefix sums non-decreasing, io sorted ascending
            for pair in cw.io_prefix.windows(2) {
                assert!(pair[0] <= pair[1], "{name}: io_prefix decreased");
            }
            for pair in cw.io_sorted.windows(2) {
                assert!(pair[0] <= pair[1], "{name}: io_sorted unsorted");
            }
            // rep table non-increasing in rep; rep=1 recovers Σ passes
            for r in 1..REP_MAX_I {
                assert!(cw.rep_sums[r - 1] >= cw.rep_sums[r], "{name}: rep_sums");
            }
            let sum_passes: f64 = w
                .layers
                .iter()
                .filter(|l| !l.dynamic())
                .map(|l| l.passes as f64)
                .sum();
            assert_eq!(cw.rep_sums[0], sum_passes, "{name}");
            // per-bucket prefix sums monotone in both index and rep
            for b in &cw.buckets {
                assert_eq!(b.rep_prefix.len(), b.xb_distinct.len() + 1);
                for pair in b.xb_distinct.windows(2) {
                    assert!(pair[0] < pair[1], "{name}: xb_distinct unsorted");
                }
                for r in 1..=REP_MAX_I {
                    for pair in b.rep_prefix.windows(2) {
                        assert!(pair[0][r - 1] <= pair[1][r - 1], "{name}");
                    }
                }
            }
        }
    }

    #[test]
    fn empty_workload_compiles_to_zero_cost() {
        let w = Workload::new("empty", Vec::new());
        let raw = [512.0, 256.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0];
        let view = DesignView::new(&raw, MemoryTech::Rram);
        let cw = w.compiled();
        let m = cw.metrics(MemoryTech::Rram, &view, 100.0).unwrap();
        assert_eq!(m.energy, 0.0);
        assert_eq!(m.latency, 0.0);
        assert!(m.feasible);
        assert_eq!(cw.xbar_demand(&view), Some((0.0, 0.0)));
    }

    #[test]
    fn all_dynamic_workload_matches_naive() {
        let gpt2 = by_name("gpt2-medium").unwrap();
        let dynamic: Vec<_> = gpt2
            .layers
            .iter()
            .filter(|l| l.dynamic())
            .cloned()
            .collect();
        assert!(!dynamic.is_empty());
        let w = Workload::new("attn-only", dynamic);
        let ev = super::super::NativeEvaluator::new(MemoryTech::Rram);
        let raw = [512.0, 256.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0];
        let a = ev.evaluate(&raw, &w);
        let b = ev.evaluate_naive(&raw, &w);
        assert!(rel(a.energy, b.energy) <= 1e-9);
        assert!(rel(a.latency, b.latency) <= 1e-9);
        assert_eq!(a.feasible, b.feasible);
        // no static layers: zero crossbar demand, swapping never engages
        let view = DesignView::new(&raw, MemoryTech::Sram);
        assert_eq!(w.compiled().xbar_demand(&view), Some((0.0, 0.0)));
    }

    #[test]
    fn sram_rep_sum_matches_per_layer_definition() {
        let w = by_name("vgg16").unwrap();
        let cw = w.compiled();
        let mut rng = Rng::seed_from(11);
        for _ in 0..200 {
            // macros from the SRAM space's (c_per_tile × t_per_router ×
            // g_per_chip) products, plus adversarial small values
            let macros = match rng.below(4) {
                0 => 4.0 * 2.0 * 2.0,
                1 => (1 + rng.below(40)) as f64,
                2 => 32.0 * 16.0 * 64.0,
                _ => (1 + rng.below(4000)) as f64,
            };
            let (rows, cols, dpw) = (512.0, 512.0, 8.0);
            let b = &cw.buckets[cw.bucket_index(rows, cols, dpw).unwrap()];
            let expect: f64 = w
                .layers
                .iter()
                .filter(|l| !l.dynamic())
                .map(|l| {
                    let xb = (l.k as f64 / rows).ceil() * (l.n as f64 * dpw / cols).ceil();
                    let rep = (macros / xb.max(1.0)).floor().clamp(1.0, REP_MAX);
                    (l.passes as f64 / rep).ceil()
                })
                .sum();
            assert_eq!(b.sram_rep_sum(macros), expect, "macros={macros}");
        }
    }

    #[test]
    fn spill_sum_matches_per_layer_definition() {
        let w = by_name("mobilebert").unwrap();
        let cw = w.compiled();
        for glb_kb in [0.0, 256.0, 1024.0, 4096.0, 16384.0, 65536.0, 1e9] {
            let glb = glb_kb * 1024.0;
            let expect: f64 = w
                .layers
                .iter()
                .filter(|l| !l.dynamic())
                .map(|l| ((l.in_bytes + l.out_bytes) as f64 - glb).max(0.0))
                .sum();
            assert_eq!(cw.spill_sum(glb), expect, "glb={glb}");
        }
    }

    #[test]
    fn off_grid_geometry_returns_none() {
        let w = by_name("alexnet").unwrap();
        let raw = [100.0, 256.0, 16.0, 8.0, 24.0, 2.0, 0.85, 2.0, 4096.0, 32.0];
        let view = DesignView::new(&raw, MemoryTech::Rram);
        let cw = w.compiled();
        assert!(!cw.covers(&view));
        assert!(cw.metrics(MemoryTech::Rram, &view, 100.0).is_none());
        assert!(cw.xbar_demand(&view).is_none());
    }
}
