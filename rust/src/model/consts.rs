//! Hardware model constants, calibrated to ISAAC / NeuroSim ballparks at
//! the 32 nm, 1.0 V reference point.
//!
//! **Single source of truth** shared with the AOT-compiled JAX evaluator:
//! `python/compile/hwspec.py` mirrors every value below, and the
//! cross-language consistency test (`rust/tests/integration_runtime.rs`)
//! plus `python/tests/test_hwspec_sync.py` keep them in lock-step. If you
//! change a number here, change it there.
//!
//! Scaling conventions (see DESIGN.md §3):
//! * area ∝ (tech/32)²
//! * dynamic energy ∝ (tech/32) · V²
//! * min cycle time: alpha-power law `t_min = T_MIN0 · √(tech/32) ·
//!   d(V)/d(1.0)` with `d(V) = V/(V−VTH)^ALPHA`
//! * leakage power ∝ (32/tech)^0.5 · V · area

/// Input activation bit width (bit-serial application).
pub const IN_BITS: f64 = 8.0;
/// Weight bit width (8-bit quantization throughout the paper).
pub const W_BITS: f64 = 8.0;

// ---- per-event energies (J) at 32 nm, 1.0 V -------------------------------

/// RRAM cell activation energy per cell per input bit.
pub const E_CELL_RRAM: f64 = 0.2e-15;
/// SRAM compute-cell energy per cell per input bit.
pub const E_CELL_SRAM: f64 = 0.05e-15;
/// 8-bit SAR ADC conversion energy (RRAM macro).
pub const E_ADC_RRAM: f64 = 2.0e-12;
/// 8-bit ADC conversion energy (SRAM macro — smaller dynamic range).
pub const E_ADC_SRAM: f64 = 1.0e-12;
/// Row driver / 1-bit DAC energy per row per bit per column-group.
pub const E_DRV: f64 = 0.05e-12;
/// NoC energy per byte per hop.
pub const E_NOC_BYTE: f64 = 1.0e-12;
/// Global buffer access energy per byte.
pub const E_GLB_BYTE: f64 = 0.5e-12;
/// LPDDR4 DRAM access energy per byte (≈4 pJ/bit).
pub const E_DRAM_BYTE: f64 = 32.0e-12;
/// SRAM array write energy per byte (weight swapping).
pub const E_SRAM_WRITE_BYTE: f64 = 0.5e-12;
/// Digital vector-unit MAC energy (dynamic transformer matmuls).
pub const E_DIG_MAC: f64 = 0.1e-12;

// ---- bandwidth / throughput ------------------------------------------------

/// LPDDR4 sustained bandwidth (bytes/s).
pub const DRAM_BW: f64 = 25.6e9;
/// Router payload bytes per cycle per router (32-bit flit).
pub const NOC_BYTES_PER_CYCLE: f64 = 4.0;
/// ADC conversions per array cycle (pipelined SAR).
pub const ADC_CONV_PER_CYCLE: f64 = 4.0;
/// Digital vector-unit MAC lanes per tile.
pub const DIG_LANES: f64 = 128.0;
/// Maximum useful weight-replication factor: input broadcast fan-out and
/// the partial-sum reduction tree bound how far spare macros can
/// parallelize one layer (ISAAC replicates early layers only a few times).
/// Without this cap small workloads parallelize infinitely and the
/// joint-vs-largest-workload trade-off of the paper degenerates.
pub const REP_MAX: f64 = 8.0;

// ---- areas (mm²) at 32 nm ---------------------------------------------------

/// RRAM cell footprint in F² (1T1R).
pub const CELL_F2_RRAM: f64 = 4.0;
/// SRAM compute cell footprint in F² (8T-ish CIM bitcell).
pub const CELL_F2_SRAM: f64 = 160.0;
/// Crossbar array peripheral overhead multiplier (sense, mux, decode).
pub const ARRAY_OVH: f64 = 1.3;
/// One 8-bit SAR ADC.
pub const ADC_AREA_MM2: f64 = 0.014;
/// Row drivers / DACs per macro.
pub const DRV_AREA_MM2: f64 = 0.004;
/// Input/output buffer per macro.
pub const MACRO_BUF_AREA_MM2: f64 = 0.004;
/// Shared buffer + control per tile.
pub const TILE_BUF_AREA_MM2: f64 = 0.05;
/// One NoC router.
pub const ROUTER_AREA_MM2: f64 = 0.15;
/// Chip I/O, PLL, misc (fixed).
pub const IO_AREA_MM2: f64 = 2.0;
/// Global buffer SRAM density (mm² per MB) at 32 nm.
pub const GLB_MM2_PER_MB: f64 = 1.6;

// ---- leakage / timing -------------------------------------------------------

/// Leakage power density at 32 nm, 1.0 V (W/mm²).
pub const P_LEAK_W_PER_MM2: f64 = 1.0e-3;
/// Threshold voltage for the alpha-power delay model (V).
pub const VTH: f64 = 0.3;
/// Alpha-power law exponent.
pub const DELAY_ALPHA: f64 = 1.3;
/// Minimum cycle time at 32 nm, 1.0 V (ns).
pub const T_MIN0_NS: f64 = 1.0;

// ---- constraints -------------------------------------------------------------

/// Area constraint applied across all paper experiments (mm²).
pub const AREA_CONSTR_MM2: f64 = 800.0;

/// Alpha-power delay factor `d(V) = V/(V−VTH)^ALPHA`, normalized by the
/// caller against `d(1.0)`.
#[inline]
pub fn delay_factor(v: f64) -> f64 {
    v / (v - VTH).max(0.05).powf(DELAY_ALPHA)
}

/// Minimum feasible cycle time (ns) at voltage `v` and node `tech` (nm).
#[inline]
pub fn t_min_ns(v: f64, tech: f64) -> f64 {
    T_MIN0_NS * (tech / 32.0).sqrt() * delay_factor(v) / delay_factor(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tmin_monotone_in_voltage() {
        // lower voltage -> slower minimum cycle
        assert!(t_min_ns(0.65, 32.0) > t_min_ns(1.0, 32.0));
        // reference point is T_MIN0
        assert!((t_min_ns(1.0, 32.0) - T_MIN0_NS).abs() < 1e-12);
    }

    #[test]
    fn tmin_scales_with_tech() {
        assert!(t_min_ns(1.0, 90.0) > t_min_ns(1.0, 32.0));
        assert!(t_min_ns(0.8, 7.0) < t_min_ns(0.8, 32.0));
    }

    #[test]
    fn low_voltage_excludes_fastest_cycle() {
        // At 32nm / 0.65V the 1 ns cycle must be infeasible but 2 ns fine —
        // this is the V/f coupling the optimizer has to navigate.
        let t = t_min_ns(0.65, 32.0);
        assert!(t > 1.0 && t < 2.0, "t_min(0.65V,32nm) = {t}");
    }
}
