//! Deterministic, out-of-band observability: process-wide counters,
//! hierarchical timing spans, and a per-generation search trace.
//!
//! The search/cache/orchestrator stack computes rich internal signals
//! (memo hit rates, surrogate accept rates, per-generation bests, lease
//! steals) and — before this module — threw them away. Telemetry makes
//! them visible without perturbing anything the determinism contract
//! pins:
//!
//! * **Counters** are relaxed [`AtomicU64`]s bumped at the existing hot
//!   sites (eval-memo lookups per shard, accuracy-memo lookups, exact
//!   evaluations, surrogate screen accept/reject, journal appends +
//!   fsyncs, lease claims/steals/heartbeats, cell retries/quarantines,
//!   artifact writes). They never feed back into scores, RNG streams,
//!   or control flow.
//! * **Spans** accumulate wall-clock per fixed [`Stage`] (count +
//!   total nanoseconds) via a drop guard; rendering happens only in
//!   `imcopt trace` and the counters snapshot, where wall fields are
//!   masked under `--stable` exactly like report timings.
//! * **Trace events** (per-generation best/median/violation/accept rate,
//!   Pareto front size + hypervolume) append schema-pinned JSONL lines
//!   under `<out-dir>/telemetry/` — `trace.jsonl` in-process,
//!   `trace-w<i>.jsonl` per orchestrator worker. Trace files are
//!   append-only and excluded from resume byte-diff checks.
//!
//! Enablement: telemetry is **on by default**; the `IMCOPT_TELEMETRY=0`
//! environment variable (or [`set_enabled`]) disables it. Because the
//! toggle is an env var it propagates to spawned orchestrator workers
//! without widening the worker argv, and it is deliberately **not** part
//! of [`config_fingerprint`](crate::experiments::config_fingerprint):
//! a run checkpointed with telemetry on resumes cleanly with it off and
//! vice versa. The whole layer is strictly out of band — reports,
//! journals, and artifacts are byte-identical with telemetry on or off,
//! at any `--threads`/`--workers` count (see
//! `tests/telemetry_determinism.rs` and the ≤2% `score_batch` overhead
//! gate in `benches/telemetry.rs`).

use crate::util::json::Json;
use crate::util::write_atomic;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Eval-memo shard count mirrored from
/// [`ShardedCache`](crate::util::shards::ShardedCache); per-shard hit
/// counters index modulo this.
pub const EVAL_SHARDS: usize = 16;

// ---------------------------------------------------------------------------
// enablement
// ---------------------------------------------------------------------------

/// 0 = uninitialised (consult `IMCOPT_TELEMETRY`), 1 = on, 2 = off.
static STATE: AtomicU8 = AtomicU8::new(0);

/// Is telemetry collection active? Defaults to `true`; the first call
/// latches `IMCOPT_TELEMETRY` (`0` disables) unless [`set_enabled`] ran
/// first.
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let on = std::env::var("IMCOPT_TELEMETRY")
                .map(|v| v != "0")
                .unwrap_or(true);
            STATE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
            on
        }
    }
}

/// Force telemetry on/off for this process (tests and benches; the env
/// var is the user-facing switch).
pub fn set_enabled(on: bool) {
    STATE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// counters
// ---------------------------------------------------------------------------

macro_rules! scalar_counters {
    ($($name:ident),* $(,)?) => {
        /// Process-wide event counters (all relaxed; order between
        /// counters is never inspected).
        #[derive(Debug)]
        pub struct Counters {
            /// Eval-memo hits, striped by cache shard.
            pub eval_memo_hits: [AtomicU64; EVAL_SHARDS],
            $(pub $name: AtomicU64,)*
        }

        impl Counters {
            const fn new() -> Counters {
                #[allow(clippy::declare_interior_mutable_const)]
                const Z: AtomicU64 = AtomicU64::new(0);
                Counters { eval_memo_hits: [Z; EVAL_SHARDS], $($name: Z,)* }
            }

            fn reset(&self) {
                for s in &self.eval_memo_hits {
                    s.store(0, Ordering::Relaxed);
                }
                $(self.$name.store(0, Ordering::Relaxed);)*
            }

            fn scalars(&self) -> Vec<(&'static str, u64)> {
                vec![$((stringify!($name), self.$name.load(Ordering::Relaxed)),)*]
            }
        }
    };
}

scalar_counters!(
    eval_memo_misses,
    acc_memo_calls,
    acc_memo_misses,
    exact_evals,
    screen_accepted,
    screened_out,
    journal_appends,
    journal_syncs,
    lease_claims,
    lease_steals,
    lease_heartbeats,
    cell_retries,
    cells_quarantined,
    cells_computed,
    cells_reused,
    artifact_writes,
);

static COUNTERS: Counters = Counters::new();

/// The live counter block (read-only access for tests and `trace`).
pub fn counters() -> &'static Counters {
    &COUNTERS
}

#[inline]
fn bump(c: &AtomicU64, n: u64) {
    if enabled() {
        c.fetch_add(n, Ordering::Relaxed);
    }
}

/// An eval-memo lookup was served from the cache (`shard` = the striped
/// cache's stripe index for the key).
#[inline]
pub fn eval_memo_hit(shard: usize) {
    if enabled() {
        COUNTERS.eval_memo_hits[shard % EVAL_SHARDS].fetch_add(1, Ordering::Relaxed);
    }
}

/// An eval-memo lookup missed.
#[inline]
pub fn eval_memo_miss() {
    bump(&COUNTERS.eval_memo_misses, 1);
}

/// An accuracy-memo lookup ran (`miss` = the closure actually computed).
#[inline]
pub fn acc_memo_lookup(miss: bool) {
    bump(&COUNTERS.acc_memo_calls, 1);
    if miss {
        bump(&COUNTERS.acc_memo_misses, 1);
    }
}

/// `n` designs reached the exact evaluator.
#[inline]
pub fn exact_evals(n: usize) {
    bump(&COUNTERS.exact_evals, n as u64);
}

/// A surrogate screen pass kept `accepted` of `accepted + rejected`
/// candidates for exact evaluation.
#[inline]
pub fn screen_selected(accepted: usize, rejected: usize) {
    bump(&COUNTERS.screen_accepted, accepted as u64);
    bump(&COUNTERS.screened_out, rejected as u64);
}

/// `n` journal lines were appended (cell journal, shared namespace, or
/// memo snapshot files).
#[inline]
pub fn journal_appends(n: usize) {
    bump(&COUNTERS.journal_appends, n as u64);
}

/// A journal append batch was fsynced.
#[inline]
pub fn journal_sync() {
    bump(&COUNTERS.journal_syncs, 1);
}

#[inline]
pub fn lease_claim() {
    bump(&COUNTERS.lease_claims, 1);
}

#[inline]
pub fn lease_steal() {
    bump(&COUNTERS.lease_steals, 1);
}

#[inline]
pub fn lease_heartbeat() {
    bump(&COUNTERS.lease_heartbeats, 1);
}

/// A cell failed and is being retried.
#[inline]
pub fn cell_retry() {
    bump(&COUNTERS.cell_retries, 1);
}

/// A cell exhausted its retries and was quarantined.
#[inline]
pub fn cell_quarantined() {
    bump(&COUNTERS.cells_quarantined, 1);
}

/// A checkpoint cell was computed fresh.
#[inline]
pub fn cell_computed() {
    bump(&COUNTERS.cells_computed, 1);
}

/// A checkpoint cell was replayed from the journal.
#[inline]
pub fn cell_reused() {
    bump(&COUNTERS.cells_reused, 1);
}

/// One report artifact file landed on disk.
#[inline]
pub fn artifact_write() {
    bump(&COUNTERS.artifact_writes, 1);
}

// ---------------------------------------------------------------------------
// notice occurrence counts (satellite: `notice (xN)` rendering)
// ---------------------------------------------------------------------------

static NOTICES: Mutex<BTreeMap<String, u64>> = Mutex::new(BTreeMap::new());

/// Count one occurrence of a deduplicated report notice. Called by
/// [`ExpContext::record_notice`](crate::coordinator::config::ExpContext::record_notice)
/// *before* its dedup check, so repeat recordings keep their count even
/// though `notices()` holds each string once.
///
/// Deliberately NOT gated on [`enabled`]: the count feeds the
/// `notice (xN)` suffix in report notes, and reports must stay
/// byte-identical whether telemetry is on or off. Unlike the hot-path
/// counters this fires only on rare degradation events, so the
/// unconditional map touch costs nothing.
pub fn count_notice(notice: &str) {
    let mut map = NOTICES.lock().unwrap();
    *map.entry(notice.to_string()).or_insert(0) += 1;
}

/// How many times `notice` was recorded.
pub fn notice_count(notice: &str) -> u64 {
    NOTICES.lock().unwrap().get(notice).copied().unwrap_or(0)
}

// ---------------------------------------------------------------------------
// timing spans
// ---------------------------------------------------------------------------

/// The fixed set of instrumented stages. `depth` encodes the static
/// nesting used by `imcopt trace` rendering (evaluate_misses runs inside
/// score_batch, which runs inside a checkpoint cell compute).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    CellCompute = 0,
    ScoreBatch = 1,
    EvaluateMisses = 2,
    SurrogateFit = 3,
    SurrogateRank = 4,
    ArtifactWrite = 5,
}

/// (name, nesting depth) per stage, in render order.
pub const STAGES: [(&str, usize); 6] = [
    ("cell_compute", 0),
    ("score_batch", 1),
    ("evaluate_misses", 2),
    ("surrogate_fit", 1),
    ("surrogate_rank", 1),
    ("artifact_write", 0),
];

struct SpanCell {
    count: AtomicU64,
    total_ns: AtomicU64,
}

impl SpanCell {
    const fn new() -> SpanCell {
        SpanCell {
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
        }
    }
}

static SPANS: [SpanCell; 6] = [
    SpanCell::new(),
    SpanCell::new(),
    SpanCell::new(),
    SpanCell::new(),
    SpanCell::new(),
    SpanCell::new(),
];

/// RAII timing guard; records (count += 1, total_ns += elapsed) for its
/// stage on drop. A guard taken while telemetry is disabled is a no-op
/// (no clock read on either end).
pub struct SpanGuard {
    stage: Option<(usize, Instant)>,
}

/// Open a timing span for `stage`.
#[inline]
pub fn span(stage: Stage) -> SpanGuard {
    SpanGuard {
        stage: enabled().then(|| (stage as usize, Instant::now())),
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((idx, start)) = self.stage {
            let ns = start.elapsed().as_nanos().min(u64::MAX as u128) as u64;
            SPANS[idx].count.fetch_add(1, Ordering::Relaxed);
            SPANS[idx].total_ns.fetch_add(ns, Ordering::Relaxed);
        }
    }
}

/// (stage name, call count, total nanoseconds) per stage, render order.
pub fn span_totals() -> Vec<(&'static str, u64, u64)> {
    STAGES
        .iter()
        .enumerate()
        .map(|(i, (name, _))| {
            (
                *name,
                SPANS[i].count.load(Ordering::Relaxed),
                SPANS[i].total_ns.load(Ordering::Relaxed),
            )
        })
        .collect()
}

// ---------------------------------------------------------------------------
// trace sink
// ---------------------------------------------------------------------------

struct Sink {
    /// `<out-dir>/telemetry/` — snapshots land here too.
    dir: PathBuf,
    /// `trace.jsonl` or `trace-w<i>.jsonl` inside `dir`.
    trace_path: PathBuf,
    stable: bool,
    worker: Option<usize>,
    t0: Instant,
    /// Current (experiment, cell key, seed) context for trace events.
    experiment: String,
    cell: String,
    seed: u64,
}

static SINK: Mutex<Option<Sink>> = Mutex::new(None);

/// Install the process-wide trace sink: creates `<out-dir>/telemetry/`
/// and routes subsequent trace events to `trace.jsonl` (or
/// `trace-w<i>.jsonl` for orchestrator workers). Replaces any previous
/// sink. No-op (and no directory creation) when telemetry is disabled.
pub fn install_sink(out_dir: &Path, stable: bool, worker: Option<usize>) {
    if !enabled() {
        return;
    }
    let dir = out_dir.join("telemetry");
    let _ = std::fs::create_dir_all(&dir);
    let trace_path = dir.join(match worker {
        Some(w) => format!("trace-w{w}.jsonl"),
        None => "trace.jsonl".to_string(),
    });
    *SINK.lock().unwrap() = Some(Sink {
        dir,
        trace_path,
        stable,
        worker,
        t0: Instant::now(),
        experiment: String::new(),
        cell: String::new(),
        seed: 0,
    });
}

/// Drop the trace sink (tests).
pub fn uninstall_sink() {
    *SINK.lock().unwrap() = None;
}

/// Is a sink installed and telemetry on? Callers computing trace-only
/// values (e.g. per-generation hypervolume) gate on this.
pub fn active() -> bool {
    enabled() && SINK.lock().unwrap().is_some()
}

/// Set the (experiment, cell, seed) context stamped on trace events.
/// Called by `run_session` at experiment granularity and refined by
/// `common::opt_cell` per checkpoint cell.
pub fn set_cell(experiment: &str, cell: &str, seed: u64) {
    if !enabled() {
        return;
    }
    if let Some(sink) = SINK.lock().unwrap().as_mut() {
        sink.experiment = experiment.to_string();
        sink.cell = cell.to_string();
        sink.seed = seed;
    }
}

/// Refine just the cell key — and, when known, the derived seed — of the
/// trace context, keeping the experiment set by `run_session`. Called by
/// the checkpoint cell wrappers (`common::opt_cell` / `ga_cell`) so
/// generation events carry the `<exp>:<scenario>:<unit>` key of the cell
/// that produced them.
pub fn set_cell_key(cell: &str, seed: Option<u64>) {
    if !enabled() {
        return;
    }
    if let Some(sink) = SINK.lock().unwrap().as_mut() {
        sink.cell = cell.to_string();
        if let Some(s) = seed {
            sink.seed = s;
        }
    }
}

/// Append one event line; `extra` is spliced after the common envelope.
fn emit(event: &str, extra: Vec<(&str, Json)>) {
    let mut guard = SINK.lock().unwrap();
    let Some(sink) = guard.as_mut() else {
        return;
    };
    let mut fields: Vec<(&str, Json)> = vec![
        ("event", Json::Str(event.to_string())),
        ("experiment", Json::Str(sink.experiment.clone())),
        ("cell", Json::Str(sink.cell.clone())),
        ("seed", Json::Num(sink.seed as f64)),
    ];
    fields.extend(extra);
    if !sink.stable {
        // wall-clock is masked under --stable, like report timings
        let ms = sink.t0.elapsed().as_secs_f64() * 1e3;
        fields.push(("wall_ms", Json::Num(ms)));
    }
    let line = Json::obj(fields).to_string();
    // append-only + fsync, mirroring the checkpoint journal discipline:
    // a torn tail is at worst one partial line `imcopt trace` skips
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&sink.trace_path)
    {
        if f.write_all(format!("{line}\n").as_bytes()).is_ok() {
            let _ = f.sync_data();
        }
    }
}

/// Emit a per-generation scalar-search trace event. `scores` is the
/// generation's raw score vector (median and violation rate derive from
/// it); `accepted`/`pool` describe the surrogate screen (equal when no
/// screening ran). Cheap no-op without an active sink.
pub fn emit_generation(
    gen: usize,
    evals: usize,
    best: f64,
    scores: &[f64],
    accepted: usize,
    pool: usize,
) {
    if !active() {
        return;
    }
    let mut sorted: Vec<f64> = scores.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let median = if sorted.is_empty() {
        f64::NAN
    } else {
        sorted[sorted.len() / 2]
    };
    let violations = scores.iter().filter(|s| !s.is_finite()).count();
    let violation_rate = if scores.is_empty() {
        0.0
    } else {
        violations as f64 / scores.len() as f64
    };
    let accept_rate = if pool == 0 {
        1.0
    } else {
        accepted as f64 / pool as f64
    };
    emit(
        "generation",
        vec![
            ("gen", Json::Num(gen as f64)),
            ("evals", Json::Num(evals as f64)),
            ("best", Json::f64(best)),
            ("median", Json::f64(median)),
            ("violation_rate", Json::Num(violation_rate)),
            ("screen_accept_rate", Json::Num(accept_rate)),
        ],
    );
}

/// Emit a per-generation Pareto front trace event (NSGA-II mode).
pub fn emit_front(gen: usize, evals: usize, front_size: usize, hypervolume: f64) {
    if !active() {
        return;
    }
    emit(
        "front",
        vec![
            ("gen", Json::Num(gen as f64)),
            ("evals", Json::Num(evals as f64)),
            ("front_size", Json::Num(front_size as f64)),
            ("hypervolume", Json::f64(hypervolume)),
        ],
    );
}

// ---------------------------------------------------------------------------
// snapshots
// ---------------------------------------------------------------------------

/// The full counter/span/notice state as JSON (the payload of
/// `telemetry/counters[-w<i>].json`). `stable` masks span wall-clock.
pub fn counters_json(stable: bool) -> Json {
    let mut counters: Vec<(&str, Json)> = Vec::new();
    let shard_hits: Vec<u64> = COUNTERS
        .eval_memo_hits
        .iter()
        .map(|s| s.load(Ordering::Relaxed))
        .collect();
    counters.push((
        "eval_memo_hits",
        Json::Num(shard_hits.iter().sum::<u64>() as f64),
    ));
    counters.push((
        "eval_memo_hits_by_shard",
        Json::Arr(shard_hits.iter().map(|&h| Json::Num(h as f64)).collect()),
    ));
    for (name, v) in COUNTERS.scalars() {
        counters.push((name, Json::Num(v as f64)));
    }
    counters.push((
        "offgrid_fallbacks",
        Json::Num(crate::model::offgrid_fallbacks() as f64),
    ));

    let spans = Json::Obj(
        span_totals()
            .into_iter()
            .map(|(name, count, ns)| {
                let mut fields = vec![("count", Json::Num(count as f64))];
                if !stable {
                    fields.push(("total_ms", Json::Num(ns as f64 / 1e6)));
                }
                (name.to_string(), Json::obj(fields))
            })
            .collect(),
    );

    let notices = Json::Obj(
        NOTICES
            .lock()
            .unwrap()
            .iter()
            .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
            .collect(),
    );

    Json::obj(vec![
        ("schema", Json::Str("imcopt.telemetry.counters.v1".into())),
        ("counters", Json::obj(counters)),
        ("spans", spans),
        ("notices", notices),
    ])
}

/// Write the counters snapshot next to the trace file
/// (`counters.json` / `counters-w<i>.json`), atomically. No-op without
/// an active sink.
pub fn write_snapshot() {
    if !enabled() {
        return;
    }
    let (dir, stable, worker) = {
        let guard = SINK.lock().unwrap();
        let Some(sink) = guard.as_ref() else {
            return;
        };
        (sink.dir.clone(), sink.stable, sink.worker)
    };
    let mut doc = counters_json(stable);
    if let Json::Obj(m) = &mut doc {
        m.insert(
            "worker".into(),
            match worker {
                Some(w) => Json::Num(w as f64),
                None => Json::Null,
            },
        );
    }
    let name = match worker {
        Some(w) => format!("counters-w{w}.json"),
        None => "counters.json".to_string(),
    };
    let _ = write_atomic(&dir.join(name), &format!("{doc}\n"));
}

/// Zero all counters, spans, and notice counts (tests and benches).
pub fn reset() {
    COUNTERS.reset();
    for s in &SPANS {
        s.count.store(0, Ordering::Relaxed);
        s.total_ns.store(0, Ordering::Relaxed);
    }
    NOTICES.lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes this module's tests: they flip the process-wide
    /// enabled flag and the sink, which must not interleave.
    static LOCK: Mutex<()> = Mutex::new(());

    /// Other lib tests share these process-wide statics, so assertions
    /// here are delta-based (>=) rather than exact.
    #[test]
    fn counters_and_spans_accumulate() {
        let _l = LOCK.lock().unwrap();
        set_enabled(true);
        let hits0: u64 = counters()
            .eval_memo_hits
            .iter()
            .map(|s| s.load(Ordering::Relaxed))
            .sum();
        let miss0 = counters().eval_memo_misses.load(Ordering::Relaxed);
        eval_memo_hit(3);
        eval_memo_hit(3 + EVAL_SHARDS); // same stripe, wraps
        eval_memo_miss();
        let hits1: u64 = counters()
            .eval_memo_hits
            .iter()
            .map(|s| s.load(Ordering::Relaxed))
            .sum();
        assert!(hits1 >= hits0 + 2);
        assert!(counters().eval_memo_misses.load(Ordering::Relaxed) >= miss0 + 1);

        let (_, c0, _) = span_totals()[1]; // score_batch
        {
            let _g = span(Stage::ScoreBatch);
        }
        let (name, c1, _) = span_totals()[1];
        assert_eq!(name, "score_batch");
        assert!(c1 >= c0 + 1);
    }

    #[test]
    fn disabled_telemetry_is_a_no_op() {
        let _l = LOCK.lock().unwrap();
        set_enabled(false);
        // cells_quarantined is only bumped by the run_session quarantine
        // path, which no lib unit test exercises concurrently
        let before = counters().cells_quarantined.load(Ordering::Relaxed);
        cell_quarantined();
        {
            let g = span(Stage::ArtifactWrite);
            assert!(g.stage.is_none());
        }
        assert_eq!(counters().cells_quarantined.load(Ordering::Relaxed), before);
        // notice counts feed the `(xN)` suffix in report notes, so they
        // deliberately keep counting while disabled — reports must not
        // change bytes when telemetry is switched off
        count_notice("telemetry-test: counted even while disabled");
        assert_eq!(notice_count("telemetry-test: counted even while disabled"), 1);
        set_enabled(true);
    }

    #[test]
    fn notice_counts_survive_dedup() {
        let _l = LOCK.lock().unwrap();
        set_enabled(true);
        let key = "telemetry-test: repeated notice";
        let n0 = notice_count(key);
        count_notice(key);
        count_notice(key);
        assert_eq!(notice_count(key), n0 + 2);
    }

    #[test]
    fn counters_json_shape_and_stable_masking() {
        let _l = LOCK.lock().unwrap();
        set_enabled(true);
        let doc = counters_json(false);
        assert_eq!(
            doc.get("schema").and_then(|s| s.as_str()),
            Some("imcopt.telemetry.counters.v1")
        );
        let c = doc.get("counters").unwrap();
        assert!(c.get("eval_memo_hits").is_some());
        assert_eq!(
            c.get("eval_memo_hits_by_shard").unwrap().as_arr().unwrap().len(),
            EVAL_SHARDS
        );
        assert!(c.get("exact_evals").is_some());
        assert!(c.get("offgrid_fallbacks").is_some());
        let spans = doc.get("spans").unwrap();
        assert!(spans.get("score_batch").unwrap().get("total_ms").is_some());
        // --stable masks wall-clock but keeps call counts
        let masked = counters_json(true);
        let sb = masked.get("spans").unwrap().get("score_batch").unwrap();
        assert!(sb.get("total_ms").is_none());
        assert!(sb.get("count").is_some());
        // document round-trips through the writer
        let text = doc.to_string();
        crate::util::json::parse(&text).expect("snapshot JSON parses");
    }

    #[test]
    fn emit_without_sink_is_cheap_and_silent() {
        let _l = LOCK.lock().unwrap();
        set_enabled(true);
        uninstall_sink();
        assert!(!active());
        emit_generation(0, 16, 1.0, &[1.0, 2.0, f64::INFINITY], 16, 16);
        emit_front(0, 16, 4, 0.5);
    }

    #[test]
    fn sink_writes_schema_shaped_trace_lines() {
        let _l = LOCK.lock().unwrap();
        set_enabled(true);
        let dir = std::env::temp_dir().join(format!("imcopt-telem-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        install_sink(&dir, true, None);
        set_cell("figX", "figX:scn:unit", 42);
        emit_generation(1, 32, 3.5, &[3.5, 4.0, f64::INFINITY, 5.0], 8, 32);
        emit_front(2, 64, 7, 0.25);
        uninstall_sink();
        let text =
            std::fs::read_to_string(dir.join("telemetry").join("trace.jsonl")).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let g = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(g.get("event").and_then(|e| e.as_str()), Some("generation"));
        assert_eq!(g.get("experiment").and_then(|e| e.as_str()), Some("figX"));
        assert_eq!(g.get("seed").and_then(|s| s.as_usize()), Some(42));
        assert_eq!(g.get("violation_rate").and_then(|v| v.as_f64()), Some(0.25));
        assert_eq!(g.get("screen_accept_rate").and_then(|v| v.as_f64()), Some(0.25));
        assert!(g.get("wall_ms").is_none(), "stable masks wall_ms");
        let f = crate::util::json::parse(lines[1]).unwrap();
        assert_eq!(f.get("event").and_then(|e| e.as_str()), Some("front"));
        assert_eq!(f.get("front_size").and_then(|s| s.as_usize()), Some(7));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
