//! Fault-tolerant multi-process orchestration for `imcopt run --workers N`.
//!
//! The orchestrator shards checkpoint **cells** — not experiments — across
//! N worker processes sharing one `--out-dir`:
//!
//! * [`lease`] — file-locked cell claims. A worker claims a cell by
//!   atomically creating a lease file; a heartbeat thread keeps the lease
//!   fresh, and leases of crashed/wedged workers go stale and are stolen
//!   after `IMCOPT_LEASE_MS`.
//! * [`supervisor`] — spawns the workers (each is `imcopt run` re-invoked
//!   with `IMCOPT_WORKER_ID` set), monitors exit statuses, restarts
//!   crashed workers with a capped backoff budget (`IMCOPT_MAX_RESTARTS`),
//!   and aggregates per-worker summaries plus the quarantine list into
//!   `<out_dir>/orchestrator_status.json`
//!   (`schemas/orchestrator_status.schema.json`).
//! * Panic isolation and per-experiment retry live in the session runner
//!   ([`crate::experiments::run_session`]): a panicking or faulted cell
//!   becomes an error, the experiment is retried with capped exponential
//!   backoff (journal replay makes a retry cost only the lost cell), and
//!   an experiment that keeps failing is **quarantined** so the rest of
//!   the sweep completes.
//!
//! Correctness rests on the repo's determinism contract: cells are pure
//! functions of (key, run config), so duplicated computation across
//! workers is harmless — the journals deduplicate by key, and `--stable`
//! reports are byte-identical at any worker count. The crash matrix in
//! `rust/tests/orchestrator_faults.rs` enforces exactly that, driven by
//! the deterministic fault harness in [`crate::util::fault`].
//!
//! Environment knobs (all optional):
//!
//! | variable | default | meaning |
//! |----------|---------|---------|
//! | `IMCOPT_LEASE_MS` | 30000 | lease staleness timeout |
//! | `IMCOPT_POLL_MS` | 50 | journal poll interval while waiting on a claim |
//! | `IMCOPT_CELL_RETRIES` | 2 | extra attempts per failing experiment |
//! | `IMCOPT_RETRY_MS` | 100 | backoff base (doubles per retry, capped 5s) |
//! | `IMCOPT_MAX_RESTARTS` | 2 | restarts per crashed worker before abandoning it |
//! | `IMCOPT_FAULT` | unset | fault-injection plan (see [`crate::util::fault`]) |

pub mod lease;
pub mod supervisor;

use crate::coordinator::ExpContext;
use crate::experiments::{self, RunSummary};
use crate::util::json::Json;
use anyhow::{Context, Result};
use lease::CellClaims;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

/// Worker exit code meaning "sweep finished, but some experiments are
/// quarantined" — the supervisor must not restart such a worker (retrying
/// won't help a deterministically poisoned cell), but must surface the
/// degradation.
pub const EXIT_QUARANTINED: i32 = 3;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Per-experiment retry schedule (panic isolation's second line of
/// defense): `1 + IMCOPT_CELL_RETRIES` attempts, sleeping
/// `IMCOPT_RETRY_MS * 2^retry` (capped at 5s) between them. Because every
/// attempt reopens the checkpoint with resume semantics, a retry replays
/// all journaled cells and re-runs only the one that failed.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    pub attempts: usize,
    pub backoff_base: Duration,
    pub backoff_cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            attempts: 1 + env_u64("IMCOPT_CELL_RETRIES", 2) as usize,
            backoff_base: Duration::from_millis(env_u64("IMCOPT_RETRY_MS", 100)),
            backoff_cap: Duration::from_secs(5),
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `retry` (1-based): base · 2^(retry-1),
    /// capped.
    pub fn backoff(&self, retry: usize) -> Duration {
        let factor = 1u32 << (retry.saturating_sub(1)).min(16) as u32;
        (self.backoff_base * factor).min(self.backoff_cap)
    }
}

/// Path of the per-worker status file (summary + quarantine list) the
/// supervisor aggregates.
pub fn worker_status_path(out_dir: &Path, worker: usize) -> std::path::PathBuf {
    out_dir
        .join("checkpoints")
        .join("workers")
        .join(format!("w{worker}.json"))
}

/// Path of a worker's redirected stdout+stderr log.
pub fn worker_log_path(out_dir: &Path, worker: usize) -> std::path::PathBuf {
    out_dir
        .join("checkpoints")
        .join("workers")
        .join(format!("w{worker}.log"))
}

/// Serialize a worker's run outcome for the supervisor.
pub fn summary_to_json(worker: usize, summary: &RunSummary, claims: &CellClaims) -> Json {
    let heartbeats = crate::telemetry::counters()
        .lease_heartbeats
        .load(std::sync::atomic::Ordering::Relaxed);
    Json::obj(vec![
        ("worker", Json::Num(worker as f64)),
        ("pid", Json::Num(std::process::id() as f64)),
        ("executed", Json::Num(summary.executed as f64)),
        ("replayed", Json::Num(summary.replayed as f64)),
        ("cells_reused", Json::Num(summary.cells_reused as f64)),
        ("cells_computed", Json::Num(summary.cells_computed as f64)),
        (
            "cells_completed",
            Json::Num((summary.cells_computed + summary.cells_reused) as f64),
        ),
        ("claims", Json::Num(claims.claim_count() as f64)),
        ("steals", Json::Num(claims.steal_count() as f64)),
        ("heartbeats", Json::Num(heartbeats as f64)),
        (
            "quarantined",
            Json::Arr(
                summary
                    .quarantined
                    .iter()
                    .map(|q| {
                        Json::obj(vec![
                            ("experiment", Json::Str(q.experiment.clone())),
                            ("reason", Json::Str(q.reason.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Entry point of a worker process (`IMCOPT_WORKER_ID` is set): run the
/// sweep coordinated through cell claims, write the worker status file,
/// and exit 0 (clean) or [`EXIT_QUARANTINED`]. Never returns on success.
pub fn worker_main(ids: &[&str], ctx: &ExpContext) -> Result<()> {
    let worker = ctx.worker_id.context("worker_main without IMCOPT_WORKER_ID")?;
    let claims = Arc::new(CellClaims::new(&ctx.out_dir, worker)?);
    let summary = experiments::run_session(ids, ctx, Some(&claims))?;
    println!("\n[worker {worker}] {}", summary.to_line());
    let status = summary_to_json(worker, &summary, &claims);
    let path = worker_status_path(&ctx.out_dir, worker);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    crate::util::write_atomic(&path, &(status.to_string() + "\n"))
        .with_context(|| format!("writing {}", path.display()))?;
    let code = if summary.quarantined.is_empty() {
        0
    } else {
        EXIT_QUARANTINED
    };
    drop(claims);
    std::process::exit(code);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RetryPolicy {
            attempts: 3,
            backoff_base: Duration::from_millis(100),
            backoff_cap: Duration::from_secs(5),
        };
        assert_eq!(p.backoff(1), Duration::from_millis(100));
        assert_eq!(p.backoff(2), Duration::from_millis(200));
        assert_eq!(p.backoff(3), Duration::from_millis(400));
        assert_eq!(p.backoff(30), Duration::from_secs(5), "cap holds");
    }

    #[test]
    fn status_paths_live_under_checkpoints() {
        let out = Path::new("/tmp/x");
        assert!(worker_status_path(out, 3).ends_with("checkpoints/workers/w3.json"));
        assert!(worker_log_path(out, 3).ends_with("checkpoints/workers/w3.log"));
    }
}
