//! Worker-process supervisor for `imcopt run --workers N`.
//!
//! The supervisor prepares the out-dir (clearing journals for fresh
//! sweeps, pre-initializing the shared bound cache so workers cannot race
//! its truncate-rewrite, and removing stale lease files), spawns N copies
//! of the current binary with `IMCOPT_WORKER_ID` set, and monitors their
//! exit statuses:
//!
//! * exit 0 — worker finished its sweep cleanly;
//! * exit [`EXIT_QUARANTINED`] — finished, but some experiments are
//!   quarantined (deterministic failures; restarting would not help);
//! * anything else (including death by signal) — a crash. The worker is
//!   restarted with capped exponential backoff up to `IMCOPT_MAX_RESTARTS`
//!   times, then **abandoned**: its lease claims go stale and the
//!   surviving workers steal them, so the sweep still completes.
//!
//! The run succeeds iff every requested experiment either has a stored
//! report or is quarantined. The outcome — per-worker states, restart
//! counts, the union quarantine list — lands atomically in
//! `<out_dir>/orchestrator_status.json`
//! (`schemas/orchestrator_status.schema.json`).

use super::{worker_log_path, worker_status_path, RetryPolicy, EXIT_QUARANTINED};
use crate::coordinator::{config::BackendChoice, ExpContext};
use crate::experiments::{self, Quarantine, RunSummary};
use crate::orchestrator::lease::CellClaims;
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, SystemTime};

/// Reconstruct the `imcopt run` argument vector a worker needs to execute
/// the same sweep as the supervisor's own invocation (minus `--workers`,
/// plus a per-worker thread share).
fn worker_args(ids: &[&str], ctx: &ExpContext, threads: usize) -> Vec<String> {
    let mut args: Vec<String> = vec!["run".into()];
    args.extend(ids.iter().map(|s| s.to_string()));
    for (flag, value) in [
        ("--seed", ctx.seed.to_string()),
        ("--out-dir", ctx.out_dir.display().to_string()),
        ("--threads", threads.to_string()),
        ("--topk", ctx.top_k.to_string()),
        ("--hold-k", ctx.hold_k.to_string()),
        ("--pareto-cap", ctx.pareto_cap.to_string()),
        // part of the config fingerprint: a worker defaulting to 1.0
        // while the supervisor screened would be rejected by bind_config
        ("--screen-frac", ctx.screen_frac.to_string()),
    ] {
        args.push(flag.into());
        args.push(value);
    }
    for (flag, value) in [
        ("--portfolio", &ctx.portfolio),
        ("--moo-mode", &ctx.moo_mode),
        ("--spec", &ctx.spec),
        // fingerprinted like --screen-frac: a worker defaulting to
        // nominal scoring under a robust supervisor would be rejected
        ("--robust", &ctx.robust),
    ] {
        if let Some(v) = value {
            args.push(flag.into());
            args.push(v.clone());
        }
    }
    if let Some(f) = ctx.acc_floor {
        args.push("--acc-floor".into());
        args.push(f.to_string());
    }
    if ctx.quick {
        args.push("--quick".into());
    }
    if ctx.stable {
        args.push("--stable".into());
    }
    match ctx.backend_choice {
        BackendChoice::Native => args.push("--native".into()),
        BackendChoice::Pjrt => args.push("--pjrt".into()),
        BackendChoice::Auto => {}
    }
    // workers always resume: the supervisor prepared the journals, and a
    // restarted worker must replay, not restart, the sweep
    args.push("--resume".into());
    args
}

fn spawn_worker(out_dir: &Path, worker: usize, args: &[String]) -> Result<Child> {
    let exe = std::env::current_exe().context("locating the imcopt binary")?;
    let log = worker_log_path(out_dir, worker);
    if let Some(dir) = log.parent() {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
    }
    let open_log = || {
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&log)
            .with_context(|| format!("opening worker log {}", log.display()))
    };
    Command::new(&exe)
        .args(args)
        .env("IMCOPT_WORKER_ID", worker.to_string())
        .stdin(Stdio::null())
        .stdout(open_log()?)
        .stderr(open_log()?)
        .spawn()
        .with_context(|| format!("spawning worker {worker} ({})", exe.display()))
}

#[derive(Debug)]
struct WorkerSlot {
    worker: usize,
    child: Option<Child>,
    restarts: usize,
    state: &'static str,
    exit_code: Option<i32>,
}

/// Parse a worker's status file into a partial [`RunSummary`] (best
/// effort: a crashed worker never wrote one).
fn read_worker_summary(out_dir: &Path, worker: usize) -> Option<(RunSummary, Json)> {
    let path = worker_status_path(out_dir, worker);
    let text = std::fs::read_to_string(&path).ok()?;
    let doc = json::parse(&text).ok()?;
    let field = |k: &str| doc.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
    let mut summary = RunSummary {
        executed: field("executed"),
        replayed: field("replayed"),
        cells_reused: field("cells_reused"),
        cells_computed: field("cells_computed"),
        quarantined: Vec::new(),
    };
    if let Some(qs) = doc.get("quarantined").and_then(|q| q.as_arr()) {
        for q in qs {
            if let (Some(exp), Some(reason)) = (
                q.get("experiment").and_then(|e| e.as_str()),
                q.get("reason").and_then(|r| r.as_str()),
            ) {
                summary.quarantined.push(Quarantine {
                    experiment: exp.to_string(),
                    reason: reason.to_string(),
                });
            }
        }
    }
    Some((summary, doc))
}

/// Age in milliseconds of the last observable sign of life from `worker`:
/// the newest mtime among its status file and any lease files it still
/// holds. `None` when neither exists (a worker that died before writing
/// either). An abandoned-but-leased worker shows a growing age here,
/// which is what makes a hung worker visible in `orchestrator_status.json`.
fn last_heartbeat_age_ms(out_dir: &Path, worker: usize) -> Option<u64> {
    let mut newest: Option<SystemTime> = None;
    let mut consider = |t: SystemTime| {
        newest = Some(match newest {
            Some(n) if n >= t => n,
            _ => t,
        });
    };
    if let Ok(modified) =
        std::fs::metadata(worker_status_path(out_dir, worker)).and_then(|m| m.modified())
    {
        consider(modified);
    }
    let claims_dir = out_dir.join("checkpoints").join("claims");
    if let Ok(entries) = std::fs::read_dir(&claims_dir) {
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|x| x.to_str()) != Some("lease") {
                continue;
            }
            let owner = std::fs::read_to_string(&path)
                .ok()
                .and_then(|text| json::parse(text.trim()).ok())
                .and_then(|doc| doc.get("worker").and_then(|w| w.as_usize()));
            if owner == Some(worker) {
                if let Ok(modified) = entry.metadata().and_then(|m| m.modified()) {
                    consider(modified);
                }
            }
        }
    }
    let newest = newest?;
    Some(
        SystemTime::now()
            .duration_since(newest)
            .unwrap_or_default()
            .as_millis() as u64,
    )
}

/// Sum the numeric telemetry counters across all per-worker snapshot
/// files (`<out_dir>/telemetry/counters-w<i>.json`) into one object, or
/// `None` when no worker wrote one (telemetry disabled).
fn aggregate_worker_counters(out_dir: &Path, workers: usize) -> Option<Json> {
    let mut sums: std::collections::BTreeMap<String, f64> = std::collections::BTreeMap::new();
    let mut found = false;
    for w in 0..workers {
        let path = out_dir
            .join("telemetry")
            .join(format!("counters-w{w}.json"));
        let Ok(text) = std::fs::read_to_string(&path) else {
            continue;
        };
        let Ok(doc) = json::parse(&text) else {
            continue;
        };
        if let Some(Json::Obj(counters)) = doc.get("counters") {
            found = true;
            for (k, v) in counters {
                if let Json::Num(x) = v {
                    *sums.entry(k.clone()).or_insert(0.0) += x;
                }
            }
        }
    }
    found.then(|| Json::Obj(sums.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()))
}

/// Run `ids` across `ctx.workers` worker processes sharing `ctx.out_dir`.
/// Returns the aggregated summary; errors if any experiment ended neither
/// completed nor quarantined (e.g. every worker holding its cells died
/// past the restart budget).
pub fn supervise(ids: &[&str], ctx: &ExpContext) -> Result<RunSummary> {
    let workers = ctx.workers.max(1);
    let config = experiments::config_fingerprint(ctx);
    // ---- prepare the out-dir ------------------------------------------
    if !ctx.resume {
        // workers always run with --resume, so the fresh-sweep clearing
        // that run_session would do must happen here, once, up front
        experiments::checkpoint::Checkpoint::reset_shared(&ctx.out_dir)?;
        for &id in ids {
            experiments::checkpoint::Checkpoint::for_experiment(
                &ctx.out_dir,
                id,
                false,
            )?;
        }
    }
    experiments::checkpoint::Checkpoint::ensure_shared(&ctx.out_dir, &config)?;
    // leases from a previous (killed) run must not stall this one
    CellClaims::clear(&ctx.out_dir)?;
    let workers_dir = ctx.out_dir.join("checkpoints").join("workers");
    if workers_dir.exists() {
        // stale status files would fool completion accounting
        std::fs::remove_dir_all(&workers_dir)
            .with_context(|| format!("clearing {}", workers_dir.display()))?;
    }
    // ---- spawn and monitor --------------------------------------------
    let max_restarts = std::env::var("IMCOPT_MAX_RESTARTS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(2);
    let policy = RetryPolicy::default();
    let threads = (ctx.threads / workers).max(1);
    let args = worker_args(ids, ctx, threads);
    println!(
        "[orchestrator] spawning {workers} workers over {} \
         (lease steal + restart budget {max_restarts})",
        ctx.out_dir.display()
    );
    let mut slots: Vec<WorkerSlot> = Vec::with_capacity(workers);
    for w in 0..workers {
        slots.push(WorkerSlot {
            worker: w,
            child: Some(spawn_worker(&ctx.out_dir, w, &args)?),
            restarts: 0,
            state: "running",
            exit_code: None,
        });
    }
    loop {
        let mut running = 0usize;
        for slot in &mut slots {
            let Some(child) = slot.child.as_mut() else {
                continue;
            };
            match child.try_wait().context("polling worker")? {
                None => running += 1,
                Some(status) => {
                    let code = status.code();
                    slot.exit_code = code;
                    slot.child = None;
                    match code {
                        Some(0) => slot.state = "done",
                        Some(c) if c == EXIT_QUARANTINED => {
                            // deterministic failures: restarting would hit
                            // the same poisoned cells again
                            slot.state = "done-quarantined";
                        }
                        _ => {
                            if slot.restarts < max_restarts {
                                slot.restarts += 1;
                                let backoff = policy.backoff(slot.restarts);
                                eprintln!(
                                    "[orchestrator] worker {} crashed \
                                     (status {status}); restart {}/{max_restarts} \
                                     in {}",
                                    slot.worker,
                                    slot.restarts,
                                    crate::util::fmt_duration(backoff)
                                );
                                std::thread::sleep(backoff);
                                slot.child =
                                    Some(spawn_worker(&ctx.out_dir, slot.worker, &args)?);
                                slot.state = "running";
                                running += 1;
                            } else {
                                eprintln!(
                                    "[orchestrator] worker {} abandoned after \
                                     {max_restarts} restarts; its leases will \
                                     go stale and be stolen",
                                    slot.worker
                                );
                                slot.state = "abandoned";
                            }
                        }
                    }
                }
            }
        }
        if running == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    // ---- aggregate and account ----------------------------------------
    let mut summary = RunSummary::default();
    let mut worker_status = Vec::new();
    for slot in &slots {
        let mut entry = vec![
            ("worker", Json::Num(slot.worker as f64)),
            ("state", Json::Str(slot.state.to_string())),
            ("restarts", Json::Num(slot.restarts as f64)),
            (
                "exit_code",
                match slot.exit_code {
                    Some(c) => Json::Num(c as f64),
                    None => Json::Null,
                },
            ),
        ];
        if let Some((ws, doc)) = read_worker_summary(&ctx.out_dir, slot.worker) {
            summary.merge(&ws);
            for k in [
                "claims",
                "steals",
                "cells_computed",
                "cells_reused",
                "cells_completed",
                "heartbeats",
            ] {
                if let Some(v) = doc.get(k) {
                    entry.push((k, v.clone()));
                }
            }
        }
        entry.push((
            "heartbeat_age_ms",
            match last_heartbeat_age_ms(&ctx.out_dir, slot.worker) {
                Some(ms) => Json::Num(ms as f64),
                None => Json::Null,
            },
        ));
        worker_status.push(Json::Obj(
            entry
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        ));
    }
    let quarantined_ids: Vec<String> = summary
        .quarantined
        .iter()
        .map(|q| q.experiment.clone())
        .collect();
    let mut completed = Vec::new();
    let mut missing = Vec::new();
    for &id in ids {
        let ckpt =
            experiments::checkpoint::Checkpoint::for_experiment(&ctx.out_dir, id, true)?;
        if ckpt.stored_report()?.is_some() {
            completed.push(id.to_string());
        } else if !quarantined_ids.contains(&id.to_string()) {
            missing.push(id.to_string());
        }
    }
    let status = Json::obj(vec![
        ("workers", Json::Num(workers as f64)),
        ("resume", Json::Bool(ctx.resume)),
        (
            "telemetry",
            aggregate_worker_counters(&ctx.out_dir, workers).unwrap_or(Json::Null),
        ),
        (
            "worker_status",
            Json::Arr(worker_status),
        ),
        (
            "completed",
            Json::Arr(completed.iter().map(|s| Json::Str(s.clone())).collect()),
        ),
        (
            "quarantined",
            Json::Arr(
                summary
                    .quarantined
                    .iter()
                    .map(|q| {
                        Json::obj(vec![
                            ("experiment", Json::Str(q.experiment.clone())),
                            ("reason", Json::Str(q.reason.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let status_path = ctx.out_dir.join("orchestrator_status.json");
    crate::util::write_atomic(&status_path, &(status.to_string() + "\n"))
        .with_context(|| format!("writing {}", status_path.display()))?;
    println!(
        "[orchestrator] {} completed, {} quarantined; status in {}",
        completed.len(),
        summary.quarantined.len(),
        status_path.display()
    );
    anyhow::ensure!(
        missing.is_empty(),
        "orchestrated sweep incomplete: {missing:?} neither completed nor \
         quarantined (see worker logs under {})",
        workers_dir.display()
    );
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_args_reconstruct_the_invocation() {
        let mut ctx = ExpContext::quick(7);
        ctx.stable = true;
        ctx.out_dir = "/tmp/sweep".into();
        ctx.portfolio = Some("cnn4-to-extras".into());
        ctx.screen_frac = 0.25;
        ctx.robust = Some("cvar0.25".into());
        ctx.acc_floor = Some(0.92);
        let args = worker_args(&["fig3", "table3"], &ctx, 2);
        let joined = args.join(" ");
        assert!(joined.starts_with("run fig3 table3 "));
        assert!(joined.contains("--seed 7"));
        assert!(joined.contains("--screen-frac 0.25"));
        assert!(joined.contains("--robust cvar0.25"));
        assert!(joined.contains("--acc-floor 0.92"));
        assert!(joined.contains("--out-dir /tmp/sweep"));
        assert!(joined.contains("--threads 2"));
        assert!(joined.contains("--portfolio cnn4-to-extras"));
        assert!(joined.contains("--quick"));
        assert!(joined.contains("--stable"));
        assert!(joined.contains("--native"), "quick ctx pins native");
        assert!(joined.ends_with("--resume"));
        assert!(!joined.contains("--workers"), "workers never nest");
    }

    #[test]
    fn worker_args_omit_unset_options() {
        let ctx = ExpContext::quick(1);
        let args = worker_args(&["fig3"], &ctx, 1);
        let joined = args.join(" ");
        assert!(!joined.contains("--portfolio"));
        assert!(!joined.contains("--moo-mode"));
        assert!(!joined.contains("--spec"));
        assert!(!joined.contains("--robust"));
        assert!(!joined.contains("--acc-floor"));
    }
}
