//! File-locked cell claims with heartbeat leases.
//!
//! Workers sharing one `--out-dir` coordinate through lease files under
//! `<out_dir>/checkpoints/claims/`: before computing a checkpoint cell, a
//! worker atomically creates `<fnv64(key)>.lease` (`O_CREAT|O_EXCL`, the
//! only primitive the protocol needs from the filesystem). While the claim
//! is held, a background heartbeat thread re-touches the file so its mtime
//! stays fresh; a lease whose mtime is older than `IMCOPT_LEASE_MS`
//! (default 30000) belongs to a crashed or wedged worker and is **stolen**
//! (rewritten via temp + rename, which also refreshes the mtime
//! atomically).
//!
//! The protocol is deliberately *advisory*: cells are deterministic pure
//! functions of (key, run config), so two workers racing the same cell at
//! worst compute it twice and journal the identical value — claims exist
//! to avoid that waste, not to guard correctness. This is also why hashed
//! file names are safe: an fnv64 collision merely serializes two unrelated
//! cells behind one lease; each worker still reads its value from the
//! journal under the real key. A worker that is wedged but still
//! heartbeating holds its lease forever — detecting live-but-stuck workers
//! is the supervisor's job (restart budget), not the lease layer's.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, SystemTime};

/// FNV-1a 64-bit hash — stable across processes and platforms, which the
/// claim protocol needs (every worker must map a key to the same file).
pub fn fnv64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn env_ms(name: &str, default: u64) -> Duration {
    let ms = std::env::var(name)
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&v| v > 0)
        .unwrap_or(default);
    Duration::from_millis(ms)
}

#[derive(Debug, Default)]
struct HeartbeatState {
    /// Lease files currently held by this process; re-touched on every
    /// heartbeat tick.
    held: Vec<PathBuf>,
}

/// The per-process claim coordinator: one instance per worker, shared by
/// every experiment's [`crate::experiments::checkpoint::Checkpoint`] via
/// `Arc`. Owns the heartbeat thread (started lazily on the first claim,
/// joined on drop).
#[derive(Debug)]
pub struct CellClaims {
    dir: PathBuf,
    worker: usize,
    lease_timeout: Duration,
    poll: Duration,
    state: Arc<Mutex<HeartbeatState>>,
    stop: Arc<AtomicBool>,
    heartbeat: Mutex<Option<std::thread::JoinHandle<()>>>,
    claims: AtomicU64,
    steals: AtomicU64,
}

impl CellClaims {
    /// Coordinator rooted at `<out_dir>/checkpoints/claims/`. `worker` is
    /// informational (recorded in lease files for debugging).
    pub fn new(out_dir: &Path, worker: usize) -> Result<CellClaims> {
        let dir = out_dir.join("checkpoints").join("claims");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating claims dir {}", dir.display()))?;
        Ok(CellClaims {
            dir,
            worker,
            lease_timeout: env_ms("IMCOPT_LEASE_MS", 30_000),
            poll: env_ms("IMCOPT_POLL_MS", 50),
            state: Arc::new(Mutex::new(HeartbeatState::default())),
            stop: Arc::new(AtomicBool::new(false)),
            heartbeat: Mutex::new(None),
            claims: AtomicU64::new(0),
            steals: AtomicU64::new(0),
        })
    }

    /// Remove every lease file under `out_dir` — called by the supervisor
    /// before a sweep so leases from a previous (possibly killed) run
    /// never stall the new one for a full lease timeout.
    pub fn clear(out_dir: &Path) -> Result<()> {
        let dir = out_dir.join("checkpoints").join("claims");
        match std::fs::remove_dir_all(&dir) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e).with_context(|| format!("clearing {}", dir.display())),
        }
    }

    /// How long a waiter sleeps between journal polls while another worker
    /// holds the lease (`IMCOPT_POLL_MS`, default 50).
    pub fn poll_interval(&self) -> Duration {
        self.poll
    }

    /// Total successful claims / stale-lease steals by this process.
    pub fn claim_count(&self) -> u64 {
        self.claims.load(Ordering::Relaxed)
    }

    pub fn steal_count(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    fn lease_path(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{:016x}.lease", fnv64(key)))
    }

    fn lease_body(&self, key: &str) -> String {
        format!(
            "{{\"key\": {}, \"worker\": {}, \"pid\": {}}}\n",
            crate::util::json::Json::Str(key.to_string()),
            self.worker,
            std::process::id()
        )
    }

    /// Try to claim `key`'s lease. `Ok(Some(..))` = acquired (fresh file
    /// created, or a stale lease stolen); `Ok(None)` = a live worker holds
    /// it. Only filesystem errors are `Err`.
    pub fn try_claim(self: &Arc<Self>, key: &str) -> Result<Option<ClaimGuard>> {
        let path = self.lease_path(key);
        let created = std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&path);
        match created {
            Ok(mut f) => {
                use std::io::Write as _;
                let _ = f.write_all(self.lease_body(key).as_bytes());
                self.acquired(&path);
                Ok(Some(ClaimGuard::new(self, path)))
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                let age = match std::fs::metadata(&path).and_then(|m| m.modified()) {
                    Ok(mtime) => SystemTime::now()
                        .duration_since(mtime)
                        .unwrap_or(Duration::ZERO),
                    // holder released (or was stolen) between our open and
                    // stat — retry on the next poll rather than racing
                    Err(_) => return Ok(None),
                };
                if age < self.lease_timeout {
                    return Ok(None);
                }
                // Stale: the holder stopped heartbeating (crashed, killed,
                // or wedged past the timeout). Steal by temp + rename —
                // atomic, and resets the mtime so other thieves back off.
                let tmp = self.dir.join(format!(
                    "steal-{}-{}.tmp",
                    std::process::id(),
                    fnv64(key)
                ));
                std::fs::write(&tmp, self.lease_body(key))
                    .with_context(|| format!("writing steal temp {}", tmp.display()))?;
                std::fs::rename(&tmp, &path)
                    .with_context(|| format!("stealing lease {}", path.display()))?;
                self.steals.fetch_add(1, Ordering::Relaxed);
                crate::telemetry::lease_steal();
                self.acquired(&path);
                Ok(Some(ClaimGuard::new(self, path)))
            }
            Err(e) => {
                Err(e).with_context(|| format!("claiming lease {}", path.display()))
            }
        }
    }

    fn acquired(self: &Arc<Self>, path: &Path) {
        self.claims.fetch_add(1, Ordering::Relaxed);
        crate::telemetry::lease_claim();
        self.state
            .lock()
            .expect("heartbeat state lock")
            .held
            .push(path.to_path_buf());
        self.ensure_heartbeat();
    }

    /// Start the heartbeat thread on first use: every tick it rewrites the
    /// held lease files in place, refreshing their mtimes. The interval is
    /// a quarter of the lease timeout (capped at 1s) so a healthy holder
    /// always beats the staleness clock with margin.
    fn ensure_heartbeat(self: &Arc<Self>) {
        let mut slot = self.heartbeat.lock().expect("heartbeat slot lock");
        if slot.is_some() {
            return;
        }
        let interval = (self.lease_timeout / 4).min(Duration::from_secs(1));
        let state = Arc::clone(&self.state);
        let stop = Arc::clone(&self.stop);
        *slot = Some(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(interval);
                let held = state.lock().expect("heartbeat state lock").held.clone();
                for path in held {
                    // re-read + rewrite bumps the mtime; a file someone
                    // stole away from us just fails silently (harmless —
                    // the journal, not the lease, carries the value)
                    if let Ok(body) = std::fs::read(&path) {
                        let _ = std::fs::write(&path, body);
                        crate::telemetry::lease_heartbeat();
                    }
                }
            }
        }));
    }

    fn forget(&self, path: &Path) {
        let mut st = self.state.lock().expect("heartbeat state lock");
        st.held.retain(|p| p != path);
    }
}

impl Drop for CellClaims {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.heartbeat.lock().expect("heartbeat slot lock").take() {
            let _ = h.join();
        }
        // release anything still held so a clean worker exit never leaves
        // leases for others to wait out
        let held = std::mem::take(&mut self.state.lock().expect("state lock").held);
        for path in held {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// A held lease; releasing (explicitly or on drop) deletes the lease file
/// and stops heartbeating it.
#[derive(Debug)]
pub struct ClaimGuard {
    owner: Arc<CellClaims>,
    path: PathBuf,
    released: bool,
}

impl ClaimGuard {
    fn new(owner: &Arc<CellClaims>, path: PathBuf) -> ClaimGuard {
        ClaimGuard {
            owner: Arc::clone(owner),
            path,
            released: false,
        }
    }

    /// Release the claim (idempotent; also runs on drop).
    pub fn release(mut self) {
        self.release_inner();
    }

    fn release_inner(&mut self) {
        if self.released {
            return;
        }
        self.released = true;
        self.owner.forget(&self.path);
        let _ = std::fs::remove_file(&self.path);
    }
}

impl Drop for ClaimGuard {
    fn drop(&mut self) {
        self.release_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("imcopt-lease-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn fnv64_is_stable_and_spreads() {
        assert_eq!(fnv64(""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv64("bound:cnn4:1"), fnv64("bound:cnn4:2"));
        assert_eq!(fnv64("abc"), fnv64("abc"));
    }

    #[test]
    fn claim_is_exclusive_until_released() {
        let dir = tmp("exclusive");
        let a = Arc::new(CellClaims::new(&dir, 0).unwrap());
        let b = Arc::new(CellClaims::new(&dir, 1).unwrap());
        let guard = a.try_claim("cell-x").unwrap().expect("first claim wins");
        assert!(b.try_claim("cell-x").unwrap().is_none(), "fresh lease held");
        // an unrelated key is claimable concurrently
        assert!(b.try_claim("cell-y").unwrap().is_some());
        guard.release();
        assert!(
            b.try_claim("cell-x").unwrap().is_some(),
            "released lease must be claimable"
        );
        assert_eq!(a.claim_count(), 1);
        assert_eq!(a.steal_count(), 0);
    }

    #[test]
    fn dropping_the_guard_releases() {
        let dir = tmp("drop");
        let a = Arc::new(CellClaims::new(&dir, 0).unwrap());
        {
            let _guard = a.try_claim("k").unwrap().expect("claim");
        }
        assert!(a.try_claim("k").unwrap().is_some(), "drop released the lease");
    }

    #[test]
    fn stale_lease_is_stolen_fresh_one_is_not() {
        let dir = tmp("steal");
        // a tiny timeout so the test can age a lease out quickly
        let mut a = CellClaims::new(&dir, 0).unwrap();
        a.lease_timeout = Duration::from_millis(40);
        let a = Arc::new(a);
        // simulate a dead holder: a lease file nobody heartbeats
        let dead = a.lease_path("cell-x");
        std::fs::write(&dead, "{\"key\": \"cell-x\", \"worker\": 9, \"pid\": 0}\n")
            .unwrap();
        assert!(
            a.try_claim("cell-x").unwrap().is_none(),
            "fresh foreign lease must be honored"
        );
        std::thread::sleep(Duration::from_millis(80));
        let guard = a
            .try_claim("cell-x")
            .unwrap()
            .expect("stale lease must be stolen");
        assert_eq!(a.steal_count(), 1);
        guard.release();
    }

    #[test]
    fn heartbeat_keeps_a_held_lease_fresh() {
        let dir = tmp("heartbeat");
        let mut a = CellClaims::new(&dir, 0).unwrap();
        a.lease_timeout = Duration::from_millis(120);
        let a = Arc::new(a);
        let mut b = CellClaims::new(&dir, 1).unwrap();
        b.lease_timeout = Duration::from_millis(120);
        let b = Arc::new(b);
        let guard = a.try_claim("cell-x").unwrap().expect("claim");
        // well past the timeout, but the heartbeat (interval 30ms) keeps
        // re-touching the file, so b must keep honoring it
        std::thread::sleep(Duration::from_millis(300));
        assert!(
            b.try_claim("cell-x").unwrap().is_none(),
            "heartbeated lease stolen despite live holder"
        );
        guard.release();
    }

    #[test]
    fn clear_removes_leftover_leases() {
        let dir = tmp("clear");
        let a = Arc::new(CellClaims::new(&dir, 0).unwrap());
        let _guard = a.try_claim("k").unwrap().expect("claim");
        CellClaims::clear(&dir).unwrap();
        let b = Arc::new(CellClaims::new(&dir, 1).unwrap());
        assert!(b.try_claim("k").unwrap().is_some());
    }
}
