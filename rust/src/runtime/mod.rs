//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them on the hot search path.
//!
//! Interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md`). Python runs
//! only at `make artifacts` time; after that the `imcopt` binary is
//! self-contained.
//!
//! Artifacts (described by `artifacts/manifest.json`):
//!
//! * `fitness_b{64,256}.hlo.txt` — the batched hardware evaluator
//!   (L2 graph wrapping the L1 Pallas fitness kernel):
//!   `(designs[B,10], layers[L_MAX,8], mode[4]) → [B,4] = (E, L, A, ok)`.
//! * `accproxy.hlo.txt` — the noisy-crossbar accuracy proxy (L1 Pallas
//!   crossbar kernel under an L2 error-measurement graph):
//!   `(w[P,P], x[XB,P], noise[ITERS,P,P], params[4]) → scalar ε̄`.
//!
//! Threading: a PJRT execution is not re-entrant, so the `Engine` lives
//! behind a `Mutex` (see `EvalBackend::Pjrt`). Callers on the parallel
//! search path chunk their batches by [`Engine::max_fitness_batch`] and
//! hold the lock **per execution only**, so native-side decode/score work
//! on other threads overlaps with artifact runs.
//!
//! The whole PJRT path is compiled only with the `pjrt` cargo feature
//! (the `xla` crate and its shared libraries). Without it a stub `Engine`
//! with the same API reports artifacts as unavailable and every backend
//! falls back to the native evaluator.

use std::path::PathBuf;

/// Default artifacts directory (relative to the repo root / CWD).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("IMCOPT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Accuracy-proxy static shapes (mirrored in `hwspec.py`).
pub const PROXY_DIM: usize = 256;
pub const PROXY_BATCH: usize = 8;
pub const PROXY_ITERS: usize = 30;

#[cfg(feature = "pjrt")]
mod engine_impl {
    use super::{default_artifact_dir, PROXY_BATCH, PROXY_DIM, PROXY_ITERS};
    use crate::model::{MemoryTech, Metrics};
    use crate::util::json::{self, Json};
    use crate::workloads::{Workload, LAYER_FEATURES, L_MAX};
    use anyhow::{bail, Context, Result};
    use std::collections::BTreeMap;
    use std::path::Path;

    /// One compiled fitness executable for a fixed (batch, lmax) shape.
    struct FitnessExe {
        batch: usize,
        lmax: usize,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT engine owning the CPU client and all compiled executables.
    pub struct Engine {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        fitness: Vec<FitnessExe>,
        accproxy: Option<xla::PjRtLoadedExecutable>,
        /// Fixed noise draws for the accuracy proxy (generated once, shared
        /// across designs for a fair comparison; the paper averages 30
        /// random iterations per design).
        proxy_noise: Vec<f32>,
        proxy_w: Vec<f32>,
        proxy_x: Vec<f32>,
        /// Manifest metadata (for diagnostics).
        pub manifest: BTreeMap<String, Json>,
    }

    // SAFETY: the xla crate's client/executable handles contain `Rc`s and
    // raw PJRT pointers, so `Engine` is not auto-`Send`. Every `Engine` in
    // this crate lives behind a `Mutex` (see `EvalBackend::Pjrt`) and no
    // `Rc` clone or buffer handle escapes a locked scope — all literals and
    // result buffers are created, consumed and dropped inside the method
    // call — so moving the whole engine across threads between locked
    // accesses is sound.
    unsafe impl Send for Engine {}

    impl Engine {
        /// Load every artifact listed in `<dir>/manifest.json` and compile
        /// on the PJRT CPU client.
        pub fn load(dir: &Path) -> Result<Engine> {
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path).with_context(|| {
                format!(
                    "cannot read {} — run `make artifacts` first",
                    manifest_path.display()
                )
            })?;
            let manifest = json::parse(&text)
                .map_err(|e| anyhow::anyhow!("bad manifest.json: {e}"))?;
            let client = xla::PjRtClient::cpu()?;

            let arts = manifest
                .get("artifacts")
                .and_then(|a| a.as_arr())
                .context("manifest.json missing 'artifacts' array")?;

            let mut fitness = Vec::new();
            let mut accproxy = None;
            for a in arts {
                let name = a.get("name").and_then(|n| n.as_str()).unwrap_or("");
                let file = a.get("file").and_then(|n| n.as_str()).unwrap_or("");
                let path = dir.join(file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 artifact path")?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                if name.starts_with("fitness") {
                    let batch = a
                        .get("batch")
                        .and_then(|b| b.as_usize())
                        .context("fitness artifact missing batch")?;
                    let lmax = a.get("lmax").and_then(|b| b.as_usize()).unwrap_or(0);
                    if lmax > L_MAX || lmax == 0 {
                        bail!(
                            "artifact {name} built for lmax={lmax}, crate supports up to \
                             {L_MAX}; rebuild artifacts"
                        );
                    }
                    fitness.push(FitnessExe { batch, lmax, exe });
                } else if name == "accproxy" {
                    accproxy = Some(exe);
                }
            }
            if fitness.is_empty() {
                bail!("manifest lists no fitness artifacts");
            }
            if !fitness.iter().any(|f| f.lmax >= L_MAX) {
                bail!("no fitness artifact covers L_MAX={L_MAX}; rebuild artifacts");
            }
            fitness.sort_by_key(|f| (f.lmax, f.batch));

            // deterministic proxy tensors
            let mut rng = crate::util::rng::Rng::seed_from(0xACC);
            let proxy_noise: Vec<f32> = (0..PROXY_ITERS * PROXY_DIM * PROXY_DIM)
                .map(|_| rng.normal() as f32)
                .collect();
            let proxy_w: Vec<f32> = (0..PROXY_DIM * PROXY_DIM)
                .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
                .collect();
            let proxy_x: Vec<f32> = (0..PROXY_BATCH * PROXY_DIM)
                .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
                .collect();

            let manifest_map = match manifest {
                Json::Obj(m) => m,
                _ => BTreeMap::new(),
            };
            Ok(Engine {
                client,
                fitness,
                accproxy,
                proxy_noise,
                proxy_w,
                proxy_x,
                manifest: manifest_map,
            })
        }

        /// Try to load from the default directory.
        pub fn load_default() -> Result<Engine> {
            Engine::load(&default_artifact_dir())
        }

        /// Pick the smallest compiled (lmax, batch) variant covering the
        /// workload depth and chunk size (§Perf: short-lmax variants skip
        /// the padded layer rows — ~4x cheaper for the CNN workloads).
        fn pick_fitness(&self, n: usize, n_layers: usize) -> &FitnessExe {
            self.fitness
                .iter()
                .find(|f| f.batch >= n && f.lmax >= n_layers)
                .or_else(|| self.fitness.iter().find(|f| f.lmax >= n_layers))
                .unwrap_or_else(|| self.fitness.last().unwrap())
        }

        /// Compiled (batch, lmax) variants, sorted.
        pub fn fitness_batch_sizes(&self) -> Vec<(usize, usize)> {
            self.fitness.iter().map(|f| (f.batch, f.lmax)).collect()
        }

        /// Largest compiled batch — callers on the parallel search path
        /// chunk by this and lock the engine per chunk execution.
        pub fn max_fitness_batch(&self) -> usize {
            self.fitness.iter().map(|f| f.batch).max().unwrap_or(1)
        }

        pub fn has_accproxy(&self) -> bool {
            self.accproxy.is_some()
        }

        /// Evaluate a batch of decoded designs on one workload through the
        /// AOT fitness artifact. Results match `NativeEvaluator` within f32
        /// tolerance (enforced by `rust/tests/integration_runtime.rs`).
        pub fn fitness(
            &self,
            raws: &[[f64; 10]],
            workload: &Workload,
            mem: MemoryTech,
        ) -> Result<Vec<Metrics>> {
            let n_layers = workload.layers.len();
            let mut out = Vec::with_capacity(raws.len());
            let mut layers_cache: Option<(usize, Vec<f32>)> = None;
            for chunk in raws.chunks(self.max_fitness_batch()) {
                let fe = self.pick_fitness(chunk.len(), n_layers);
                // build (and reuse) the padded layer tensor for this lmax
                if layers_cache.as_ref().map(|(l, _)| *l) != Some(fe.lmax) {
                    layers_cache = Some((fe.lmax, workload.to_tensor_padded(fe.lmax)));
                }
                let layers = &layers_cache.as_ref().unwrap().1;
                out.extend(self.fitness_chunk(fe, chunk, layers, mem)?);
            }
            Ok(out)
        }

        fn fitness_chunk(
            &self,
            fe: &FitnessExe,
            raws: &[[f64; 10]],
            layers: &[f32],
            mem: MemoryTech,
        ) -> Result<Vec<Metrics>> {
            let b = fe.batch;
            assert!(raws.len() <= b);
            // pad with copies of the first row (cheap, discarded)
            let mut designs = vec![0f32; b * 10];
            for (i, raw) in raws.iter().enumerate() {
                for (j, &v) in raw.iter().enumerate() {
                    designs[i * 10 + j] = v as f32;
                }
            }
            for i in raws.len()..b {
                for j in 0..10 {
                    designs[i * 10 + j] = designs[j];
                }
            }
            let mode = [
                match mem {
                    MemoryTech::Rram => 0f32,
                    MemoryTech::Sram => 1f32,
                },
                0.0,
                0.0,
                0.0,
            ];
            let d_lit = xla::Literal::vec1(&designs).reshape(&[b as i64, 10])?;
            let l_lit = xla::Literal::vec1(layers)
                .reshape(&[fe.lmax as i64, LAYER_FEATURES as i64])?;
            let m_lit = xla::Literal::vec1(&mode);
            let result = fe.exe.execute::<xla::Literal>(&[d_lit, l_lit, m_lit])?[0][0]
                .to_literal_sync()?;
            let flat = result.to_tuple1()?.to_vec::<f32>()?;
            anyhow::ensure!(flat.len() == b * 4, "unexpected output size {}", flat.len());
            Ok(raws
                .iter()
                .enumerate()
                .map(|(i, _)| Metrics {
                    energy: flat[i * 4] as f64,
                    latency: flat[i * 4 + 1] as f64,
                    area: flat[i * 4 + 2] as f64,
                    feasible: flat[i * 4 + 3] > 0.5,
                })
                .collect())
        }

        /// Measure the per-layer relative MVM error of a design's noise
        /// configuration through the AOT noisy-crossbar proxy (30
        /// iterations, fixed draws). `sigma_scale` and `ir_drop` come from
        /// `accuracy::NoiseSpec`.
        pub fn accproxy_eps(&self, sigma_scale: f64, ir_drop: f64) -> Result<f64> {
            let exe = self
                .accproxy
                .as_ref()
                .context("accproxy artifact not loaded")?;
            let w = xla::Literal::vec1(&self.proxy_w)
                .reshape(&[PROXY_DIM as i64, PROXY_DIM as i64])?;
            let x = xla::Literal::vec1(&self.proxy_x)
                .reshape(&[PROXY_BATCH as i64, PROXY_DIM as i64])?;
            let noise = xla::Literal::vec1(&self.proxy_noise).reshape(&[
                PROXY_ITERS as i64,
                PROXY_DIM as i64,
                PROXY_DIM as i64,
            ])?;
            let params = xla::Literal::vec1(&[
                sigma_scale as f32,
                ir_drop as f32,
                crate::accuracy::OUT_NOISE as f32,
                crate::accuracy::QUANT_BITS as f32,
            ]);
            let result = exe.execute::<xla::Literal>(&[w, x, noise, params])?[0][0]
                .to_literal_sync()?;
            let eps = result.to_tuple1()?.to_vec::<f32>()?;
            anyhow::ensure!(!eps.is_empty(), "empty accproxy output");
            Ok(eps[0] as f64)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod engine_impl {
    //! API-compatible stub used when the `pjrt` feature (and with it the
    //! `xla` crate) is not compiled in. `load` always fails, so every
    //! `BackendChoice::Auto` caller falls back to the native evaluator;
    //! the remaining methods exist only so backend-generic code compiles.

    use super::default_artifact_dir;
    use crate::model::{MemoryTech, Metrics};
    use crate::util::json::Json;
    use crate::workloads::Workload;
    use anyhow::{bail, Result};
    use std::collections::BTreeMap;
    use std::path::Path;

    /// Stub engine (never instantiable: [`Engine::load`] always errors).
    pub struct Engine {
        /// Manifest metadata (always empty in the stub).
        pub manifest: BTreeMap<String, Json>,
    }

    impl Engine {
        pub fn load(dir: &Path) -> Result<Engine> {
            bail!(
                "PJRT support not compiled in (enable the `pjrt` cargo feature); \
                 artifacts in {} unusable — run `make artifacts` and rebuild \
                 with `--features pjrt`",
                dir.display()
            )
        }

        pub fn load_default() -> Result<Engine> {
            Engine::load(&default_artifact_dir())
        }

        pub fn fitness_batch_sizes(&self) -> Vec<(usize, usize)> {
            Vec::new()
        }

        pub fn max_fitness_batch(&self) -> usize {
            1
        }

        pub fn has_accproxy(&self) -> bool {
            false
        }

        pub fn fitness(
            &self,
            _raws: &[[f64; 10]],
            _workload: &Workload,
            _mem: MemoryTech,
        ) -> Result<Vec<Metrics>> {
            bail!("PJRT support not compiled in")
        }

        pub fn accproxy_eps(&self, _sigma_scale: f64, _ir_drop: f64) -> Result<f64> {
            bail!("PJRT support not compiled in")
        }
    }
}

pub use engine_impl::Engine;

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn missing_artifacts_error_is_actionable() {
        let err = match Engine::load(Path::new("/nonexistent-dir")) {
            Ok(_) => panic!("load from a nonexistent dir must fail"),
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
