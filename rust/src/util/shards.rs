//! N-way sharded (striped-lock) concurrent hash map.
//!
//! The joint-search hot path memoizes per-design evaluations; with a single
//! `Mutex<HashMap>` every worker thread serializes on one lock. A
//! [`ShardedCache`] splits the key space over [`SHARDS`] independent
//! `Mutex<HashMap>` stripes keyed by `key % SHARDS`, so concurrent lookups
//! and inserts on different designs proceed in parallel. Values are
//! returned by clone; compute-on-miss ([`ShardedCache::get_or_insert_with`])
//! holds only the owning stripe's lock while computing, which both
//! deduplicates work and keeps results deterministic under any thread
//! count.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Mutex;

/// Default stripe count. Sixteen stripes keep contention negligible for the
/// pool sizes we run (≤ number of cores workers) at ~1 KiB of overhead.
pub const SHARDS: usize = 16;

/// A key that can pick its stripe. For dense `u64` design indices the
/// stripe is literally `key % SHARDS`; composite keys fold their fields
/// into a 64-bit value first.
pub trait ShardKey: Eq + Hash {
    /// A 64-bit projection of the key; the stripe is `shard_key() % N`.
    fn shard_key(&self) -> u64;
}

impl ShardKey for u64 {
    fn shard_key(&self) -> u64 {
        *self
    }
}

impl ShardKey for (u16, u16, u16) {
    fn shard_key(&self) -> u64 {
        // spread the fields so stripes don't collapse when only one varies
        (self.0 as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((self.1 as u64).wrapping_mul(0xD1B5_4A32_D192_ED03))
            .wrapping_add(self.2 as u64)
    }
}

/// Striped-lock hash map; see the module docs.
pub struct ShardedCache<K, V> {
    shards: Vec<Mutex<HashMap<K, V>>>,
}

impl<K: ShardKey, V: Clone> ShardedCache<K, V> {
    pub fn new() -> Self {
        Self::with_shards(SHARDS)
    }

    pub fn with_shards(n: usize) -> Self {
        ShardedCache {
            shards: (0..n.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, key: &K) -> &Mutex<HashMap<K, V>> {
        let i = (key.shard_key() % self.shards.len() as u64) as usize;
        &self.shards[i]
    }

    /// Clone of the cached value, if present.
    pub fn get(&self, key: &K) -> Option<V> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    /// Apply `f` to the cached value under the stripe lock (avoids cloning
    /// large values when only a projection is needed).
    pub fn map_get<R>(&self, key: &K, f: impl FnOnce(&V) -> R) -> Option<R> {
        self.shard(key).lock().unwrap().get(key).map(f)
    }

    pub fn insert(&self, key: K, value: V) {
        self.shard(&key).lock().unwrap().insert(key, value);
    }

    /// Return the cached value for `key`, computing and inserting it with
    /// `f` on a miss. The stripe lock is held across `f`, so concurrent
    /// callers with the same key compute exactly once.
    pub fn get_or_insert_with(&self, key: K, f: impl FnOnce() -> V) -> V {
        let mut m = self.shard(&key).lock().unwrap();
        m.entry(key).or_insert_with(f).clone()
    }

    /// Total entries across all stripes.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<K: ShardKey + Ord + Clone, V: Clone> ShardedCache<K, V> {
    /// All entries, sorted by key — deterministic regardless of stripe
    /// layout, for diagnostics and cache-equality tests.
    pub fn sorted_entries(&self) -> Vec<(K, V)> {
        let mut out: Vec<(K, V)> = Vec::with_capacity(self.len());
        for s in &self.shards {
            let m = s.lock().unwrap();
            out.extend(m.iter().map(|(k, v)| (k.clone(), v.clone())));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

impl<K: ShardKey, V: Clone> Default for ShardedCache<K, V> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_get_insert() {
        let c: ShardedCache<u64, f64> = ShardedCache::new();
        assert!(c.is_empty());
        assert_eq!(c.get(&7), None);
        c.insert(7, 1.5);
        assert_eq!(c.get(&7), Some(1.5));
        assert_eq!(c.map_get(&7, |v| v * 2.0), Some(3.0));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn get_or_insert_computes_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let c: ShardedCache<u64, u64> = ShardedCache::new();
        let calls = AtomicUsize::new(0);
        for _ in 0..5 {
            let v = c.get_or_insert_with(42, || {
                calls.fetch_add(1, Ordering::Relaxed);
                99
            });
            assert_eq!(v, 99);
        }
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn keys_spread_across_stripes() {
        let c: ShardedCache<u64, u64> = ShardedCache::new();
        for k in 0..(SHARDS as u64 * 4) {
            c.insert(k, k);
        }
        assert_eq!(c.len(), SHARDS * 4);
        let used = c.shards.iter().filter(|s| !s.lock().unwrap().is_empty()).count();
        assert_eq!(used, SHARDS, "dense u64 keys must hit every stripe");
    }

    #[test]
    fn sorted_entries_deterministic() {
        let c: ShardedCache<u64, u64> = ShardedCache::new();
        for k in [9u64, 3, 27, 1, 16] {
            c.insert(k, k * 10);
        }
        assert_eq!(
            c.sorted_entries(),
            vec![(1, 10), (3, 30), (9, 90), (16, 160), (27, 270)]
        );
    }

    #[test]
    fn concurrent_inserts_all_land() {
        let c: ShardedCache<u64, u64> = ShardedCache::new();
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = &c;
                s.spawn(move || {
                    for i in 0..100u64 {
                        let k = t * 100 + i;
                        c.insert(k, k + 1);
                    }
                });
            }
        });
        assert_eq!(c.len(), 800);
        for k in 0..800u64 {
            assert_eq!(c.get(&k), Some(k + 1));
        }
    }

    #[test]
    fn tuple_keys_work() {
        let c: ShardedCache<(u16, u16, u16), f64> = ShardedCache::new();
        c.insert((512, 256, 2), 0.25);
        assert_eq!(c.get(&(512, 256, 2)), Some(0.25));
        assert_eq!(c.get(&(512, 256, 4)), None);
    }
}
