//! Tiny CLI argument parser (the offline registry has no clap).
//!
//! Supports the subset the `imcopt` binary needs: a subcommand followed by
//! positional arguments and `--flag[=value]` / `--flag value` options.
//!
//! Threading options: every subcommand that evaluates populations accepts
//! `--threads N` (worker threads for the parallel evaluation pipeline).
//! When omitted, the `IMCOPT_THREADS` environment variable is consulted,
//! then the machine's available parallelism (`util::pool::default_threads`).
//! Thread count only affects throughput — scores and cache contents are
//! bit-identical at any setting.

use std::collections::BTreeMap;

/// Parsed command line: `imcopt <command> [positionals...] [--opts...]`.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positionals: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with("--") {
                args.command = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.options.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                args.positionals.push(a);
            }
        }
        args
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.options.get(name).map(|v| v != "false").unwrap_or(false)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("exp fig3 extra");
        assert_eq!(a.command, "exp");
        assert_eq!(a.positionals, vec!["fig3", "extra"]);
    }

    #[test]
    fn options_all_forms() {
        let a = parse("search --seed=7 --gens 20 --native --out results");
        assert_eq!(a.opt_u64("seed", 0), 7);
        assert_eq!(a.opt_usize("gens", 0), 20);
        assert!(a.flag("native"));
        assert_eq!(a.opt_str("out", ""), "results");
        assert!(!a.flag("missing"));
    }

    #[test]
    fn no_command() {
        let a = parse("--help");
        assert_eq!(a.command, "");
        assert!(a.flag("help"));
    }
}
