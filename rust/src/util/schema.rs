//! Minimal JSON-Schema subset validator (the offline registry has no
//! jsonschema crate).
//!
//! Supports the keywords the CI gate needs to pin artifact shapes:
//! `type` (a string or an array of strings), `required`, `properties`,
//! `items`, `enum`, `minimum` and `minItems`. Unknown keywords are
//! ignored, as in real JSON Schema. Checked-in schemas live under
//! `schemas/` and are enforced by `imcopt validate` (see `ci.sh`).

use super::json::Json;

/// Validate `value` against `schema`; returns every violation found (empty
/// = valid), each prefixed with a `$`-rooted path.
pub fn validate(schema: &Json, value: &Json) -> Vec<String> {
    let mut errs = Vec::new();
    check(schema, value, "$", &mut errs);
    errs
}

fn type_name(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "boolean",
        Json::Num(_) => "number",
        Json::Str(_) => "string",
        Json::Arr(_) => "array",
        Json::Obj(_) => "object",
    }
}

fn check(schema: &Json, value: &Json, path: &str, errs: &mut Vec<String>) {
    // type: "object" | ["number", "string"] | ...
    if let Some(ty) = schema.get("type") {
        let actual = type_name(value);
        let allowed: Vec<&str> = match ty {
            Json::Str(s) => vec![s.as_str()],
            Json::Arr(v) => v.iter().filter_map(|t| t.as_str()).collect(),
            _ => Vec::new(),
        };
        if !allowed.is_empty() && !allowed.contains(&actual) {
            errs.push(format!("{path}: expected type {allowed:?}, got {actual}"));
            return; // further keyword checks would only cascade
        }
    }
    if let Some(Json::Arr(options)) = schema.get("enum") {
        if !options.contains(value) {
            errs.push(format!("{path}: value not in enum"));
        }
    }
    if let Some(min) = schema.get("minimum").and_then(|m| m.as_f64()) {
        if let Json::Num(x) = value {
            if *x < min {
                errs.push(format!("{path}: {x} below minimum {min}"));
            }
        }
    }
    if let Json::Obj(obj) = value {
        if let Some(Json::Arr(req)) = schema.get("required") {
            for key in req.iter().filter_map(|k| k.as_str()) {
                if !obj.contains_key(key) {
                    errs.push(format!("{path}: missing required key '{key}'"));
                }
            }
        }
        if let Some(Json::Obj(props)) = schema.get("properties") {
            for (key, sub) in props {
                if let Some(v) = obj.get(key) {
                    check(sub, v, &format!("{path}.{key}"), errs);
                }
            }
        }
    }
    if let Json::Arr(items) = value {
        if let Some(min) = schema.get("minItems").and_then(|m| m.as_f64()) {
            if (items.len() as f64) < min {
                errs.push(format!(
                    "{path}: {} items below minItems {min}",
                    items.len()
                ));
            }
        }
        if let Some(item_schema) = schema.get("items") {
            for (i, v) in items.iter().enumerate() {
                check(item_schema, v, &format!("{path}[{i}]"), errs);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn schema() -> Json {
        parse(
            r#"{
                "type": "object",
                "required": ["name", "speedup", "rows"],
                "properties": {
                    "name": {"type": "string"},
                    "speedup": {"type": "number", "minimum": 0},
                    "rows": {
                        "type": "array",
                        "minItems": 1,
                        "items": {"type": "array", "items": {"type": "string"}}
                    },
                    "ok": {"type": "boolean"}
                }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn accepts_conforming_document() {
        let doc = parse(
            r#"{"name": "bench", "speedup": 3.5, "rows": [["a", "b"]], "ok": true}"#,
        )
        .unwrap();
        assert!(validate(&schema(), &doc).is_empty());
    }

    #[test]
    fn reports_missing_required_and_bad_types() {
        let doc = parse(r#"{"name": 7, "rows": []}"#).unwrap();
        let errs = validate(&schema(), &doc);
        assert!(errs.iter().any(|e| e.contains("missing required key 'speedup'")));
        assert!(errs.iter().any(|e| e.contains("$.name")));
        assert!(errs.iter().any(|e| e.contains("minItems")));
    }

    #[test]
    fn checks_minimum_and_nested_items() {
        let doc = parse(r#"{"name": "x", "speedup": -1, "rows": [["a"], [3]]}"#).unwrap();
        let errs = validate(&schema(), &doc);
        assert!(errs.iter().any(|e| e.contains("below minimum")));
        assert!(errs.iter().any(|e| e.contains("$.rows[1][0]")));
    }

    #[test]
    fn type_unions_and_enums() {
        let s = parse(r#"{"type": ["string", "number"], "enum": ["inf", 1]}"#).unwrap();
        assert!(validate(&s, &Json::Num(1.0)).is_empty());
        assert!(validate(&s, &Json::Str("inf".into())).is_empty());
        assert!(!validate(&s, &Json::Bool(true)).is_empty());
        assert!(!validate(&s, &Json::Str("other".into())).is_empty());
    }
}
