//! Minimal JSON parser + writer (the offline registry has no serde).
//!
//! Used for the artifact manifest written by `python/compile/aot.py` and
//! for machine-readable experiment result dumps. Supports the full JSON
//! grammar except for exotic number forms; numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` so emission order is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Encode an `f64`, spelling non-finite values as strings (JSON has no
    /// `inf`/`nan` literals). The inverse is [`Json::as_f64_lenient`];
    /// finite values round-trip bit-exactly (shortest-representation
    /// `Display` plus exact integers below 1e15).
    pub fn f64(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else if x.is_nan() {
            Json::Str("nan".into())
        } else if x > 0.0 {
            Json::Str("inf".into())
        } else {
            Json::Str("-inf".into())
        }
    }

    /// Decode a value written by [`Json::f64`].
    pub fn as_f64_lenient(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Str(s) => match s.as_str() {
                "inf" => Some(f64::INFINITY),
                "-inf" => Some(f64::NEG_INFINITY),
                "nan" => Some(f64::NAN),
                _ => None,
            },
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`value.to_string()` comes from this impl; the
/// inherent method it replaces tripped `clippy::inherent_to_string`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error message with byte offset on
/// malformed input.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("invalid number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("truncated \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape")?;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // copy a full UTF-8 scalar
                let tail = &b[*pos..];
                let ch_len = utf8_len(tail[0]);
                let chunk = std::str::from_utf8(&tail[..ch_len.min(tail.len())])
                    .map_err(|_| "invalid utf8 in string")?;
                s.push_str(chunk);
                *pos += ch_len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(b0: u8) -> usize {
    match b0 {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '['
    let mut v = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(v));
    }
    loop {
        v.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            _ => return Err(format!("expected , or ] at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '{'
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at byte {pos}", pos = *pos));
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        m.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            _ => return Err(format!("expected , or }} at byte {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"artifacts":[{"name":"fitness_b64","batch":64,"lmax":128,
            "inputs":["designs","layers","mode"],"outputs":4}],"version":1,
            "note":"a\"b\\c\nd"}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_f64(), Some(1.0));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("fitness_b64"));
        assert_eq!(arts[0].get("batch").unwrap().as_usize(), Some(64));
        // reparse what we emit
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(parse("3.5e2").unwrap().as_f64(), Some(350.0));
        assert_eq!(parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(parse("[1,2,3]").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn f64_codec_roundtrips_including_nonfinite() {
        for x in [0.0, -1.5, 1.0 / 3.0, 6.02e23, 1e-300, 123456789.0] {
            let v = parse(&Json::f64(x).to_string()).unwrap();
            assert_eq!(v.as_f64_lenient().unwrap().to_bits(), x.to_bits());
        }
        assert_eq!(
            Json::f64(f64::INFINITY).as_f64_lenient(),
            Some(f64::INFINITY)
        );
        assert_eq!(
            Json::f64(f64::NEG_INFINITY).as_f64_lenient(),
            Some(f64::NEG_INFINITY)
        );
        assert!(Json::f64(f64::NAN).as_f64_lenient().unwrap().is_nan());
        assert_eq!(Json::Str("bogus".into()).as_f64_lenient(), None);
    }

    #[test]
    fn unicode_strings() {
        let v = parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("é café ☕"));
    }
}
