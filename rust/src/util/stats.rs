//! Small statistics helpers used by experiments and the bench harness.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0 for < 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean of strictly positive values.
pub fn geo_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Minimum, ignoring NaNs; +inf for empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::INFINITY, f64::min)
}

/// Maximum, ignoring NaNs; -inf for empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile (`q` in [0,1]) of a *sorted* slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median of an unsorted slice (copies).
pub fn median(xs: &[f64]) -> f64 {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, 0.5)
}

/// 2-D Pareto front (minimize both axes). Returns indices of non-dominated
/// points, sorted by x. Used by the Fig. 9 EDAP-vs-cost trade-off.
pub fn pareto_front_2d(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .partial_cmp(&points[b].0)
            .unwrap()
            .then(points[a].1.partial_cmp(&points[b].1).unwrap())
    });
    let mut front = Vec::new();
    let mut best_y = f64::INFINITY;
    for i in idx {
        if points[i].1 < best_y {
            best_y = points[i].1;
            front.push(i);
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geo_mean_basic() {
        assert!((geo_mean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pareto_front() {
        // points: (cost, edap)
        let pts = [(1.0, 10.0), (2.0, 5.0), (3.0, 6.0), (4.0, 1.0), (2.5, 4.9)];
        let front = pareto_front_2d(&pts);
        assert_eq!(front, vec![0, 1, 4, 3]);
    }

    #[test]
    fn min_max_ignore_nan() {
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 3.0);
    }
}
