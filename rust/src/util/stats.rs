//! Small statistics helpers used by experiments and the bench harness.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0 for < 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Geometric mean of strictly positive values.
pub fn geo_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Minimum, ignoring NaNs; +inf for empty input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::INFINITY, f64::min)
}

/// Maximum, ignoring NaNs; -inf for empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().filter(|x| !x.is_nan()).fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile (`q` in [0,1]) of a *sorted* slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median of an unsorted slice (copies).
pub fn median(xs: &[f64]) -> f64 {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, 0.5)
}

/// 2-D Pareto front (minimize both axes). Returns indices of non-dominated
/// points, sorted by x (ties by y, then input index), with exact duplicate
/// points collapsed to their first occurrence. Used by the Fig. 9
/// EDAP-vs-cost trade-off.
///
/// Dominance is delegated to [`crate::pareto::sort::non_dominated_sort`] so
/// the whole repo shares a single definition of "non-dominated".
pub fn pareto_front_2d(points: &[(f64, f64)]) -> Vec<usize> {
    let vecs: Vec<Vec<f64>> = points.iter().map(|&(x, y)| vec![x, y]).collect();
    let mut front = match crate::pareto::sort::non_dominated_sort(&vecs).into_iter().next() {
        Some(f) => f,
        None => return Vec::new(),
    };
    front.sort_by(|&a, &b| {
        points[a].partial_cmp(&points[b]).unwrap().then(a.cmp(&b))
    });
    // strict dominance leaves exact duplicates in front 0; keep the first
    front.dedup_by(|a, b| points[*a] == points[*b]);
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geo_mean_basic() {
        assert!((geo_mean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn pareto_front() {
        // points: (cost, edap)
        let pts = [(1.0, 10.0), (2.0, 5.0), (3.0, 6.0), (4.0, 1.0), (2.5, 4.9)];
        let front = pareto_front_2d(&pts);
        assert_eq!(front, vec![0, 1, 4, 3]);
        // exact duplicates collapse to the first occurrence; weakly
        // dominated points (equal on one axis, worse on the other) drop
        let pts = [(1.0, 10.0), (1.0, 10.0), (2.0, 10.0), (0.5, 20.0)];
        assert_eq!(pareto_front_2d(&pts), vec![3, 0]);
        assert!(pareto_front_2d(&[]).is_empty());
    }

    #[test]
    fn min_max_ignore_nan() {
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 3.0);
    }
}
