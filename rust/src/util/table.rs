//! Aligned-text / markdown / CSV table rendering for experiment reports.

/// A simple column-oriented table builder; every experiment prints its
/// paper-matching rows through this so output formatting is uniform.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned plain-text table (for terminal output).
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavored markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.headers.len())
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// Render as CSV (naive quoting: fields containing commas/quotes are
    /// double-quoted).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1.5".into()]);
        t.row(vec!["beta, or b".into(), "2".into()]);
        t
    }

    #[test]
    fn text_alignment() {
        let txt = sample().to_text();
        assert!(txt.contains("## Demo"));
        assert!(txt.lines().count() >= 4);
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("| name | value |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    fn csv_quoting() {
        let csv = sample().to_csv();
        assert!(csv.contains("\"beta, or b\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn panics_on_bad_row() {
        sample().row(vec!["only-one".into()]);
    }
}
