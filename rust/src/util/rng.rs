//! Deterministic pseudo-random number generation.
//!
//! xoshiro256++ seeded through SplitMix64 — the standard construction
//! recommended by the xoshiro authors. All experiment randomness flows
//! through [`Rng`] so every paper figure is reproducible from a seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Construct from a 64-bit seed (SplitMix64-expanded).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (used to hand one RNG per worker /
    /// per experiment repetition without sharing state).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in `[0, n)` (Lemire's bounded rejection method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; throughput is irrelevant at our call rates).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // (0,1]
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n - 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_diverge() {
        let mut root = Rng::seed_from(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seed_from(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from(9);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(13);
        let mut v: Vec<usize> = (0..32).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..32).collect::<Vec<_>>());
    }
}
