//! A minimal scoped thread pool (the offline registry has no rayon).
//!
//! The coordinator fans population evaluations out across workers with
//! [`parallel_map`]. On the single-core CI box this degrades gracefully to
//! sequential execution; on multi-core hosts it scales like a plain
//! work-stealing-free chunked pool, which is sufficient because every work
//! item (a hardware evaluation) has near-identical cost.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use by default: the machine's available
/// parallelism, overridable through `IMCOPT_THREADS`.
pub fn default_threads() -> usize {
    threads_from(std::env::var("IMCOPT_THREADS").ok().as_deref())
}

/// Resolve a thread-count override (the `IMCOPT_THREADS` value):
/// a positive integer wins, anything else falls back to the machine's
/// available parallelism. Split out from [`default_threads`] so tests can
/// cover the parsing without mutating the process environment (concurrent
/// `setenv`/`getenv` is undefined behavior on glibc).
pub fn threads_from(val: Option<&str>) -> usize {
    if let Some(v) = val {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every element of `items` on `threads` workers, preserving
/// input order in the output. `f` must be `Sync` (called concurrently).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().map(|x| f(x)).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker produced result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 4, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |x| *x).is_empty());
        assert_eq!(parallel_map(&[5u32], 8, |x| x + 1), vec![6]);
    }

    #[test]
    fn sequential_path_matches() {
        let items: Vec<u64> = (0..64).collect();
        let seq = parallel_map(&items, 1, |x| x * x);
        let par = parallel_map(&items, 3, |x| x * x);
        assert_eq!(seq, par);
    }
}
