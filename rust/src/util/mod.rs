//! Std-only infrastructure.
//!
//! The build environment has an offline cargo registry containing only the
//! `xla` crate's dependency closure, so the usual ecosystem crates
//! (tokio, rayon, clap, criterion, serde, rand, proptest) are unavailable.
//! This module provides small, well-tested replacements for the subset of
//! their functionality the project needs.

pub mod bench;
pub mod cli;
pub mod fault;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod schema;
pub mod shards;
pub mod stats;
pub mod table;

/// Write `contents` to `path` atomically: write a same-directory temp file,
/// then rename over the target. Concurrent writers (e.g. two orchestrator
/// workers emitting the same report) each land a complete file — readers
/// never observe an interleaved or truncated artifact.
pub fn write_atomic(path: &std::path::Path, contents: &str) -> std::io::Result<()> {
    let tmp = path.with_extension(format!(
        "{}.tmp.{}",
        path.extension()
            .map(|e| e.to_string_lossy().to_string())
            .unwrap_or_default(),
        std::process::id()
    ));
    std::fs::write(&tmp, contents)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// `ceil(a / b)` for positive integers, avoiding float rounding.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Format a duration compactly (`1.23s`, `45ms`, `812us`).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.0}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

/// Format an f64 in engineering style with the given significant digits —
/// used by all report tables so output is diff-stable.
pub fn fmt_sig(x: f64, sig: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let dec = (sig as i32 - 1 - mag).max(0) as usize;
    if mag.abs() >= 5 {
        format!("{x:.prec$e}", prec = sig - 1)
    } else {
        format!("{x:.dec$}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_replaces_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!("imcopt-atomic-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        write_atomic(&path, "first\n").unwrap();
        write_atomic(&path, "second\n").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "second\n");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .filter(|n| n.contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(u64::MAX, 1), u64::MAX);
    }

    #[test]
    fn fmt_sig_ranges() {
        assert_eq!(fmt_sig(0.0, 3), "0");
        assert_eq!(fmt_sig(1.234567, 3), "1.23");
        assert_eq!(fmt_sig(123.4567, 3), "123");
        assert!(fmt_sig(1.23e9, 3).contains('e'));
        assert!(fmt_sig(f64::NAN, 3).contains("NaN"));
    }

    #[test]
    fn fmt_duration_units() {
        use std::time::Duration;
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(45)), "45ms");
        assert_eq!(fmt_duration(Duration::from_micros(812)), "812us");
    }
}
