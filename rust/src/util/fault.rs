//! Deterministic fault injection for crash-matrix testing.
//!
//! The orchestrator's robustness claims (lease stealing, panic isolation,
//! crash-consistent journals) are only credible if they are exercised by
//! tests that crash processes at *seeded, reproducible* points. This module
//! provides those points. Production code calls [`point`] at named sites;
//! when the `IMCOPT_FAULT` environment variable is unset (the normal case)
//! every call is a no-op costing one atomic load.
//!
//! `IMCOPT_FAULT` accepts two grammars:
//!
//! 1. **Plan mode** — a comma-separated list of
//!    `[w<id>:]<kind>@<site>=<nth|*>` entries, e.g.
//!    `IMCOPT_FAULT="w1:exit@cell=2,io@journal=1"`:
//!    - `kind` is `panic` (the site panics), `io` (the site returns an
//!      `io::Error`), or `exit` (the whole process dies with exit code 137,
//!      simulating `kill -9`).
//!    - `site` matches exactly, or as a `:`-separated prefix: an entry for
//!      `cell` matches the site `cell:fig3:w=4`, an entry for `journal`
//!      matches `journal:cells`.
//!    - `=<nth>` fires on the nth visit *counted per plan entry* across all
//!      sites the entry matches; `=*` fires on every visit (a permanently
//!      poisoned site).
//!    - `w<id>:` restricts the entry to the worker process whose
//!      `IMCOPT_WORKER_ID` equals `<id>` (entries without a prefix apply to
//!      every process).
//! 2. **Random mode** — `<seed>:<rate>` (e.g. `IMCOPT_FAULT=42:0.01`)
//!    derives a deterministic per-visit hash from the seed, the site name
//!    and a global visit counter; sites whose hash falls below `rate` fail
//!    (journal sites with `io`, all others with `panic`). Same seed, same
//!    visit order, same faults.
//!
//! Sites currently instrumented:
//! - `cell:<key>` — entered when a checkpoint cell is about to be computed
//!   fresh (after journal lookup misses).
//! - `journal:cells` / `journal:shared` / `journal:memo` / `journal:acc` —
//!   entered before appending to the respective journal file.

use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// What a firing fault does to the caller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// `panic!` at the site (absorbed by the checkpoint's `catch_unwind`).
    Panic,
    /// Return an `io::Error` from the site.
    Io,
    /// Kill the whole process with exit code 137 (like `kill -9`).
    Exit,
}

#[derive(Debug)]
pub struct PlanEntry {
    kind: Kind,
    site: String,
    /// `None` = fire on every matched visit (`=*`).
    nth: Option<u64>,
    visits: AtomicU64,
}

impl PlanEntry {
    fn matches_site(&self, site: &str) -> bool {
        site == self.site
            || (site.len() > self.site.len()
                && site.starts_with(&self.site)
                && site.as_bytes()[self.site.len()] == b':')
    }
}

/// A parsed `IMCOPT_FAULT` value.
#[derive(Debug)]
pub enum Plan {
    /// Explicit entries (`[w<id>:]<kind>@<site>=<nth|*>`, comma-separated).
    Entries(Vec<PlanEntry>),
    /// `<seed>:<rate>` random mode.
    Random { seed: u64, rate: f64 },
}

impl Plan {
    /// Parse an `IMCOPT_FAULT` value for the process with the given worker
    /// id (`None` outside orchestrated runs). Malformed entries are
    /// rejected with a message rather than silently ignored — a typo in a
    /// fault plan must not produce a falsely green crash-matrix.
    pub fn parse(spec: &str, worker: Option<usize>) -> Result<Plan, String> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(Plan::Entries(Vec::new()));
        }
        // Random mode: exactly `<u64>:<f64>` with no `@`.
        if !spec.contains('@') {
            if let Some((s, r)) = spec.split_once(':') {
                let seed = s
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| format!("IMCOPT_FAULT: bad seed '{s}'"))?;
                let rate = r
                    .trim()
                    .parse::<f64>()
                    .map_err(|_| format!("IMCOPT_FAULT: bad rate '{r}'"))?;
                if !(0.0..=1.0).contains(&rate) {
                    return Err(format!("IMCOPT_FAULT: rate {rate} outside [0, 1]"));
                }
                return Ok(Plan::Random { seed, rate });
            }
            return Err(format!(
                "IMCOPT_FAULT: '{spec}' is neither <seed>:<rate> nor a plan entry"
            ));
        }
        let mut entries = Vec::new();
        for raw in spec.split(',') {
            let mut entry = raw.trim();
            if entry.is_empty() {
                continue;
            }
            let mut entry_worker = None;
            if let Some(rest) = entry.strip_prefix('w') {
                // `w<digits>:` worker scope; `w` alone would be a kind typo.
                if let Some((id, tail)) = rest.split_once(':') {
                    if let Ok(id) = id.parse::<usize>() {
                        entry_worker = Some(id);
                        entry = tail;
                    }
                }
            }
            let (kind_s, rest) = entry
                .split_once('@')
                .ok_or_else(|| format!("IMCOPT_FAULT: entry '{raw}' missing '@site'"))?;
            let kind = match kind_s.trim() {
                "panic" => Kind::Panic,
                "io" => Kind::Io,
                "exit" => Kind::Exit,
                other => return Err(format!("IMCOPT_FAULT: unknown kind '{other}'")),
            };
            let (site, nth_s) = rest
                .split_once('=')
                .ok_or_else(|| format!("IMCOPT_FAULT: entry '{raw}' missing '=nth'"))?;
            let nth = match nth_s.trim() {
                "*" => None,
                n => Some(
                    n.parse::<u64>()
                        .ok()
                        .filter(|&n| n >= 1)
                        .ok_or_else(|| format!("IMCOPT_FAULT: bad visit count '{n}'"))?,
                ),
            };
            // An entry scoped to another worker is validated (a typo must
            // fail everywhere) but dropped in this process.
            if entry_worker.is_some() && entry_worker != worker {
                continue;
            }
            entries.push(PlanEntry {
                kind,
                site: site.trim().to_string(),
                nth,
                visits: AtomicU64::new(0),
            });
        }
        Ok(Plan::Entries(entries))
    }

    /// Which fault (if any) fires for this visit of `site`.
    fn fire(&self, site: &str) -> Option<Kind> {
        match self {
            Plan::Entries(entries) => {
                let mut fired = None;
                for e in entries {
                    if !e.matches_site(site) {
                        continue;
                    }
                    let visit = e.visits.fetch_add(1, Ordering::Relaxed) + 1;
                    let hit = match e.nth {
                        None => true,
                        Some(n) => visit == n,
                    };
                    if hit && fired.is_none() {
                        fired = Some(e.kind);
                    }
                }
                fired
            }
            Plan::Random { seed, rate } => {
                static VISITS: AtomicU64 = AtomicU64::new(0);
                let visit = VISITS.fetch_add(1, Ordering::Relaxed);
                let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed.wrapping_mul(0x100_0000_01b3);
                for b in site.bytes() {
                    h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
                }
                h = (h ^ visit).wrapping_mul(0x100_0000_01b3);
                // xorshift finalizer for avalanche
                h ^= h >> 33;
                h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
                h ^= h >> 33;
                let u = (h >> 11) as f64 / (1u64 << 53) as f64;
                if u < *rate {
                    Some(if site.starts_with("journal") {
                        Kind::Io
                    } else {
                        Kind::Panic
                    })
                } else {
                    None
                }
            }
        }
    }
}

fn active_plan() -> Option<&'static Plan> {
    static PLAN: OnceLock<Option<Plan>> = OnceLock::new();
    PLAN.get_or_init(|| {
        let spec = std::env::var("IMCOPT_FAULT").ok()?;
        let worker = std::env::var("IMCOPT_WORKER_ID")
            .ok()
            .and_then(|v| v.parse::<usize>().ok());
        match Plan::parse(&spec, worker) {
            Ok(Plan::Entries(e)) if e.is_empty() => None,
            Ok(plan) => Some(plan),
            Err(msg) => {
                eprintln!("[fault] {msg} — ignoring fault plan");
                None
            }
        }
    })
    .as_ref()
}

/// A named fault-injection site. No-op unless `IMCOPT_FAULT` selects this
/// visit, in which case it panics (`Kind::Panic`), returns an injected
/// `io::Error` (`Kind::Io`), or exits the process with code 137
/// (`Kind::Exit`).
pub fn point(site: &str) -> io::Result<()> {
    let Some(plan) = active_plan() else {
        return Ok(());
    };
    match plan.fire(site) {
        None => Ok(()),
        Some(Kind::Io) => Err(io::Error::other(format!("injected fault at {site}"))),
        Some(Kind::Panic) => panic!("injected fault at {site}"),
        Some(Kind::Exit) => {
            eprintln!("[fault] injected kill at {site}");
            std::process::exit(137);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fire_seq(plan: &Plan, sites: &[&str]) -> Vec<Option<Kind>> {
        sites.iter().map(|s| plan.fire(s)).collect()
    }

    #[test]
    fn plan_counts_visits_per_entry_across_prefixed_sites() {
        let plan = Plan::parse("panic@cell=3", None).unwrap();
        let fired = fire_seq(&plan, &["cell:a", "cell:b", "cell:c", "cell:d"]);
        assert_eq!(
            fired,
            vec![None, None, Some(Kind::Panic), None],
            "3rd visit to any cell:* site must fire"
        );
    }

    #[test]
    fn star_fires_every_matched_visit() {
        let plan = Plan::parse("io@journal:cells=*", None).unwrap();
        assert_eq!(plan.fire("journal:cells"), Some(Kind::Io));
        assert_eq!(plan.fire("journal:cells"), Some(Kind::Io));
        assert_eq!(plan.fire("journal:shared"), None, "exact/prefix only");
    }

    #[test]
    fn prefix_matching_respects_segment_boundaries() {
        let plan = Plan::parse("panic@cell=1", None).unwrap();
        assert_eq!(plan.fire("cellar:x"), None, "'cellar' is not 'cell:*'");
        assert_eq!(plan.fire("cell:x"), Some(Kind::Panic));
    }

    #[test]
    fn worker_scoped_entries_only_apply_to_that_worker() {
        let for_w1 = Plan::parse("w1:exit@cell=1", Some(1)).unwrap();
        assert_eq!(for_w1.fire("cell:x"), Some(Kind::Exit));
        let for_w2 = Plan::parse("w1:exit@cell=1", Some(2)).unwrap();
        assert_eq!(for_w2.fire("cell:x"), None);
        let for_main = Plan::parse("w1:exit@cell=1", None).unwrap();
        assert_eq!(for_main.fire("cell:x"), None);
    }

    #[test]
    fn multiple_entries_count_independently() {
        let plan = Plan::parse("panic@cell=2, io@journal=1", None).unwrap();
        assert_eq!(plan.fire("journal:cells"), Some(Kind::Io));
        assert_eq!(plan.fire("cell:a"), None);
        assert_eq!(plan.fire("cell:b"), Some(Kind::Panic));
    }

    #[test]
    fn random_mode_is_deterministic_and_rate_bounded() {
        let a = Plan::parse("42:0.25", None).unwrap();
        let b = Plan::parse("42:0.25", None).unwrap();
        // Same seed → same fault sequence (counters are per-Plan only in
        // Entries mode; Random uses a process-global counter, so compare
        // hashes directly through one interleaved run).
        let mut fired = 0usize;
        for i in 0..400 {
            let site = format!("cell:{i}");
            let fa = a.fire(&site).is_some();
            let fb = b.fire(&site).is_some();
            // a and b consume distinct global visit numbers, so they need
            // not agree per call; the aggregate rate still must be sane.
            fired += usize::from(fa) + usize::from(fb);
        }
        assert!(fired > 0, "rate 0.25 over 800 visits must fire sometimes");
        assert!(fired < 500, "rate 0.25 must not fire on most visits");
        // zero rate never fires
        let z = Plan::parse("7:0.0", None).unwrap();
        assert!((0..100).all(|i| z.fire(&format!("cell:{i}")).is_none()));
    }

    #[test]
    fn malformed_specs_are_rejected() {
        assert!(Plan::parse("panic@cell", None).is_err(), "missing =nth");
        assert!(Plan::parse("boom@cell=1", None).is_err(), "unknown kind");
        assert!(Plan::parse("panic@cell=0", None).is_err(), "nth >= 1");
        assert!(Plan::parse("42:1.5", None).is_err(), "rate > 1");
        assert!(Plan::parse("x:0.1", None).is_err(), "bad seed");
        assert!(Plan::parse("justtext", None).is_err());
        assert!(matches!(
            Plan::parse("", None),
            Ok(Plan::Entries(e)) if e.is_empty()
        ));
    }
}
