//! A miniature property-based testing harness (no proptest crate offline).
//!
//! [`check`] runs a property over `n` randomly generated cases; on failure
//! it performs a bounded greedy shrink by re-generating from nearby seeds
//! and reports the seed so the failure is reproducible:
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the crate's rpath flags,
//! // so they can't locate the xla shared libraries at load time)
//! use imcopt::util::{proptest::check, rng::Rng};
//! check("addition commutes", 200, |rng: &mut Rng| {
//!     let (a, b) = (rng.below(1000) as i64, rng.below(1000) as i64);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a},{b}")) }
//! });
//! ```

use super::rng::Rng;

/// Run `prop` on `cases` random inputs. The property receives a seeded RNG
/// and returns `Err(description)` to signal a counterexample. Panics with
/// the failing seed + description so `cargo test` reports it.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    // Fixed base seed: property tests are deterministic run-to-run;
    // override with IMCOPT_PROPTEST_SEED to explore.
    let base = std::env::var("IMCOPT_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::seed_from(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}): {msg}\n\
                 reproduce with IMCOPT_PROPTEST_SEED={base} (case index {case})"
            );
        }
    }
}

/// Like [`check`] but the property builds its own input value from the RNG
/// through `gen`, which keeps generation/checking separated for readability.
pub fn check_with<T, G, F>(name: &str, cases: usize, gen: G, prop: F)
where
    G: Fn(&mut Rng) -> T,
    F: Fn(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    check(name, cases, |rng| {
        let input = gen(rng);
        prop(&input).map_err(|m| format!("{m}; input={input:?}"))
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("rotate roundtrip", 100, |rng| {
            let x = rng.next_u64();
            if x.rotate_left(13).rotate_right(13) == x {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics() {
        check("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn check_with_passes_input() {
        check_with(
            "sorted idempotent",
            50,
            |rng| {
                let mut v: Vec<u64> = (0..rng.below(20)).map(|_| rng.next_u64()).collect();
                v.sort_unstable();
                v
            },
            |v| {
                let mut w = v.clone();
                w.sort_unstable();
                if &w == v {
                    Ok(())
                } else {
                    Err("sort changed a sorted vec".into())
                }
            },
        );
    }
}
