//! Minimal benchmarking harness (the offline registry has no criterion).
//!
//! Benches are `harness = false` binaries that call [`Bench::run`] per
//! case: warm-up, then timed iterations until a wall-clock budget is spent,
//! reporting mean / median / p95 per-iteration time and throughput. Output
//! is stable plain text suitable for `cargo bench | tee bench_output.txt`.

use std::time::{Duration, Instant};

use super::stats;

/// One benchmark suite; prints a header and per-case rows.
pub struct Bench {
    suite: String,
    /// Per-case measurement budget.
    pub budget: Duration,
    /// Minimum timed iterations regardless of budget.
    pub min_iters: usize,
}

/// A single case's measurements.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        println!("\n=== bench suite: {suite} ===");
        // Honor a quick mode for CI smoke runs.
        let quick = std::env::var("IMCOPT_BENCH_QUICK").is_ok();
        Bench {
            suite: suite.to_string(),
            budget: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            min_iters: if quick { 3 } else { 10 },
        }
    }

    /// Time `f` repeatedly; `items_per_iter` scales the throughput line
    /// (e.g. designs evaluated per call).
    pub fn run<F: FnMut()>(&self, name: &str, items_per_iter: usize, mut f: F) -> Measurement {
        // Warm-up: one untimed call (fills caches, JITs nothing here but
        // primes page tables and the PJRT executable).
        f();
        let mut samples: Vec<f64> = Vec::new();
        let started = Instant::now();
        while started.elapsed() < self.budget || samples.len() < self.min_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= 10_000 {
                break;
            }
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            mean: Duration::from_secs_f64(stats::mean(&samples)),
            median: Duration::from_secs_f64(stats::percentile_sorted(&sorted, 0.5)),
            p95: Duration::from_secs_f64(stats::percentile_sorted(&sorted, 0.95)),
        };
        let thr = if m.mean.as_secs_f64() > 0.0 {
            items_per_iter as f64 / m.mean.as_secs_f64()
        } else {
            f64::INFINITY
        };
        println!(
            "{suite}/{name}: {iters} iters, mean {mean}, median {median}, p95 {p95}, {thr:.1} items/s",
            suite = self.suite,
            iters = m.iters,
            mean = super::fmt_duration(m.mean),
            median = super::fmt_duration(m.median),
            p95 = super::fmt_duration(m.p95),
        );
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("selftest");
        b.budget = Duration::from_millis(30);
        b.min_iters = 3;
        let mut acc = 0u64;
        let m = b.run("noop-ish", 1, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(m.iters >= 3);
        assert!(m.mean.as_secs_f64() >= 0.0);
    }
}
