//! # imcopt — joint hardware-workload co-optimization for IMC accelerators
//!
//! Reproduction of Krestinskaya et al., *"Joint Hardware-Workload
//! Co-Optimization for In-Memory Computing Accelerators"* (2026).
//!
//! The crate is the **L3 coordinator** of a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`space`] — the multi-level hardware search space (device / circuit /
//!   architecture / system parameters) with index-coded designs.
//! * [`workloads`] — per-layer shape models of the nine neural-network
//!   workloads evaluated in the paper.
//! * [`ingest`] — workload ingestion beyond the hand-coded nine: a
//!   layer-list JSON parser (schema-pinned), a pragmatic ONNX-subset
//!   reader, and the seeded synthetic generator behind `--spec
//!   synth:<dist>:<n>:<seed>` scenario families and the `population`
//!   experiment (see `docs/workloads.md`).
//! * [`model`] — the analytical IMC hardware evaluator (energy / latency /
//!   area for tiled RRAM- and SRAM-based crossbar architectures); the
//!   CIMLoop substitute, mirrored 1:1 by the AOT-compiled JAX/Pallas
//!   fitness artifact.
//! * [`objective`] — joint scores across workloads (EDAP/EDP/E/L/A ×
//!   {Max, All, Mean} aggregation, cost-aware, accuracy-aware).
//! * [`search`] — the paper's four-phase genetic algorithm with
//!   Hamming-distance sampling, plus the baseline optimizers of Table 3
//!   (GA, PSO, ES, ERES, CMA-ES, G3PCX) and exhaustive enumeration.
//! * [`pareto`] — the multi-objective counterpart: NSGA-II over vector
//!   objectives ([`pareto::MooMode`]: energy/latency/area axes, or one
//!   EDAP axis per workload), bounded deterministic front archives and
//!   front-quality indicators (hypervolume, spacing, knee); surfaced by
//!   the `pareto` registry experiment (see `docs/pareto.md`).
//! * [`accuracy`] — RRAM non-ideality model (conductance noise, IR-drop,
//!   quantization) for the accuracy-aware objective of Fig. 8.
//! * [`robustness`] — deterministic device-variation injection:
//!   σ(g)/IR-drop corners, retention drift and stuck-at cells as
//!   [`robustness::Perturbation`]s over the accuracy noise model, seeded
//!   [`robustness::PerturbationEnsemble`]s, and the robust objective
//!   modes behind `--robust worst|cvar<q>|mean` (see
//!   `docs/robustness.md`).
//! * [`runtime`] — PJRT engine that loads the AOT artifacts
//!   (`artifacts/*.hlo.txt`) and executes batched fitness evaluation on the
//!   hot path; Python never runs at search time.
//! * [`coordinator`] — the experiment runner: population evaluation with
//!   memoization, thread-pool fan-out, progress reporting and experiment
//!   configs.
//! * [`scenarios`] — scenario portfolios: [`scenarios::Portfolio`]
//!   describes a (train set, deploy set) generalization study, with
//!   combinatorial generators for hold-k-out and cross-set transfer
//!   (the `genmatrix_k` / `transfer` experiments; see
//!   `docs/scenarios.md`).
//! * [`experiments`] — the experiment registry: one module per paper
//!   table/figure (plus the portfolio sweeps), each a
//!   [`experiments::Experiment`] entry with checkpoint/resume support
//!   (`experiments::checkpoint`) and machine-readable JSON artifacts.
//!   The registry is self-describing: `imcopt list --markdown`
//!   regenerates the catalog in `docs/experiments.md`, and a drift test
//!   pins the checked-in file to [`experiments::REGISTRY`].
//! * [`orchestrator`] — fault-tolerant multi-process sweeps
//!   (`imcopt run --workers N`): file-locked cell claims with heartbeat
//!   leases, a worker supervisor with restart budgets and quarantine,
//!   and the deterministic fault-injection harness behind the
//!   crash-matrix tests (see `docs/orchestration.md`).
//! * [`telemetry`] — deterministic out-of-band observability: relaxed
//!   atomic counters at the memo/screen/journal/lease hot sites, timing
//!   spans around the hot boundaries, and a schema-pinned per-generation
//!   search trace under `<out-dir>/telemetry/`, rendered post-mortem by
//!   `imcopt trace` (see `docs/telemetry.md`). Strictly out of band:
//!   reports, journals, and artifacts are byte-identical with telemetry
//!   on or off.
//! * [`util`] — std-only infrastructure (RNG, thread pool, sharded
//!   striped-lock cache, JSON, stats, tables, CLI, property-testing and
//!   bench harnesses); the offline crate registry has no
//!   tokio/rayon/clap/criterion/serde/rand.
//!
//! ## Quickstart
//!
//! ```no_run
//! use imcopt::prelude::*;
//!
//! // Search space + workloads of the paper's 4-workload experiments.
//! let space = SearchSpace::rram();
//! let workloads = WorkloadSet::cnn4();
//! // Native analytical evaluator (the PJRT artifact path is in `runtime`).
//! let eval = NativeEvaluator::new(MemoryTech::Rram);
//! let problem = JointProblem::new(&space, &workloads, eval,
//!                                 Objective::edap(), Aggregation::Max);
//! let mut rng = Rng::seed_from(42);
//! let result = FourPhaseGa::paper_defaults().run(&problem, &mut rng);
//! println!("best joint EDAP score: {:.4e}", result.best_score);
//! ```

pub mod accuracy;
pub mod coordinator;
pub mod experiments;
pub mod ingest;
pub mod model;
pub mod objective;
pub mod orchestrator;
pub mod pareto;
pub mod report;
pub mod robustness;
pub mod runtime;
pub mod scenarios;
pub mod search;
pub mod space;
pub mod telemetry;
pub mod util;
pub mod workloads;

/// Convenient re-exports of the most frequently used public items.
pub mod prelude {
    pub use crate::coordinator::{EvalBackend, Evaluations, JointProblem};
    pub use crate::model::{Metrics, MemoryTech, NativeEvaluator};
    pub use crate::objective::{Aggregation, Objective, ObjectiveKind};
    pub use crate::pareto::{
        MooMode, MooProblem, MooResult, MultiObjective, MultiObjectiveOptimizer, Nsga2,
        Nsga2Config, ParetoArchive, VectorObjective,
    };
    pub use crate::robustness::{
        Corner, Perturbation, PerturbationEnsemble, RobustConfig, RobustMode,
    };
    pub use crate::scenarios::{Portfolio, ScenarioSpec};
    pub use crate::search::{
        FourPhaseGa, GaConfig, GeneticAlgorithm, OptResult, Optimizer, SearchBudget,
    };
    pub use crate::space::{Design, SearchSpace};
    pub use crate::util::rng::Rng;
    pub use crate::workloads::{Workload, WorkloadSet};
}
