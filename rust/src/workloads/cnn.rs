//! CNN workload layer tables (ImageNet 224×224 input, batch 1, 8-bit).
//!
//! Shapes are generated programmatically from the published architectures
//! (torchvision variants). Only matmul-mapped layers are emitted:
//! convolutions (im2col view), depthwise convolutions (per-channel view),
//! squeeze-excite and classifier FCs. Pooling/norm/activation stages only
//! affect the tracked spatial size.

use super::{Layer, LayerKind, Workload};

/// Spatial tracking context while building a network.
struct Ctx {
    /// Current feature-map side (square maps).
    hw: u64,
    /// Current channel count.
    c: u64,
    layers: Vec<Layer>,
}

impl Ctx {
    fn new() -> Ctx {
        Ctx {
            hw: 224,
            c: 3,
            layers: Vec::new(),
        }
    }

    /// Standard convolution with explicit geometry.
    /// `pad` is per-side; output side = (hw + 2*pad - k)/stride + 1.
    fn conv_px(&mut self, name: &str, cout: u64, k: u64, stride: u64, pad: u64) {
        let out = (self.hw + 2 * pad - k) / stride + 1;
        let kk = k * k * self.c;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Conv,
            k: kk,
            n: cout,
            passes: out * out,
            weights: kk * cout,
            in_bytes: self.hw * self.hw * self.c,
            out_bytes: out * out * cout,
        });
        self.hw = out;
        self.c = cout;
    }

    /// Same-padded convolution (pad = k/2), the common case.
    fn conv(&mut self, name: &str, cout: u64, k: u64, stride: u64) {
        self.conv_px(name, cout, k, stride, k / 2);
    }

    /// Depthwise convolution: per-channel k×k filter; matmul view
    /// `k = kh·kw`, `n = channels`.
    fn dwconv(&mut self, name: &str, k: u64, stride: u64) {
        let pad = k / 2;
        let out = (self.hw + 2 * pad - k) / stride + 1;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::DepthwiseConv,
            k: k * k,
            n: self.c,
            passes: out * out,
            weights: k * k * self.c,
            in_bytes: self.hw * self.hw * self.c,
            out_bytes: out * out * self.c,
        });
        self.hw = out;
    }

    /// Max/avg pool: spatial reduction only.
    fn pool(&mut self, k: u64, stride: u64) {
        // floor mode, no padding (torchvision default for these nets)
        self.hw = (self.hw - k) / stride + 1;
    }

    /// Global average pool to 1×1.
    fn gap(&mut self) {
        self.hw = 1;
    }

    /// Fully connected layer on the flattened current tensor.
    fn fc(&mut self, name: &str, nout: u64) {
        let nin = self.hw * self.hw * self.c;
        self.layers.push(Layer {
            name: name.to_string(),
            kind: LayerKind::Fc,
            k: nin,
            n: nout,
            passes: 1,
            weights: nin * nout,
            in_bytes: nin,
            out_bytes: nout,
        });
        self.hw = 1;
        self.c = nout;
    }

    /// Squeeze-and-excite block: GAP + two FCs on the channel vector.
    fn se(&mut self, name: &str, reduce: u64) {
        let c = self.c;
        let mid = (c / reduce).max(8);
        for (suffix, k, n) in [("se_fc1", c, mid), ("se_fc2", mid, c)] {
            self.layers.push(Layer {
                name: format!("{name}.{suffix}"),
                kind: LayerKind::Fc,
                k,
                n,
                passes: 1,
                weights: k * n,
                in_bytes: k,
                out_bytes: n,
            });
        }
    }

    fn finish(self, name: &'static str) -> Workload {
        Workload::new(name, self.layers)
    }
}

/// AlexNet (torchvision; 61M params).
pub fn alexnet() -> Workload {
    let mut c = Ctx::new();
    c.conv_px("conv1", 64, 11, 4, 2); // 224 -> 55
    c.pool(3, 2); // 27
    c.conv_px("conv2", 192, 5, 1, 2);
    c.pool(3, 2); // 13
    c.conv("conv3", 384, 3, 1);
    c.conv("conv4", 256, 3, 1);
    c.conv("conv5", 256, 3, 1);
    c.pool(3, 2); // 6
    c.fc("fc6", 4096);
    c.fc("fc7", 4096);
    c.fc("fc8", 1000);
    c.finish("alexnet")
}

/// VGG16 (138M params; its fc6 at 25088×4096 is the largest single layer
/// across all nine workloads — the paper's "largest workload").
pub fn vgg16() -> Workload {
    let mut c = Ctx::new();
    let cfg: &[&[u64]] = &[&[64, 64], &[128, 128], &[256, 256, 256], &[512, 512, 512], &[512, 512, 512]];
    for (bi, block) in cfg.iter().enumerate() {
        for (li, &ch) in block.iter().enumerate() {
            c.conv(&format!("conv{}_{}", bi + 1, li + 1), ch, 3, 1);
        }
        c.pool(2, 2);
    }
    c.fc("fc6", 4096);
    c.fc("fc7", 4096);
    c.fc("fc8", 1000);
    c.finish("vgg16")
}

/// Shared ResNet stem: 7×7/2 conv + 3×3/2 maxpool.
fn resnet_stem(c: &mut Ctx) {
    c.conv_px("conv1", 64, 7, 2, 3); // 224 -> 112
    c.pool(3, 2); // 112 -> 55 floor-mode; torchvision pads -> 56
    c.hw = 56; // torchvision uses padded maxpool; fix up
}

/// ResNet-18 (11.7M params): 4 stages × 2 basic blocks.
pub fn resnet18() -> Workload {
    let mut c = Ctx::new();
    resnet_stem(&mut c);
    let widths = [64u64, 128, 256, 512];
    for (si, &w) in widths.iter().enumerate() {
        for b in 0..2u64 {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            if stride == 2 {
                // projection shortcut
                let (hw, cin) = (c.hw, c.c);
                c.conv(&format!("layer{}_{}_conv1", si + 1, b), w, 3, stride);
                c.conv(&format!("layer{}_{}_conv2", si + 1, b), w, 3, 1);
                // downsample path (1×1, stride 2) from the block input
                let saved = (c.hw, c.c);
                c.hw = hw;
                c.c = cin;
                c.conv(&format!("layer{}_{}_down", si + 1, b), w, 1, 2);
                c.hw = saved.0;
                c.c = saved.1;
            } else {
                c.conv(&format!("layer{}_{}_conv1", si + 1, b), w, 3, 1);
                c.conv(&format!("layer{}_{}_conv2", si + 1, b), w, 3, 1);
            }
        }
    }
    c.gap();
    c.fc("fc", 1000);
    c.finish("resnet18")
}

/// ResNet-50 (25.6M params): 4 stages × [3,4,6,3] bottleneck blocks.
pub fn resnet50() -> Workload {
    let mut c = Ctx::new();
    resnet_stem(&mut c);
    let stages: [(u64, u64, usize); 4] =
        [(64, 256, 3), (128, 512, 4), (256, 1024, 6), (512, 2048, 3)];
    let mut cin = 64u64;
    for (si, &(mid, out, blocks)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let stride = if si > 0 && b == 0 { 2 } else { 1 };
            let block_in_hw = c.hw;
            c.c = cin;
            c.conv(&format!("layer{}_{}_c1", si + 1, b), mid, 1, 1);
            c.conv(&format!("layer{}_{}_c2", si + 1, b), mid, 3, stride);
            c.conv(&format!("layer{}_{}_c3", si + 1, b), out, 1, 1);
            if b == 0 {
                // projection shortcut from block input
                let saved = (c.hw, c.c);
                c.hw = block_in_hw;
                c.c = cin;
                c.conv(&format!("layer{}_{}_down", si + 1, b), out, 1, stride);
                c.hw = saved.0;
                c.c = saved.1;
            }
            cin = out;
        }
    }
    c.gap();
    c.fc("fc", 1000);
    c.finish("resnet50")
}

/// MobileNetV3-Large (5.4M params): inverted-residual bottlenecks with
/// optional squeeze-excite, from the paper's Table 2 (Howard et al. 2019).
pub fn mobilenet_v3_large() -> Workload {
    let mut c = Ctx::new();
    c.conv("stem", 16, 3, 2); // 224 -> 112
    // (kernel, expansion, out, SE, stride)
    let blocks: &[(u64, u64, u64, bool, u64)] = &[
        (3, 16, 16, false, 1),
        (3, 64, 24, false, 2),
        (3, 72, 24, false, 1),
        (5, 72, 40, true, 2),
        (5, 120, 40, true, 1),
        (5, 120, 40, true, 1),
        (3, 240, 80, false, 2),
        (3, 200, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 480, 112, true, 1),
        (3, 672, 112, true, 1),
        (5, 672, 160, true, 2),
        (5, 960, 160, true, 1),
        (5, 960, 160, true, 1),
    ];
    for (i, &(k, exp, out, se, stride)) in blocks.iter().enumerate() {
        let name = format!("bneck{i}");
        if exp != c.c {
            c.conv(&format!("{name}.expand"), exp, 1, 1);
        }
        c.dwconv(&format!("{name}.dw"), k, stride);
        if se {
            c.se(&name, 4);
        }
        c.conv(&format!("{name}.project"), out, 1, 1);
    }
    c.conv("head_conv", 960, 1, 1); // 7×7×960
    c.gap();
    c.fc("head_fc1", 1280);
    c.fc("classifier", 1000);
    c.finish("mobilenetv3")
}

/// DenseNet-201 (20M params): growth 32, blocks [6,12,48,32], bottleneck
/// 1×1(128)+3×3(32) dense layers, compression-0.5 transitions.
pub fn densenet201() -> Workload {
    let mut c = Ctx::new();
    c.conv_px("stem", 64, 7, 2, 3);
    c.pool(3, 2);
    c.hw = 56; // padded maxpool as in torchvision
    let growth = 32u64;
    let blocks = [6usize, 12, 48, 32];
    let mut ch = 64u64;
    for (bi, &n_layers) in blocks.iter().enumerate() {
        for li in 0..n_layers {
            // dense layer: 1x1 conv ch->4*growth, 3x3 conv 4*growth->growth
            c.c = ch;
            c.conv(&format!("db{}_{}_c1", bi + 1, li), 4 * growth, 1, 1);
            c.conv(&format!("db{}_{}_c2", bi + 1, li), growth, 3, 1);
            ch += growth;
        }
        if bi < blocks.len() - 1 {
            // transition: 1x1 conv to ch/2 + 2x2 avgpool
            c.c = ch;
            c.conv(&format!("trans{}", bi + 1), ch / 2, 1, 1);
            c.pool(2, 2);
            ch /= 2;
        }
    }
    c.c = ch; // 1920
    c.gap();
    c.fc("classifier", 1000);
    c.finish("densenet201")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_geometry() {
        let w = alexnet();
        // conv1 maps 224->55
        assert_eq!(w.layers[0].passes, 55 * 55);
        // fc6 input is 6*6*256 = 9216
        let fc6 = w.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert_eq!(fc6.k, 9216);
        assert_eq!(fc6.weights, 9216 * 4096);
    }

    #[test]
    fn vgg16_weights() {
        let w = vgg16();
        assert_eq!(w.layers.len(), 16); // 13 convs + 3 fcs
        let total = w.total_weights();
        assert!((total as f64 - 138.0e6).abs() / 138.0e6 < 0.02, "{total}");
    }

    #[test]
    fn resnet18_shapes() {
        let w = resnet18();
        // stem + (2+2)+( 2*2+1)+(5)+(5) convs + fc = 21 mapped layers
        assert_eq!(w.layers.len(), 21);
        let total = w.total_weights() as f64;
        assert!((total - 11.2e6).abs() / 11.2e6 < 0.05, "{total}");
        // final stage operates at 7x7
        let last_conv = &w.layers[w.layers.len() - 2];
        assert_eq!(last_conv.passes, 7 * 7);
    }

    #[test]
    fn resnet50_block_count() {
        let w = resnet50();
        // stem + 16 blocks*3 + 4 downsamples + fc = 1+48+4+1 = 54
        assert_eq!(w.layers.len(), 54);
    }

    #[test]
    fn mobilenet_has_dw_and_se() {
        let w = mobilenet_v3_large();
        assert!(w.layers.iter().any(|l| l.kind == LayerKind::DepthwiseConv));
        assert!(w.layers.iter().any(|l| l.name.contains("se_fc")));
        let total = w.total_weights() as f64;
        assert!((total - 5.2e6).abs() / 5.2e6 < 0.10, "{total}");
    }

    #[test]
    fn densenet_channel_growth() {
        let w = densenet201();
        // final classifier input must be 1920 channels
        let fc = w.layers.last().unwrap();
        assert_eq!(fc.k, 1920);
        // 2 convs per dense layer * 98 + 3 transitions + stem + fc
        assert_eq!(w.layers.len(), 2 * 98 + 3 + 1 + 1);
    }
}
