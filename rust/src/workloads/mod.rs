//! Neural-network workload models (paper §III-A, §IV-J).
//!
//! The hardware evaluator only needs each layer's *matmul view*: a weight
//! matrix of `k × n` (crossbar rows × columns before bit-slicing), the
//! number of input vectors applied per inference (`passes`), and the
//! activation traffic. Convolutions map through im2col
//! (`k = kh·kw·c_in`, `n = c_out`, `passes = out_h·out_w`), depthwise
//! convolutions map per-channel (`k = kh·kw`, `n = c`), transformer
//! projections map with `passes = seq_len`, and attention
//! activation×activation matmuls are flagged [`Layer::dynamic`] — they
//! cannot be weight-stationary and execute on the per-tile digital vector
//! units (see `model::digital`).
//!
//! All models are 8-bit quantized (weights and activations), as in the
//! paper's experiments. Embedding lookups and norms/biases are excluded
//! from the crossbar mapping (standard practice; they are not matmuls).

mod cnn;
mod transformer;

pub use cnn::{alexnet, densenet201, mobilenet_v3_large, resnet18, resnet50, vgg16};
pub use transformer::{gpt2_medium, mobilebert, vit_b16};

use crate::model::compiled::CompiledWorkload;
use std::sync::OnceLock;

/// Maximum padded layer count in the AOT workload tensor — shared with
/// `python/compile/hwspec.py` (MobileBERT has the most mapped layers).
pub const L_MAX: usize = 512;
/// Features per layer row in the AOT workload tensor.
pub const LAYER_FEATURES: usize = 8;

/// Kind of a mapped layer (affects mapping and the digital-unit path).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    DepthwiseConv,
    Fc,
    /// Activation×activation matmul (attention scores / context): no
    /// stored weights; runs on the digital vector unit.
    Dynamic,
}

/// One mapped layer in matmul view.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Weight-matrix rows (crossbar input dimension).
    pub k: u64,
    /// Weight-matrix columns (output features, before bit slicing).
    pub n: u64,
    /// Input vectors applied per inference.
    pub passes: u64,
    /// Stored parameters (0 for dynamic layers).
    pub weights: u64,
    /// Input activation bytes per inference (8-bit activations).
    pub in_bytes: u64,
    /// Output activation bytes per inference.
    pub out_bytes: u64,
}

impl Layer {
    /// Multiply-accumulate operations per inference.
    pub fn macs(&self) -> u64 {
        self.k * self.n * self.passes
    }
    pub fn dynamic(&self) -> bool {
        self.kind == LayerKind::Dynamic
    }
}

/// A full workload: an ordered list of mapped layers.
///
/// Names are owned strings so workloads can come from anywhere — the
/// hand-coded tables here, files parsed by [`crate::ingest`], or the
/// seeded synthetic generator ([`crate::ingest::WorkloadDistribution`]).
#[derive(Debug)]
pub struct Workload {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Lazily-built aggregate tables for the O(1) compiled evaluator
    /// (`model::compiled`); every evaluation of this instance reads the
    /// one table built on first use.
    compiled: OnceLock<CompiledWorkload>,
}

/// Cloning resets the compiled-table cache, so the common
/// clone-then-edit-layers pattern (tests, synthetic workloads) can never
/// observe a table compiled from the pre-edit layers.
impl Clone for Workload {
    fn clone(&self) -> Workload {
        Workload::new(self.name.clone(), self.layers.clone())
    }
}

impl Workload {
    /// Construct a workload (compiled tables build lazily on first
    /// evaluation).
    pub fn new(name: impl Into<String>, layers: Vec<Layer>) -> Workload {
        Workload {
            name: name.into(),
            layers,
            compiled: OnceLock::new(),
        }
    }

    /// The precomputed aggregate tables of `model::compiled`, built on
    /// first use. Mutating `layers` on an instance that has already been
    /// evaluated is not supported (the evaluator's O(1) staleness
    /// fingerprint — layer count plus first/last-layer signatures — makes
    /// it fall back to the naive path for the common edits, but interior
    /// same-length edits can evade it); clone first — clones start with
    /// an empty cache and recompile.
    pub fn compiled(&self) -> &CompiledWorkload {
        self.compiled
            .get_or_init(|| CompiledWorkload::build(&self.layers))
    }

    /// Total stored parameters (weights) across all layers.
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights).sum()
    }

    /// Largest single layer's weight count — the paper's "largest
    /// workload" criterion for SRAM weight-swapping (§IV-J).
    pub fn max_layer_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights).max().unwrap_or(0)
    }

    /// Total MACs per inference.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Number of layers that map onto crossbars (non-dynamic).
    pub fn mapped_layers(&self) -> usize {
        self.layers.iter().filter(|l| !l.dynamic()).count()
    }

    /// Export as the padded `[L_MAX, LAYER_FEATURES]` f32 tensor consumed
    /// by the AOT fitness artifact. Feature order (shared with
    /// `hwspec.py`): `[k, n, passes, weights, in_bytes, out_bytes,
    /// is_dynamic, valid]`.
    pub fn to_tensor(&self) -> Vec<f32> {
        self.to_tensor_padded(L_MAX)
    }

    /// Like [`Workload::to_tensor`] but padded to an arbitrary layer
    /// count — the runtime picks the smallest compiled artifact variant
    /// that fits (§Perf: short variants skip the padded rows).
    pub fn to_tensor_padded(&self, lmax: usize) -> Vec<f32> {
        assert!(
            self.layers.len() <= lmax,
            "{}: {} layers exceed lmax={lmax}",
            self.name,
            self.layers.len()
        );
        let mut t = vec![0f32; lmax * LAYER_FEATURES];
        for (i, l) in self.layers.iter().enumerate() {
            let row = &mut t[i * LAYER_FEATURES..(i + 1) * LAYER_FEATURES];
            row[0] = l.k as f32;
            row[1] = l.n as f32;
            row[2] = l.passes as f32;
            row[3] = l.weights as f32;
            row[4] = l.in_bytes as f32;
            row[5] = l.out_bytes as f32;
            row[6] = if l.dynamic() { 1.0 } else { 0.0 };
            row[7] = 1.0;
        }
        t
    }
}

/// A named set of workloads used by one experiment.
#[derive(Clone, Debug)]
pub struct WorkloadSet {
    pub workloads: Vec<Workload>,
}

impl WorkloadSet {
    /// The paper's core 4-workload CNN set (§III-A): ResNet18, VGG16,
    /// AlexNet, MobileNetV3.
    pub fn cnn4() -> WorkloadSet {
        WorkloadSet {
            workloads: vec![resnet18(), vgg16(), alexnet(), mobilenet_v3_large()],
        }
    }

    /// The 9-workload scalability set of §IV-J.
    pub fn all9() -> WorkloadSet {
        WorkloadSet {
            workloads: vec![
                resnet18(),
                vgg16(),
                alexnet(),
                mobilenet_v3_large(),
                mobilebert(),
                densenet201(),
                resnet50(),
                vit_b16(),
                gpt2_medium(),
            ],
        }
    }

    /// Construct from names (CLI).
    pub fn by_names(names: &[&str]) -> anyhow::Result<WorkloadSet> {
        let mut workloads = Vec::new();
        for n in names {
            workloads.push(by_name(n)?);
        }
        Ok(WorkloadSet { workloads })
    }

    pub fn len(&self) -> usize {
        self.workloads.len()
    }
    pub fn is_empty(&self) -> bool {
        self.workloads.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.workloads.iter().map(|w| w.name.as_str()).collect()
    }

    /// Index of the workload with the most total weights — the "largest
    /// workload" for RRAM weight-stationary experiments (§IV-A).
    pub fn largest_by_total(&self) -> usize {
        (0..self.len())
            .max_by_key(|&i| self.workloads[i].total_weights())
            .unwrap()
    }

    /// Index of the workload with the largest single layer — the "largest
    /// workload" in the SRAM weight-swapping sense (§IV-J).
    pub fn largest_by_layer(&self) -> usize {
        (0..self.len())
            .max_by_key(|&i| self.workloads[i].max_layer_weights())
            .unwrap()
    }
}

/// Look up a single workload by canonical name.
pub fn by_name(name: &str) -> anyhow::Result<Workload> {
    Ok(match name {
        "resnet18" => resnet18(),
        "resnet50" => resnet50(),
        "vgg16" => vgg16(),
        "alexnet" => alexnet(),
        "mobilenetv3" => mobilenet_v3_large(),
        "densenet201" => densenet201(),
        "vit" => vit_b16(),
        "mobilebert" => mobilebert(),
        "gpt2-medium" => gpt2_medium(),
        other => anyhow::bail!("unknown workload '{other}'"),
    })
}

/// All canonical workload names.
pub const ALL_NAMES: [&str; 9] = [
    "resnet18",
    "vgg16",
    "alexnet",
    "mobilenetv3",
    "mobilebert",
    "densenet201",
    "resnet50",
    "vit",
    "gpt2-medium",
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Known parameter counts (matmul weights only — embeddings, biases
    /// and norms excluded), checked within ±12 % of the published totals.
    #[test]
    fn parameter_counts_near_published() {
        let cases: &[(&str, f64)] = &[
            ("resnet18", 11.2e6),  // 11.7M incl. bn/bias
            ("resnet50", 25.0e6),  // 25.6M
            ("vgg16", 138.0e6),    // 138M
            ("alexnet", 61.0e6),   // 61M
            ("mobilenetv3", 5.1e6),
            ("densenet201", 19.0e6),
            ("vit", 85.0e6),
            ("gpt2-medium", 350.0e6), // 355M (w/ untied lm head counted once)
        ];
        for (name, published) in cases {
            let w = by_name(name).unwrap().total_weights() as f64;
            let rel = (w - published).abs() / published;
            assert!(
                rel < 0.12,
                "{name}: computed {w:.3e} vs published {published:.3e} (rel {rel:.3})"
            );
        }
    }

    #[test]
    fn vgg16_fc1_is_the_largest_single_layer_overall() {
        // Paper §IV-J: VGG16's largest layer (25088×4096 ≈ 1.03e8 params)
        // exceeds GPT-2 Medium's largest (~5.1e7), so VGG16 is the
        // "largest workload" even in the 9-workload SRAM experiment.
        let set = WorkloadSet::all9();
        let li = set.largest_by_layer();
        assert_eq!(set.workloads[li].name, "vgg16");
        let vgg_max = vgg16().max_layer_weights();
        assert_eq!(vgg_max, 25088 * 4096);
        let gpt_max = gpt2_medium().max_layer_weights();
        assert!(gpt_max > 4.0e7 as u64 && gpt_max < 6.0e7 as u64);
        assert!(vgg_max > gpt_max);
    }

    #[test]
    fn largest_by_total_is_gpt2_in_set9_and_vgg_in_cnn4() {
        let s9 = WorkloadSet::all9();
        assert_eq!(s9.workloads[s9.largest_by_total()].name, "gpt2-medium");
        let s4 = WorkloadSet::cnn4();
        assert_eq!(s4.workloads[s4.largest_by_total()].name, "vgg16");
    }

    #[test]
    fn layer_counts_fit_lmax() {
        for name in ALL_NAMES {
            let w = by_name(name).unwrap();
            assert!(
                w.layers.len() <= L_MAX,
                "{name} has {} layers",
                w.layers.len()
            );
            assert!(!w.layers.is_empty());
        }
    }

    #[test]
    fn tensor_layout() {
        let w = alexnet();
        let t = w.to_tensor();
        assert_eq!(t.len(), L_MAX * LAYER_FEATURES);
        // first layer: conv1 k=3*11*11
        assert_eq!(t[0], (3 * 11 * 11) as f32);
        // valid flags: exactly layers.len() ones
        let valid: f32 = (0..L_MAX).map(|i| t[i * LAYER_FEATURES + 7]).sum();
        assert_eq!(valid as usize, w.layers.len());
    }

    #[test]
    fn macs_sane() {
        // Published MAC counts (±25 %: our mapping includes downsample
        // convs and counts dynamic attention separately).
        let cases: &[(&str, f64)] = &[
            ("resnet18", 1.8e9),
            ("vgg16", 15.5e9),
            ("alexnet", 0.72e9),
        ];
        for (name, published) in cases {
            let m = by_name(name).unwrap().total_macs() as f64;
            let rel = (m - published).abs() / published;
            assert!(rel < 0.25, "{name}: {m:.3e} vs {published:.3e}");
        }
    }

    #[test]
    fn dynamic_layers_only_in_transformers() {
        for name in ["resnet18", "vgg16", "alexnet", "mobilenetv3", "densenet201"] {
            let w = by_name(name).unwrap();
            assert!(w.layers.iter().all(|l| !l.dynamic()), "{name}");
        }
        for name in ["vit", "gpt2-medium", "mobilebert"] {
            let w = by_name(name).unwrap();
            assert!(w.layers.iter().any(|l| l.dynamic()), "{name}");
            // dynamic layers carry no weights
            assert!(w
                .layers
                .iter()
                .filter(|l| l.dynamic())
                .all(|l| l.weights == 0));
        }
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(by_name("resnet34").is_err());
    }

    #[test]
    fn compiled_tables_cached_per_instance_and_reset_on_clone() {
        let w = alexnet();
        assert!(
            std::ptr::eq(w.compiled(), w.compiled()),
            "same instance must reuse one table"
        );
        assert_eq!(w.compiled().layer_count(), w.layers.len());
        // clone-then-edit sees a freshly built table, never a stale one
        let mut doubled = w.clone();
        let extra = doubled.layers.clone();
        doubled.layers.extend(extra);
        assert_eq!(doubled.compiled().layer_count(), doubled.layers.len());
    }
}
