//! Transformer workload layer tables (paper §IV-J).
//!
//! Projection/FFN matrices map onto crossbars with `passes = seq_len`;
//! attention score (`Q·Kᵀ`) and context (`A·V`) matmuls are
//! activation×activation and flagged dynamic — they carry no stored
//! weights and execute on the digital vector units (`model::digital`),
//! mirroring how CIMLoop models transformer workloads on IMC hardware.
//! Embedding tables / norms / biases are not matmuls and are excluded.

use super::{Layer, LayerKind, Workload};

/// Weight-stationary projection layer applied to every token.
fn proj(name: &str, k: u64, n: u64, seq: u64) -> Layer {
    Layer {
        name: name.to_string(),
        kind: LayerKind::Fc,
        k,
        n,
        passes: seq,
        weights: k * n,
        in_bytes: seq * k,
        out_bytes: seq * n,
    }
}

/// Dynamic attention matmul aggregated across heads: MACs equal
/// `heads · seq² · head_dim`, expressed as `k = heads·head_dim`,
/// `n = seq`, `passes = seq`.
fn attn_dynamic(name: &str, heads: u64, head_dim: u64, seq: u64) -> Layer {
    Layer {
        name: name.to_string(),
        kind: LayerKind::Dynamic,
        k: heads * head_dim,
        n: seq,
        passes: seq,
        weights: 0,
        in_bytes: 2 * seq * heads * head_dim,
        out_bytes: seq * seq * heads / 8, // scores kept at reduced precision
    }
}

/// ViT-B/16 at 224×224 (86M params): 196 patches + class token.
pub fn vit_b16() -> Workload {
    let d = 768u64;
    let seq = 197u64;
    let heads = 12u64;
    let hd = d / heads;
    let mut layers = Vec::new();
    // patch embedding as a 16×16×3 conv = 768×768 matmul over 196 patches
    layers.push(Layer {
        name: "patch_embed".into(),
        kind: LayerKind::Conv,
        k: 16 * 16 * 3,
        n: d,
        passes: 196,
        weights: 16 * 16 * 3 * d,
        in_bytes: 224 * 224 * 3,
        out_bytes: 196 * d,
    });
    for b in 0..12 {
        layers.push(proj(&format!("blk{b}.qkv"), d, 3 * d, seq));
        layers.push(attn_dynamic(&format!("blk{b}.scores"), heads, hd, seq));
        layers.push(attn_dynamic(&format!("blk{b}.context"), heads, hd, seq));
        layers.push(proj(&format!("blk{b}.attn_out"), d, d, seq));
        layers.push(proj(&format!("blk{b}.mlp_fc1"), d, 4 * d, seq));
        layers.push(proj(&format!("blk{b}.mlp_fc2"), 4 * d, d, seq));
    }
    layers.push(proj("head", d, 1000, 1));
    Workload::new("vit", layers)
}

/// MobileBERT (24 blocks, hidden 512, intra-bottleneck 128, 4 stacked
/// FFNs per block, 4 heads; seq 128). ~18M matmul params.
pub fn mobilebert() -> Workload {
    let hidden = 512u64;
    let intra = 128u64;
    let seq = 128u64;
    let heads = 4u64;
    let hd = intra / heads;
    let mut layers = Vec::new();
    for b in 0..24 {
        let p = |s: &str| format!("blk{b}.{s}");
        layers.push(proj(&p("bottleneck_in"), hidden, intra, seq));
        layers.push(proj(&p("qkv"), intra, 3 * intra, seq));
        layers.push(attn_dynamic(&p("scores"), heads, hd, seq));
        layers.push(attn_dynamic(&p("context"), heads, hd, seq));
        layers.push(proj(&p("attn_out"), intra, intra, seq));
        for f in 0..4 {
            layers.push(proj(&p(&format!("ffn{f}_up")), intra, hidden, seq));
            layers.push(proj(&p(&format!("ffn{f}_down")), hidden, intra, seq));
        }
        layers.push(proj(&p("bottleneck_out"), intra, hidden, seq));
    }
    Workload::new("mobilebert", layers)
}

/// GPT-2 Medium (24 layers, d=1024, 16 heads, FFN 4096, seq 1024; ~353M
/// matmul params including the untied LM head).
pub fn gpt2_medium() -> Workload {
    let d = 1024u64;
    let seq = 1024u64;
    let heads = 16u64;
    let hd = d / heads;
    let mut layers = Vec::new();
    for b in 0..24 {
        let p = |s: &str| format!("h{b}.{s}");
        layers.push(proj(&p("qkv"), d, 3 * d, seq));
        layers.push(attn_dynamic(&p("scores"), heads, hd, seq));
        layers.push(attn_dynamic(&p("context"), heads, hd, seq));
        layers.push(proj(&p("attn_out"), d, d, seq));
        layers.push(proj(&p("ffn_up"), d, 4 * d, seq));
        layers.push(proj(&p("ffn_down"), 4 * d, d, seq));
    }
    // LM head (largest single GPT-2 layer, 1024×50257 ≈ 5.15e7 weights —
    // still smaller than VGG16's fc6, see workloads::tests).
    layers.push(proj("lm_head", d, 50257, seq));
    Workload::new("gpt2-medium", layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vit_params() {
        let w = vit_b16();
        let total = w.total_weights() as f64;
        // 86.4M park (matmul-only ≈ 85.8M)
        assert!((total - 85.8e6).abs() / 85.8e6 < 0.03, "{total}");
        assert_eq!(w.layers.len(), 1 + 12 * 6 + 1);
    }

    #[test]
    fn gpt2_params_and_largest_layer() {
        let w = gpt2_medium();
        let total = w.total_weights() as f64;
        assert!((total - 353.0e6).abs() / 353.0e6 < 0.03, "{total}");
        assert_eq!(w.max_layer_weights(), 1024 * 50257);
    }

    #[test]
    fn mobilebert_block_structure() {
        let w = mobilebert();
        assert_eq!(w.layers.len(), 24 * 14);
        // 4 FFN pairs per block
        let ffn = w.layers.iter().filter(|l| l.name.contains("ffn")).count();
        assert_eq!(ffn, 24 * 8);
    }

    #[test]
    fn dynamic_macs_match_head_math() {
        let w = vit_b16();
        let scores = w
            .layers
            .iter()
            .find(|l| l.name == "blk0.scores")
            .unwrap();
        // heads * seq^2 * head_dim = 12 * 197^2 * 64
        assert_eq!(scores.macs(), 12 * 197 * 197 * 64);
        assert_eq!(scores.weights, 0);
    }
}
