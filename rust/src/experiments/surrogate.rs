//! Surrogate pre-screening ablation — does the two-stage generation
//! loop (`--screen-frac`, see `docs/search.md`) buy search quality at
//! **equal wall-clock**?
//!
//! For each paper scenario family (`scenarios::paper_specs`: cnn4 on
//! weight-stationary RRAM, all9 on weight-swapping SRAM) the experiment
//! runs the four-phase GA on the full joint problem at screen fractions
//! 1.0 (the exact loop), 0.5 and 0.25 — same seed, same budget, same
//! initial population. The comparison is equal-wall-clock *by
//! construction*, not merely equal-eval: screening never changes the
//! number of exact evaluator calls per generation (the dominant cost);
//! it widens the variation pool by `1/frac` and sends only the
//! predicted-best λ candidates to the evaluator, so every row spends
//! the same evaluation budget and, up to the microseconds of the ridge
//! fit, the same wall-clock. A "vs exact" ratio below 1.0 therefore
//! means the screened search found a strictly better design from the
//! same time budget.
//!
//! Every row is a checkpoint cell (`surrogate:<set>:f<pct>`), so
//! `--resume` replays completed fractions; the sweep is bit-identical
//! across `--threads`/`--workers` (`rust/tests/surrogate_screen.rs`).
//! The row-level fraction overrides the context's `--screen-frac` —
//! the sweep *is* the experiment.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::report::Report;
use crate::scenarios;
use crate::search::GaConfig;
use crate::util::table::Table;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Surrogate;

impl super::Experiment for Surrogate {
    fn id(&self) -> &'static str {
        "surrogate"
    }
    fn description(&self) -> &'static str {
        "Surrogate pre-screening ablation: screened GA vs exact loop at equal wall-clock"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Medium
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

/// The swept screen fractions; 1.0 first so the exact baseline anchors
/// every "vs exact" ratio in its table.
const FRACS: [f64; 3] = [1.0, 0.5, 0.25];

/// Stable cell-key tag for a fraction (`f100`, `f50`, `f25`).
fn frac_tag(frac: f64) -> String {
    format!("f{:.0}", frac * 100.0)
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let mut report = Report::new(
        "surrogate",
        "Surrogate pre-screening vs the exact GA loop at equal wall-clock",
    );
    for spec in scenarios::paper_specs() {
        let problem = ctx.problem(&spec.space, &spec.set, spec.mem, spec.objective());
        ckpt.warm_problem(&problem);
        let mut t = Table::new(
            &format!(
                "{} on {} — --screen-frac sweep (joint {}-aggregated EDAP; \
                 same seed and budget in every row)",
                spec.name,
                spec.mem.name(),
                spec.agg.name()
            ),
            &["screen-frac", "pool x", "best EDAP", "vs exact", "evals", "wall"],
        );
        let mut exact_best = f64::NAN;
        for &frac in &FRACS {
            let cfg = GaConfig {
                screen_frac: frac,
                top_k: ctx.top_k,
                ..common::four_phase(ctx)
            };
            let r = common::ga_cell(
                ckpt,
                &format!("surrogate:{}:{}", spec.name, frac_tag(frac)),
                &problem,
                cfg,
                ctx.seed,
            )?;
            if frac >= 1.0 {
                exact_best = r.best_score;
            }
            let ratio = if exact_best.is_finite() && exact_best > 0.0 {
                r.best_score / exact_best
            } else {
                f64::NAN
            };
            t.row(vec![
                format!("{frac:.2}"),
                format!("{:.0}x", 1.0 / frac.max(0.05)),
                common::s(r.best_score),
                common::s(ratio),
                r.evals.to_string(),
                ctx.fmt_wall(r.wall),
            ]);
        }
        ckpt.absorb_problem(&problem)?;
        report.table(t);
    }
    report.note(
        "equal wall-clock by construction, not merely equal-eval: screening \
         never changes the exact evaluator calls per generation (the dominant \
         cost) — it widens the variation pool by 1/frac and only the \
         predicted-best candidates are evaluated, so every row spends the \
         same evaluation budget and, up to the ridge fit's microseconds, the \
         same wall-clock. 'vs exact' < 1.0 = the screened run found a better \
         design from the same time budget. See docs/search.md.",
    );
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frac_tags_are_stable_cell_keys() {
        assert_eq!(frac_tag(1.0), "f100");
        assert_eq!(frac_tag(0.5), "f50");
        assert_eq!(frac_tag(0.25), "f25");
    }

    #[test]
    fn quick_sweep_reports_both_sets_at_equal_budget() {
        let mut ctx = ExpContext::quick(61);
        ctx.stable = true;
        ctx.out_dir = std::env::temp_dir().join("imcopt-surrogate-test");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 2, "one table per paper family");
        for t in &r.tables {
            assert_eq!(t.rows.len(), FRACS.len());
            // the exact row anchors the ratio at exactly 1.0
            assert_eq!(t.rows[0][0], "1.00");
            let anchor: f64 = t.rows[0][3].parse().unwrap();
            assert_eq!(anchor, 1.0);
            // equal evaluation budget in every row — the claim the
            // experiment exists to demonstrate
            for row in &t.rows[1..] {
                assert_eq!(row[4], t.rows[0][4], "evals must match the exact row");
            }
            // stable mode masks wall-clock
            assert!(t.rows.iter().all(|row| row[5] == "-"));
        }
        assert!(ctx.out_dir.join("surrogate.md").exists());
        assert!(ctx.out_dir.join("surrogate.json").exists());
    }

    #[test]
    fn screened_rows_are_deterministic_per_seed() {
        let mut a = ExpContext::quick(62);
        a.stable = true;
        a.out_dir = std::env::temp_dir().join("imcopt-surrogate-det-a");
        let _ = std::fs::remove_dir_all(&a.out_dir);
        let mut b = ExpContext::quick(62);
        b.stable = true;
        b.out_dir = std::env::temp_dir().join("imcopt-surrogate-det-b");
        let _ = std::fs::remove_dir_all(&b.out_dir);
        let ra = run(&a, &mut Checkpoint::disabled()).unwrap();
        let rb = run(&b, &mut Checkpoint::disabled()).unwrap();
        for (ta, tb) in ra.tables.iter().zip(&rb.tables) {
            assert_eq!(ta.rows, tb.rows);
        }
    }
}
