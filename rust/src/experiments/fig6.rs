//! Fig. 6 (§IV-F): design insights — the optimized hardware parameters and
//! resulting EDAP/energy/latency for RRAM vs SRAM across objective
//! functions (EDAP, energy, latency, area). Energy/latency are reported
//! for the largest workload (VGG16), as in the paper.
//!
//! Paper shape: RRAM converges to max rows (512) with fewer columns except
//! under area-only optimization; SRAM prefers fewer rows / more columns;
//! SRAM shows lower energy but higher latency (swapping); RRAM wins EDAP.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::{Aggregation, Objective, ObjectiveKind};
use crate::report::Report;
use crate::space::idx;
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Fig6;

impl super::Experiment for Fig6 {
    fn id(&self) -> &'static str {
        "fig6"
    }
    fn description(&self) -> &'static str {
        "Optimized RRAM vs SRAM design parameters across objectives"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Light
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Experiment
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, _ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let vgg_index = 1usize;
    let mut report = Report::new(
        "fig6",
        "Optimized RRAM vs SRAM design parameters across objectives (VGG16 E/L shown)",
    );

    let objectives = [
        ObjectiveKind::Edap,
        ObjectiveKind::Energy,
        ObjectiveKind::Latency,
        ObjectiveKind::Area,
    ];

    let mut rram_edap = f64::INFINITY;
    let mut sram_edap = f64::INFINITY;

    for (mem, space) in [
        (MemoryTech::Rram, crate::space::SearchSpace::rram()),
        (MemoryTech::Sram, crate::space::SearchSpace::sram()),
    ] {
        let mut t = Table::new(
            &format!("{} — optimized parameters per objective", mem.name()),
            &[
                "objective", "rows", "cols", "macros/tile", "tiles/rt", "groups",
                "bits", "V", "tcyc ns", "GLB KB", "E_vgg mJ", "L_vgg ms", "area mm2",
                "EDAP_vgg",
            ],
        );
        for kind in objectives {
            let objective = Objective::new(kind, Aggregation::Max);
            let p = ctx.problem(&space, &set, mem, objective);
            let r = common::run_ga(&p, common::four_phase(ctx), ctx.seed);
            let raw = space.decode(&r.best);
            let ms = p.metrics_all_workloads(&r.best);
            let vg = &ms[vgg_index];
            let edap = vg.edap();
            if kind == ObjectiveKind::Edap {
                match mem {
                    MemoryTech::Rram => rram_edap = edap,
                    MemoryTech::Sram => sram_edap = edap,
                }
            }
            t.row(vec![
                objective.kind.name().into(),
                format!("{}", raw[idx::ROWS]),
                format!("{}", raw[idx::COLS]),
                format!("{}", raw[idx::C_PER_TILE]),
                format!("{}", raw[idx::T_PER_ROUTER]),
                format!("{}", raw[idx::G_PER_CHIP]),
                format!("{}", raw[idx::BITS_CELL]),
                format!("{:.2}", raw[idx::V_STEP]),
                format!("{}", raw[idx::T_CYCLE_NS]),
                format!("{}", raw[idx::GLB_KB]),
                common::s(vg.energy * 1e3),
                common::s(vg.latency * 1e3),
                common::s(vg.area),
                common::s(edap),
            ]);
        }
        report.table(t);
    }
    report.note(format!(
        "EDAP-optimized VGG16 EDAP: RRAM {} vs SRAM {} (paper: RRAM consistently lower)",
        common::s(rram_edap),
        common::s(sram_edap)
    ));
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_quick_has_four_objectives_per_mem() {
        let ctx = ExpContext::quick(23);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 2);
        for t in &r.tables {
            assert_eq!(t.rows.len(), 4);
        }
    }
}
