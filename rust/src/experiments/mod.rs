//! Experiment registry: one module per paper table/figure (see DESIGN.md
//! §4 for the index) plus the `genmatrix` generalization sweep.
//!
//! Every experiment is a unit struct implementing [`Experiment`] and
//! listed in [`REGISTRY`] (paper order). The registry replaces the old
//! string `match` dispatch: the CLI, benches, CI validation and the
//! checkpoint/resume runner all iterate the same list, so adding a
//! scenario is one module + one registry entry — see README.md
//! ("Adding an experiment").

pub mod ablations;
pub mod checkpoint;
pub mod common;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod genmatrix;
pub mod table3;
pub mod table5;
pub mod table6;

use crate::coordinator::ExpContext;
use crate::report::Report;
use crate::util::json::Json;
use anyhow::{Context, Result};
use checkpoint::Checkpoint;

/// Coarse run-cost class under the paper budget (the `--quick` budget
/// shrinks everything to CI scale). Shown by `imcopt list`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cost {
    /// Seconds: a handful of searches on the 4-workload set.
    Light,
    /// Minutes: repeated searches or a scenario sweep.
    Medium,
    /// Tens of minutes: many repeats, large workload sets, or panels.
    Heavy,
}

impl Cost {
    pub fn name(&self) -> &'static str {
        match self {
            Cost::Light => "light",
            Cost::Medium => "medium",
            Cost::Heavy => "heavy",
        }
    }
}

/// A registered experiment. Implementations are stateless unit structs;
/// all run state lives in the [`ExpContext`] and the [`Checkpoint`].
pub trait Experiment: Sync {
    /// Stable id (CLI argument, artifact file stem, checkpoint name).
    fn id(&self) -> &'static str;
    /// One-line description for `imcopt list`.
    fn description(&self) -> &'static str;
    /// Estimated cost class under the paper budget.
    fn cost(&self) -> Cost;
    /// Produce the report, journaling resumable work units through the
    /// checkpoint. Must emit its artifacts under `ctx.out_dir`.
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report>;
}

/// All experiments in paper order (the `genmatrix` scenario sweep sits
/// with the other generalization results, before the ablation suite).
pub static REGISTRY: [&dyn Experiment; 13] = [
    &table3::Table3,
    &fig3::Fig3,
    &fig4::Fig4,
    &table5::Table5,
    &fig5::Fig5,
    &table6::Table6,
    &fig6::Fig6,
    &fig7::Fig7,
    &fig8::Fig8,
    &fig9::Fig9,
    &fig10::Fig10,
    &genmatrix::GenMatrix,
    &ablations::Ablations,
];

/// All experiment ids in registry order (kept as a const array for
/// callers that want a compile-time list; `registry_matches_all_ids`
/// pins it to [`REGISTRY`]).
pub const ALL_IDS: [&str; 13] = [
    "table3", "fig3", "fig4", "table5", "fig5", "table6", "fig6", "fig7", "fig8", "fig9",
    "fig10", "genmatrix", "ablations",
];

/// Look up a registered experiment.
pub fn by_id(id: &str) -> Option<&'static dyn Experiment> {
    REGISTRY.iter().copied().find(|e| e.id() == id)
}

/// Run one experiment without persistence (library/test entry point).
pub fn run(id: &str, ctx: &ExpContext) -> Result<Report> {
    run_with(id, ctx, &mut Checkpoint::disabled())
}

/// Run one experiment against an explicit checkpoint.
pub fn run_with(id: &str, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let exp = by_id(id).with_context(|| {
        format!("unknown experiment '{id}' (try one of {ALL_IDS:?})")
    })?;
    exp.run(ctx, ckpt)
}

/// Outcome of a [`run_selected`] sweep.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunSummary {
    /// Experiments executed (fully or partially fresh).
    pub executed: usize,
    /// Experiments whose completed report was replayed from the journal.
    pub replayed: usize,
    /// Journaled cells reused across all experiments.
    pub cells_reused: usize,
    /// Cells computed fresh across all experiments.
    pub cells_computed: usize,
}

impl RunSummary {
    /// Stable one-line form printed by the CLI and grepped by `ci.sh`'s
    /// resume smoke check.
    pub fn to_line(&self) -> String {
        format!(
            "run summary: executed={} replayed={} cells_reused={} cells_computed={}",
            self.executed, self.replayed, self.cells_reused, self.cells_computed
        )
    }
}

/// The configuration fields a checkpoint journal's cells depend on
/// (thread count deliberately excluded: scores are thread-invariant).
/// Journals refuse to resume under a different fingerprint.
fn config_fingerprint(ctx: &ExpContext) -> Json {
    Json::obj(vec![
        ("seed", Json::Str(ctx.seed.to_string())),
        ("quick", Json::Bool(ctx.quick)),
        ("stable", Json::Bool(ctx.stable)),
        ("topk", Json::Num(ctx.top_k as f64)),
        ("backend", Json::Str(format!("{:?}", ctx.backend_choice))),
    ])
}

/// Run a list of experiments with per-experiment checkpoints under
/// `ctx.out_dir`. With `ctx.resume`, completed experiments replay their
/// journaled reports byte-identically and partially-complete ones skip
/// their journaled cells; without it every checkpoint starts cold.
/// Resuming with a different seed/budget/topk/backend/stable mode is
/// rejected (the journal pins its configuration).
pub fn run_selected(ids: &[&str], ctx: &ExpContext) -> Result<RunSummary> {
    let mut summary = RunSummary::default();
    let config = config_fingerprint(ctx);
    for &id in ids {
        // resolve before spending any work so typos fail fast
        by_id(id).with_context(|| {
            format!("unknown experiment '{id}' (try one of {ALL_IDS:?})")
        })?;
        println!("\n================ {id} ================");
        let mut ckpt = Checkpoint::for_experiment(&ctx.out_dir, id, ctx.resume)?;
        ckpt.bind_config(&config)
            .with_context(|| format!("cannot resume '{id}'"))?;
        if let Some(report) = ckpt.stored_report()? {
            println!("[resume] {id}: replaying completed report");
            report.emit(&ctx.out_dir)?;
            summary.replayed += 1;
        } else {
            let report = run_with(id, ctx, &mut ckpt)?;
            ckpt.store_report(&report)?;
            summary.executed += 1;
        }
        summary.cells_reused += ckpt.reused();
        summary.cells_computed += ckpt.computed();
    }
    Ok(summary)
}

#[cfg(test)]
mod registry_tests {
    use super::*;

    #[test]
    fn registry_matches_all_ids() {
        let ids: Vec<&str> = REGISTRY.iter().map(|e| e.id()).collect();
        assert_eq!(ids, ALL_IDS);
    }

    #[test]
    fn registry_metadata_is_populated() {
        for exp in REGISTRY {
            assert!(!exp.description().is_empty(), "{}", exp.id());
            assert!(!exp.cost().name().is_empty());
        }
    }

    #[test]
    fn unknown_id_fails_fast_in_run_selected() {
        let ctx = ExpContext::quick(1);
        let err = run_selected(&["nope"], &ctx).unwrap_err();
        assert!(format!("{err}").contains("unknown experiment"));
    }
}
