//! One module per paper table/figure (see DESIGN.md §4 for the index).

pub mod ablations;
pub mod common;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod table3;
pub mod table5;
pub mod table6;

use crate::coordinator::ExpContext;
use crate::report::Report;
use anyhow::Result;

/// All experiment ids in paper order, plus the extra ablation suite.
pub const ALL_IDS: [&str; 12] = [
    "table3", "fig3", "fig4", "table5", "fig5", "table6", "fig6", "fig7", "fig8", "fig9",
    "fig10", "ablations",
];

/// Dispatch one experiment by id.
pub fn run(id: &str, ctx: &ExpContext) -> Result<Report> {
    match id {
        "table3" => table3::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "table5" => table5::run(ctx),
        "fig5" => fig5::run(ctx),
        "table6" => table6::run(ctx),
        "fig6" => fig6::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "fig9" => fig9::run(ctx),
        "fig10" => fig10::run(ctx),
        "ablations" => ablations::run(ctx),
        other => anyhow::bail!("unknown experiment '{other}' (try one of {ALL_IDS:?})"),
    }
}
