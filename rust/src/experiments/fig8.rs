//! Fig. 8 (§IV-H): RRAM non-idealities — hardware-accuracy co-optimization
//! with the objective `max(E)·max(L)·A / Π acc`, compared against (i) the
//! same objective optimized for the largest workload only, and (ii) plain
//! EDAP joint optimization (accuracy ignored).
//!
//! Paper shape: joint beats largest-workload-only; the accuracy-aware and
//! EDAP-only joint searches converge to (nearly) the same architecture
//! because cycle-to-cycle noise — set by bits/cell — dominates IR-drop.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::{Aggregation, Objective, ObjectiveKind};
use crate::report::Report;
use crate::util::table::Table;
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Fig8;

impl super::Experiment for Fig8 {
    fn id(&self) -> &'static str {
        "fig8"
    }
    fn description(&self) -> &'static str {
        "RRAM non-idealities: accuracy-aware joint optimization"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Light
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Experiment
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, _ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let space = crate::space::SearchSpace::rram();
    let acc_obj = Objective::new(ObjectiveKind::EdapAccuracy, Aggregation::Max);
    let edap_obj = Objective::edap();
    let mut report = Report::new(
        "fig8",
        "RRAM non-idealities: accuracy-aware joint optimization",
    );

    // (a) joint, accuracy-aware
    let p_joint = ctx.problem(&space, &set, MemoryTech::Rram, acc_obj);
    let r_joint = common::run_ga(&p_joint, common::four_phase(ctx), ctx.seed);
    // (b) largest-workload-only, accuracy-aware (naive baseline of §IV-A)
    let r_largest =
        common::naive_largest_search(ctx, &space, &set, MemoryTech::Rram, acc_obj, ctx.seed);
    // (c) joint, EDAP only
    let p_edap = ctx.problem(&space, &set, MemoryTech::Rram, edap_obj);
    let r_edap = common::run_ga(&p_edap, common::four_phase(ctx), ctx.seed);

    let mut t = Table::new(
        "EDAP and estimated accuracy per workload (30 noisy iterations)",
        &[
            "strategy", "workload", "EDAP (mJ·ms·mm²)", "accuracy % (8-bit baseline)",
        ],
    );
    for (name, best) in [
        ("joint + accuracy", &r_joint.best),
        ("largest-workload + accuracy", &r_largest.best),
        ("joint EDAP-only", &r_edap.best),
    ] {
        let edaps = common::per_workload_scores(&p_joint, best, &edap_obj);
        // accuracy estimates come through the problem's (possibly AOT
        // noisy-crossbar) proxy path
        let ev = p_joint.evaluate_design(best);
        let accs = ev
            .accuracies
            .unwrap_or_else(|| vec![f64::NAN; set.len()]);
        for (i, w) in set.workloads.iter().enumerate() {
            let (base, _) = crate::accuracy::baseline(&w.name);
            t.row(vec![
                name.into(),
                w.name.clone(),
                common::s(edaps[i]),
                format!("{:.2} ({:.2})", accs[i] * 100.0, base * 100.0),
            ]);
        }
    }
    report.table(t);

    // architecture agreement between accuracy-aware and EDAP-only joint
    let hamming = r_joint.best.hamming(&r_edap.best);
    report.note(format!(
        "accuracy-aware vs EDAP-only joint architectures differ in {hamming}/10 \
         parameters (paper: nearly identical, noise dominates IR-drop)"
    ));
    report.note(format!(
        "designs: acc-aware {} | EDAP-only {} | largest-only {}",
        space.describe(&r_joint.best),
        space.describe(&r_edap.best),
        space.describe(&r_largest.best)
    ));
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_quick_reports_accuracy_below_baseline() {
        let ctx = ExpContext::quick(37);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        let t = &r.tables[0];
        assert_eq!(t.rows.len(), 12); // 3 strategies x 4 workloads
        for row in &t.rows {
            // "est (base)" column: estimated accuracy must not exceed the
            // 8-bit baseline
            let cell = &row[3];
            let est: f64 = cell.split(' ').next().unwrap().parse().unwrap();
            let base: f64 = cell
                .split(['(', ')'])
                .nth(1)
                .unwrap()
                .parse()
                .unwrap();
            assert!(est <= base + 1e-6, "{cell}");
            assert!(est > 0.0);
        }
    }
}
