//! Cross-set transfer portfolios — "train on cnn4, deploy on the all9
//! extras" and friends, over the 9-workload set on weight-swapping SRAM
//! (§IV-J, Mean aggregation).
//!
//! Where `genmatrix`/`genmatrix_k` hold workloads out of one set, this
//! experiment poses asymmetric train/deploy scenarios
//! (`scenarios::transfer_portfolios`):
//!
//! * `cnn4-to-extras` — the paper's 4-workload joint design deployed on
//!   the five workloads it never saw (MobileBERT, DenseNet-201,
//!   ResNet-50, ViT-B/16, GPT-2 Medium): pure transfer.
//! * `cnn4-to-all9` — the same design scored on the full set, showing
//!   how much headroom it keeps on its own training set vs the extras.
//! * `all9-joint` — the all-9 joint reference deployed per workload.
//!
//! Every deploy-side EDAP is compared against that workload's
//! separate-search specialist bound; the bounds are journaled once and
//! shared across portfolios (`common::separate_bound_cell`). Restrict
//! the run with `--portfolio <id>[,<id>...]`. Per-portfolio JSON cells
//! land in `<out_dir>/transfer_cells/<portfolio>.json`
//! (`schemas/portfolio_cell.schema.json`).

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::report::Report;
use crate::scenarios::{self, Portfolio};
use crate::util::table::Table;
use anyhow::{bail, Context, Result};

/// Registry entry (see `experiments::REGISTRY`).
pub struct Transfer;

impl super::Experiment for Transfer {
    fn id(&self) -> &'static str {
        "transfer"
    }
    fn description(&self) -> &'static str {
        "Cross-set transfer: cnn4-trained designs deployed on the all9 extras"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Medium
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

/// The scenario legs and their transfer portfolios: by default the
/// paper's all9 SRAM set plus the weight-stationary companion row
/// (`all9-rram`, whose GPT-2 Medium deployments are infeasible by
/// construction and surface as an infeasibility rate); under a
/// user-defined `--spec` a single leg split at the half
/// (`scenarios::split_transfer_portfolios` — train on the first ⌈n/2⌉
/// workloads, deploy on the extras / the full set / the all-joint
/// reference).
fn spec_and_portfolios(
    ctx: &ExpContext,
) -> Result<Vec<(scenarios::ScenarioSpec, Vec<Portfolio>)>> {
    match &ctx.spec {
        None => Ok(vec![
            (scenarios::ScenarioSpec::all9(), scenarios::transfer_portfolios()),
            (scenarios::ScenarioSpec::all9_rram(), scenarios::rram_transfer_portfolios()),
        ]),
        Some(s) => {
            let spec = scenarios::ScenarioSpec::parse(s)
                .with_context(|| format!("parsing --spec '{s}'"))?;
            let n = spec.set.len();
            anyhow::ensure!(
                n >= 2,
                "transfer needs at least 2 workloads in the set (got {n}); widen --spec"
            );
            let ports = scenarios::split_transfer_portfolios(n, n.div_ceil(2).min(n - 1));
            Ok(vec![(spec, ports)])
        }
    }
}

/// Resolve `--portfolio` against every leg's transfer portfolios
/// (unknown ids fail fast with the union of available ids). Returns the
/// selected portfolios per leg, parallel to `legs`.
fn selected_portfolios(
    ctx: &ExpContext,
    legs: &[(scenarios::ScenarioSpec, Vec<Portfolio>)],
) -> Result<Vec<Vec<Portfolio>>> {
    let Some(csv) = &ctx.portfolio else {
        return Ok(legs.iter().map(|(_, ports)| ports.clone()).collect());
    };
    let mut picked: Vec<Vec<Portfolio>> = vec![Vec::new(); legs.len()];
    for id in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let hit = legs.iter().enumerate().find_map(|(li, (_, ports))| {
            ports.iter().find(|p| p.id == id).map(|p| (li, p.clone()))
        });
        match hit {
            Some((li, p)) => picked[li].push(p),
            None => {
                let ids: Vec<&str> = legs
                    .iter()
                    .flat_map(|(_, ports)| ports.iter().map(|p| p.id.as_str()))
                    .collect();
                bail!("unknown portfolio '{id}' (available: {ids:?})");
            }
        }
    }
    if picked.iter().all(|ps| ps.is_empty()) {
        bail!("--portfolio selected nothing (empty list)");
    }
    Ok(picked)
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let legs = spec_and_portfolios(ctx)?;
    let per_leg = selected_portfolios(ctx, &legs)?;
    let mut report = Report::new(
        "transfer",
        "Cross-set transfer: train/deploy portfolios vs per-workload bounds",
    );
    let cells_dir = ctx.out_dir.join("transfer_cells");
    std::fs::create_dir_all(&cells_dir)
        .with_context(|| format!("creating {}", cells_dir.display()))?;

    let mut summary = Table::new(
        "transfer portfolios — deploy-side EDAP gap vs specialist bound",
        &[
            "portfolio",
            "mem",
            "train",
            "deploy",
            "mean gap",
            "geo-mean gap",
            "worst gap",
            "infeasible rate",
            "worst workload",
        ],
    );
    let mut detail = Table::new(
        "per-workload deploy gaps (trained? = workload was in the train set)",
        &["portfolio", "workload", "trained?", "EDAP joint", "EDAP bound", "gap x"],
    );
    for ((spec, _), ports) in legs.iter().zip(&per_leg) {
        let names = spec.set.names();
        for p in ports {
            // no joint sharing: transfer's kill/resume contract requires
            // its cells to recompute independently after a journal wipe
            let out = common::portfolio_cell(ckpt, "transfer", ctx, spec, p, false)?;
            let worst_label = out
                .summary
                .worst_at
                .map(|i| names[out.deploy[i].workload].to_string())
                .unwrap_or_else(|| "-".into());
            summary.row(vec![
                p.id.clone(),
                spec.mem.name().to_string(),
                p.train.len().to_string(),
                p.deploy.len().to_string(),
                common::s(out.summary.mean),
                common::s(out.summary.geo_mean),
                common::s(out.summary.worst),
                common::s(common::infeasible_rate(&out)),
                worst_label,
            ]);
            for d in &out.deploy {
                detail.row(vec![
                    p.id.clone(),
                    names[d.workload].to_string(),
                    String::from(if p.train.contains(&d.workload) { "yes" } else { "no" }),
                    common::s(d.joint_edap),
                    common::s(d.bound_edap),
                    common::s(d.gap),
                ]);
            }
            common::write_portfolio_cell(
                &cells_dir.join(format!("{}.json", p.id)),
                "transfer",
                spec,
                p,
                ctx.seed,
                &out,
            )?;
        }
    }
    report.table(summary);
    report.table(detail);
    report.note(
        "gap = joint design's EDAP on a deployed workload / that workload's \
         separate-search bound (1.0 = transfers as well as a specialist). \
         cnn4-to-extras is the paper's headline generalization claim posed as \
         pure transfer: nothing deployed was seen during the search. The \
         cnn4-to-extras-rram row replays it on weight-stationary RRAM, where \
         GPT-2 Medium cannot fit on-chip: such capacity failures stay in the \
         table as a deploy-side infeasible rate instead of dropping the row."
            .to_string(),
    );
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn transfer_quick_emits_summary_and_cells() {
        let mut ctx = ExpContext::quick(59);
        ctx.out_dir = std::env::temp_dir().join("imcopt-transfer-test");
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 2);
        assert_eq!(r.tables[0].rows.len(), 4, "three SRAM portfolios + the RRAM row");
        // detail rows: (5 extras + 9 + 9) on SRAM + 5 extras on RRAM
        assert_eq!(r.tables[1].rows.len(), 28);
        let mut cells: Vec<(scenarios::Portfolio, &str)> = scenarios::transfer_portfolios()
            .into_iter()
            .map(|p| (p, "SRAM"))
            .collect();
        cells.extend(scenarios::rram_transfer_portfolios().into_iter().map(|p| (p, "RRAM")));
        for (p, mem) in cells {
            let path = ctx.out_dir.join("transfer_cells").join(format!("{}.json", p.id));
            let text = std::fs::read_to_string(&path)
                .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
            let v = json::parse(&text).unwrap();
            assert_eq!(v.get("experiment").and_then(|e| e.as_str()), Some("transfer"));
            assert_eq!(
                v.get("portfolio").and_then(|q| q.get("mem")).and_then(|m| m.as_str()),
                Some(mem)
            );
            let gaps = v.get("deploy_gaps").and_then(|g| g.as_arr()).unwrap();
            assert_eq!(gaps.len(), p.deploy.len());
            let rate = v
                .get("summary")
                .and_then(|s| s.get("infeasible_rate"))
                .and_then(|x| x.as_f64())
                .unwrap();
            assert!((0.0..=1.0).contains(&rate), "{rate}");
        }
        // the RRAM companion row keeps its capacity failures in-table:
        // GPT-2 Medium cannot fit a weight-stationary chip
        let text = std::fs::read_to_string(
            ctx.out_dir.join("transfer_cells/cnn4-to-extras-rram.json"),
        )
        .unwrap();
        let v = json::parse(&text).unwrap();
        let rate = v
            .get("summary")
            .and_then(|s| s.get("infeasible_rate"))
            .and_then(|x| x.as_f64())
            .unwrap();
        assert!(rate > 0.0, "expected gpt2-medium to be infeasible on RRAM, rate={rate}");
        // the pure-transfer portfolio never deploys on a trained workload
        let text = std::fs::read_to_string(
            ctx.out_dir.join("transfer_cells/cnn4-to-extras.json"),
        )
        .unwrap();
        let v = json::parse(&text).unwrap();
        for g in v.get("deploy_gaps").and_then(|g| g.as_arr()).unwrap() {
            assert_eq!(g.get("in_train"), Some(&json::Json::Bool(false)));
        }
    }

    #[test]
    fn portfolio_filter_selects_and_rejects() {
        let mut ctx = ExpContext::quick(61);
        let legs = spec_and_portfolios(&ctx).unwrap();
        let count = |picked: Vec<Vec<scenarios::Portfolio>>| -> usize {
            picked.iter().map(|ps| ps.len()).sum()
        };
        ctx.portfolio = Some("cnn4-to-extras".into());
        assert_eq!(count(selected_portfolios(&ctx, &legs).unwrap()), 1);
        ctx.portfolio = Some("cnn4-to-extras, all9-joint".into());
        assert_eq!(count(selected_portfolios(&ctx, &legs).unwrap()), 2);
        // the RRAM companion row resolves onto its own leg
        ctx.portfolio = Some("cnn4-to-extras-rram".into());
        let picked = selected_portfolios(&ctx, &legs).unwrap();
        assert!(picked[0].is_empty() && picked[1].len() == 1);
        ctx.portfolio = Some("nope".into());
        let err = selected_portfolios(&ctx, &legs).unwrap_err();
        assert!(format!("{err}").contains("unknown portfolio"), "{err}");
        ctx.portfolio = Some(" , ".into());
        assert!(selected_portfolios(&ctx, &legs).is_err());
        ctx.portfolio = None;
        assert_eq!(count(selected_portfolios(&ctx, &legs).unwrap()), 4);
    }

    #[test]
    fn spec_swaps_the_scenario_and_splits_at_the_half() {
        let mut ctx = ExpContext::quick(63);
        // default: the paper's all9 family plus the RRAM companion leg
        let legs = spec_and_portfolios(&ctx).unwrap();
        assert_eq!(legs.len(), 2);
        assert_eq!(legs[0].0.name, "all9");
        assert_eq!(legs[0].1[0].id, "cnn4-to-extras");
        assert_eq!(legs[1].0.name, "all9-rram");
        assert_eq!(legs[1].1[0].id, "cnn4-to-extras-rram");
        // custom family: one leg with generic head-split ids
        ctx.spec = Some("resnet18+vgg16+alexnet:rram".into());
        let legs = spec_and_portfolios(&ctx).unwrap();
        assert_eq!(legs.len(), 1);
        let (spec, ports) = &legs[0];
        assert_eq!(spec.name, "custom");
        assert_eq!(spec.set.len(), 3);
        assert_eq!(ports.len(), 3);
        assert_eq!(ports[0].id, "head2-to-extras");
        assert_eq!(ports[0].train, vec![0, 1]);
        assert_eq!(ports[0].deploy, vec![2]);
        assert_eq!(ports[2].id, "all-joint");
        // too-small and malformed specs fail fast
        ctx.spec = Some("alexnet:rram".into());
        assert!(spec_and_portfolios(&ctx).is_err());
        ctx.spec = Some("alexnet:dram".into());
        assert!(spec_and_portfolios(&ctx).is_err());
    }

    #[test]
    fn custom_spec_transfer_runs_end_to_end() {
        let mut ctx = ExpContext::quick(67);
        ctx.out_dir = std::env::temp_dir().join("imcopt-transfer-spec-test");
        ctx.spec = Some("resnet18+alexnet+mobilenetv3:rram".into());
        let _ = std::fs::remove_dir_all(&ctx.out_dir);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables[0].rows.len(), 3, "three split portfolios");
        // detail rows: 1 extra + 3 + 3
        assert_eq!(r.tables[1].rows.len(), 7);
        let path = ctx.out_dir.join("transfer_cells/head2-to-extras.json");
        let v = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(
            v.get("portfolio").and_then(|p| p.get("set")).and_then(|s| s.as_str()),
            Some("custom")
        );
    }
}
