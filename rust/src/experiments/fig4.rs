//! Fig. 4 + §IV-B: convergence and run-to-run stability of the proposed
//! 4-phase GA with enhanced sampling vs. the traditional GA, over
//! independent joint-EDAP RRAM runs (6 plotted in the paper, plus a
//! 25-run mean/std: 2.47±0.87 vs 1.21±0.16 mJ·ms·mm²).

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::MemoryTech;
use crate::objective::Objective;
use crate::report::Report;
use crate::util::{fmt_sig, stats, table::Table};
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Fig4;

impl super::Experiment for Fig4 {
    fn id(&self) -> &'static str {
        "fig4"
    }
    fn description(&self) -> &'static str {
        "Convergence & run-to-run stability of the 4-phase GA vs traditional GA"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Heavy
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let space = crate::space::SearchSpace::rram();
    let objective = Objective::edap();
    let mut report = Report::new(
        "fig4",
        "Convergence & stability: 4-phase GA + sampling vs traditional GA (RRAM, EDAP)",
    );

    let runs = ctx.repeats(6);
    let extra = ctx.repeats(25);

    let mut curves = Table::new(
        "Convergence (best-so-far EDAP by generation, run 0)",
        &["generation", "traditional GA", "4-phase GA + sampling"],
    );
    let mut finals_classic = Vec::new();
    let mut finals_fourphase = Vec::new();
    let mut curve_classic: Vec<f64> = Vec::new();
    let mut curve_fourphase: Vec<f64> = Vec::new();

    for run_i in 0..runs.max(extra) {
        let seed = ctx.seed.wrapping_add(run_i as u64 * 7919);
        // fresh problems per run so the cache doesn't leak information
        let p1 = ctx.problem(&space, &set, MemoryTech::Rram, objective);
        let r_classic = common::ga_cell(
            ckpt,
            &format!("fig4:classic:{run_i}"),
            &p1,
            common::classic(ctx),
            seed,
        )?;
        let p2 = ctx.problem(&space, &set, MemoryTech::Rram, objective);
        let r_four = common::ga_cell(
            ckpt,
            &format!("fig4:4phase:{run_i}"),
            &p2,
            common::four_phase(ctx),
            seed,
        )?;
        finals_classic.push(r_classic.best_score);
        finals_fourphase.push(r_four.best_score);
        if run_i == 0 {
            curve_classic = r_classic.history.clone();
            curve_fourphase = r_four.history.clone();
        }
    }
    let gens = curve_classic.len().max(curve_fourphase.len());
    let at = |v: &Vec<f64>, g: usize| -> String {
        v.get(g.min(v.len().saturating_sub(1)))
            .map(|x| common::s(*x))
            .unwrap_or_default()
    };
    for g in 0..gens {
        curves.row(vec![
            g.to_string(),
            at(&curve_classic, g),
            at(&curve_fourphase, g),
        ]);
    }
    report.table(curves);

    let plotted_c = &finals_classic[..runs.min(finals_classic.len())];
    let plotted_f = &finals_fourphase[..runs.min(finals_fourphase.len())];
    let mut t = Table::new(
        &format!("Final EDAP over {} independent runs", plotted_c.len()),
        &["run", "traditional GA", "4-phase GA + sampling"],
    );
    for i in 0..plotted_c.len() {
        t.row(vec![
            i.to_string(),
            common::s(plotted_c[i]),
            common::s(plotted_f[i]),
        ]);
    }
    report.table(t);

    let mut summary = Table::new(
        &format!("Mean ± std over {} runs (paper: 2.47±0.87 vs 1.21±0.16)", finals_classic.len()),
        &["algorithm", "mean EDAP", "std", "min", "max"],
    );
    for (name, xs) in [
        ("traditional GA", &finals_classic),
        ("4-phase GA + sampling", &finals_fourphase),
    ] {
        summary.row(vec![
            name.into(),
            fmt_sig(stats::mean(xs), 4),
            fmt_sig(stats::std_dev(xs), 3),
            fmt_sig(stats::min(xs), 4),
            fmt_sig(stats::max(xs), 4),
        ]);
    }
    report.table(summary);

    let better_mean = stats::mean(&finals_fourphase) <= stats::mean(&finals_classic);
    let tighter = stats::std_dev(&finals_fourphase) <= stats::std_dev(&finals_classic) * 1.2;
    report.note(format!(
        "4-phase GA mean better: {better_mean}; spread tighter-or-equal: {tighter} \
         (paper: consistently lower EDAP and smaller variance)"
    ));
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_quick_produces_three_tables() {
        let ctx = ExpContext::quick(3);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 3);
        assert!(!r.tables[0].rows.is_empty()); // convergence curve
        assert_eq!(r.tables[2].rows.len(), 2); // summary rows
    }
}
