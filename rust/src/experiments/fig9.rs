//! Fig. 9 + Table 7 (§IV-I): hardware-workload-technology co-optimization
//! — EDAP vs fabrication cost trade-off on SRAM hardware with the CMOS
//! node as a search variable and objective `max(E)·max(L)·Cost`,
//! `Cost = α·A`.
//!
//! Paper shape: feasible designs cluster by node; 65/90 nm violate the
//! area constraint; the Pareto front is populated by 7–14 nm designs with
//! the best trade-offs (knee) around 10 nm; 7 nm occupies the low-EDAP /
//! high-cost end.

use super::checkpoint::Checkpoint;
use super::common;
use crate::coordinator::ExpContext;
use crate::model::{tech, MemoryTech};
use crate::objective::{Aggregation, Objective, ObjectiveKind};
use crate::report::Report;
use crate::search::Problem;
use crate::space::idx;
use crate::util::{stats, table::Table};
use crate::workloads::WorkloadSet;
use anyhow::Result;

/// Registry entry (see `experiments::REGISTRY`).
pub struct Fig9;

impl super::Experiment for Fig9 {
    fn id(&self) -> &'static str {
        "fig9"
    }
    fn description(&self) -> &'static str {
        "EDAP vs fabrication cost across CMOS nodes (tech co-optimization)"
    }
    fn cost(&self) -> super::Cost {
        super::Cost::Medium
    }
    fn granularity(&self) -> super::Granularity {
        super::Granularity::Cell
    }
    fn run(&self, ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
        run(ctx, ckpt)
    }
}

pub fn run(ctx: &ExpContext, ckpt: &mut Checkpoint) -> Result<Report> {
    let set = WorkloadSet::cnn4();
    let space = crate::space::SearchSpace::sram_tech();
    let objective = Objective::new(ObjectiveKind::EdapCost, Aggregation::Max);
    let edap = Objective::edap();
    let mut report = Report::new(
        "fig9",
        "EDAP vs fabrication cost across CMOS nodes (SRAM, tech co-optimization)",
    );

    // joint cost-aware search as a checkpoint cell (a resumed run replays
    // it from the journal); its evaluation cache doubles as the cloud of
    // explored designs, persisted via the warmed eval memo
    let problem = ctx.problem(&space, &set, MemoryTech::Sram, objective);
    ckpt.warm_problem(&problem);
    let r = common::ga_cell(
        ckpt,
        "fig9:cnn4:joint",
        &problem,
        common::four_phase(ctx),
        ctx.seed,
    )?;

    // additional random sweep so every node is represented in the cloud
    let n_sweep = if ctx.quick { 200 } else { 3000 };
    let mut rng = crate::util::rng::Rng::seed_from(ctx.seed ^ 0x9e37);
    let sweep: Vec<crate::space::Design> =
        (0..n_sweep).map(|_| space.random(&mut rng)).collect();
    problem.score_batch(&sweep);
    ckpt.absorb_problem(&problem)?;

    // collect feasible (cost, edap) points from everything evaluated
    let mut points: Vec<(f64, f64, f64, crate::space::Design)> = Vec::new(); // cost, edap, tech
    let mut seen = std::collections::HashSet::new();
    let mut consider = |d: &crate::space::Design| {
        if !seen.insert(space.linear_index(d)) {
            return;
        }
        let ev = problem.evaluate_design(d);
        if !ev.score.is_finite() {
            return;
        }
        let raw = space.decode(d);
        let area = ev.metrics[0].area;
        let cost = tech::fabrication_cost(raw[idx::TECH_NM], area);
        let e = stats::max(&ev.metrics.iter().map(|m| m.energy * 1e3).collect::<Vec<_>>());
        let l = stats::max(&ev.metrics.iter().map(|m| m.latency * 1e3).collect::<Vec<_>>());
        points.push((cost, e * l * area, raw[idx::TECH_NM], d.clone()));
    };
    for d in &sweep {
        consider(d);
    }
    for (d, _) in &r.top {
        consider(d);
    }
    let _ = edap;

    // per-node statistics
    let mut t = Table::new(
        "Feasible designs per CMOS node (explored cloud)",
        &["node nm", "feasible points", "min EDAP", "min cost", "on Pareto front"],
    );
    let xy: Vec<(f64, f64)> = points.iter().map(|p| (p.0, p.1)).collect();
    let front = stats::pareto_front_2d(&xy);
    let front_set: std::collections::HashSet<usize> = front.iter().copied().collect();
    for node in tech::TECH_TABLE.iter() {
        let node_pts: Vec<usize> = (0..points.len())
            .filter(|&i| (points[i].2 - node.nm).abs() < 0.5)
            .collect();
        let on_front = node_pts.iter().filter(|i| front_set.contains(i)).count();
        let min_edap = node_pts
            .iter()
            .map(|&i| points[i].1)
            .fold(f64::INFINITY, f64::min);
        let min_cost = node_pts
            .iter()
            .map(|&i| points[i].0)
            .fold(f64::INFINITY, f64::min);
        t.row(vec![
            format!("{}", node.nm),
            node_pts.len().to_string(),
            common::s(min_edap),
            common::s(min_cost),
            on_front.to_string(),
        ]);
    }
    report.table(t);

    // Pareto-front designs with parameters (the paper annotates these)
    let mut pf = Table::new(
        "Pareto front (cost ↑, EDAP ↓)",
        &["cost (norm)", "EDAP", "node nm", "design"],
    );
    for &i in &front {
        pf.row(vec![
            common::s(points[i].0),
            common::s(points[i].1),
            format!("{}", points[i].2),
            space.describe(&points[i].3),
        ]);
    }
    report.table(pf);

    let advanced_on_front = front
        .iter()
        .filter(|&&i| points[i].2 <= 14.0)
        .count();
    report.note(format!(
        "{}/{} Pareto points use ≤14 nm nodes (paper: front dominated by 7–14 nm)",
        advanced_on_front,
        front.len()
    ));
    report.note(format!(
        "cost-aware search best: {} (score {})",
        space.describe(&r.best),
        common::s(r.best_score)
    ));
    report.emit(&ctx.out_dir)?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_quick_builds_pareto_front() {
        let ctx = ExpContext::quick(41);
        let r = run(&ctx, &mut Checkpoint::disabled()).unwrap();
        assert_eq!(r.tables.len(), 2);
        assert_eq!(r.tables[0].rows.len(), 8); // one per node
        assert!(!r.tables[1].rows.is_empty(), "empty Pareto front");
        // front is sorted by cost ascending and EDAP descending
        let costs: Vec<f64> = r.tables[1]
            .rows
            .iter()
            .map(|row| row[0].parse().unwrap())
            .collect();
        for w in costs.windows(2) {
            assert!(w[0] <= w[1] + 1e-9);
        }
    }
}
